//! Simulated TPU core: a dedicated OS thread owning a PJRT client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), which forces —
//! and conveniently models — the paper's device semantics: one program
//! running at a time per core, per-core program/memory state, explicit
//! transfers. A `DeviceCore` thread compiles HLO-text programs on demand and
//! executes them serially; `DeviceHandle` is the cloneable, `Send` handle
//! the coordinator threads use.
//!
//! Occupancy accounting (busy-time) feeds the actor/learner utilisation
//! stats that the paper's core-split ablation is about.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::tensor::HostTensor;

enum Command {
    /// Load + compile an HLO-text program under a string key.
    Compile { key: String, path: PathBuf, reply: mpsc::Sender<Result<()>> },
    /// Upload a tensor to device-resident memory under a named slot
    /// (e.g. parameters: uploaded once per version, reused every step —
    /// the paper's "parameters stay on device"; §Perf L3-1).
    Cache { slot: String, tensor: HostTensor, reply: mpsc::Sender<Result<()>> },
    /// Execute a compiled program. `cached` lists (input position, slot)
    /// pairs satisfied from device-resident cache instead of `inputs`.
    Execute {
        key: String,
        inputs: Vec<HostTensor>,
        cached: Vec<(usize, String)>,
        reply: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    Shutdown,
}

/// Busy-time counters shared with handles (read side of the occupancy stats).
#[derive(Default)]
struct CoreStats {
    busy_nanos: AtomicU64,
    executions: AtomicU64,
}

/// Cloneable, `Send` handle to a device core.
#[derive(Clone)]
pub struct DeviceHandle {
    pub core_id: usize,
    tx: mpsc::Sender<Command>,
    stats: Arc<CoreStats>,
    spawned_at: Instant,
}

impl DeviceHandle {
    /// Compile the HLO file under `key`; blocks until done.
    pub fn compile(&self, key: &str, path: PathBuf) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Command::Compile { key: key.to_string(), path, reply })
            .map_err(|_| anyhow!("core {} is down", self.core_id))?;
        rx.recv().map_err(|_| anyhow!("core {} died compiling {key}", self.core_id))?
    }

    /// Start compilation without waiting; returns the receiver to join on.
    pub fn compile_async(&self, key: &str, path: PathBuf) -> Result<mpsc::Receiver<Result<()>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Command::Compile { key: key.to_string(), path, reply })
            .map_err(|_| anyhow!("core {} is down", self.core_id))?;
        Ok(rx)
    }

    /// Execute `key` with `inputs`; blocks until the result is back on host.
    pub fn execute(&self, key: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Command::Execute { key: key.to_string(), inputs, cached: Vec::new(), reply })
            .map_err(|_| anyhow!("core {} is down", self.core_id))?;
        rx.recv().map_err(|_| anyhow!("core {} died executing {key}", self.core_id))?
    }

    /// Upload `tensor` to a device-resident cache slot (blocks until done).
    pub fn cache(&self, slot: &str, tensor: HostTensor) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Command::Cache { slot: slot.to_string(), tensor, reply })
            .map_err(|_| anyhow!("core {} is down", self.core_id))?;
        rx.recv().map_err(|_| anyhow!("core {} died caching {slot}", self.core_id))?
    }

    /// Execute with some inputs taken from device-resident cache slots:
    /// `cached` is a list of (input position, slot); `inputs` supplies the
    /// remaining positions in order.
    pub fn execute_cached(
        &self,
        key: &str,
        inputs: Vec<HostTensor>,
        cached: Vec<(usize, String)>,
    ) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Command::Execute { key: key.to_string(), inputs, cached, reply })
            .map_err(|_| anyhow!("core {} is down", self.core_id))?;
        rx.recv().map_err(|_| anyhow!("core {} died executing {key}", self.core_id))?
    }

    /// Like [`Self::execute_cached`], but returns immediately with a
    /// receiver for the result. The split-batch pipelined actor fires one
    /// sub-batch's inference through this while the worker pool steps
    /// another sub-batch's environments (DESIGN.md §2).
    pub fn execute_cached_async(
        &self,
        key: &str,
        inputs: Vec<HostTensor>,
        cached: Vec<(usize, String)>,
    ) -> Result<mpsc::Receiver<Result<Vec<HostTensor>>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Command::Execute { key: key.to_string(), inputs, cached, reply })
            .map_err(|_| anyhow!("core {} is down", self.core_id))?;
        Ok(rx)
    }

    /// Fire an execution and return a receiver for the result — lets an
    /// actor thread overlap env stepping with device compute (the paper's
    /// multiple-threads-per-core trick relies on this shape).
    pub fn execute_async(
        &self,
        key: &str,
        inputs: Vec<HostTensor>,
    ) -> Result<mpsc::Receiver<Result<Vec<HostTensor>>>> {
        self.execute_cached_async(key, inputs, Vec::new())
    }

    /// Fraction of wall-time this core spent executing programs.
    pub fn occupancy(&self) -> f64 {
        let busy = self.stats.busy_nanos.load(Ordering::Relaxed) as f64;
        let total = self.spawned_at.elapsed().as_nanos() as f64;
        if total > 0.0 {
            busy / total
        } else {
            0.0
        }
    }

    pub fn executions(&self) -> u64 {
        self.stats.executions.load(Ordering::Relaxed)
    }

    pub fn busy_seconds(&self) -> f64 {
        self.stats.busy_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }
}

/// A running device-core thread. Dropping shuts the core down and joins it.
pub struct DeviceCore {
    pub handle: DeviceHandle,
    join: Option<std::thread::JoinHandle<()>>,
    tx: mpsc::Sender<Command>,
}

impl DeviceCore {
    /// Spawn a core thread with its own PJRT CPU client.
    pub fn spawn(core_id: usize) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Command>();
        let stats = Arc::new(CoreStats::default());
        let stats_thread = stats.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let join = std::thread::Builder::new()
            .name(format!("core-{core_id}"))
            .spawn(move || core_main(core_id, rx, stats_thread, ready_tx))
            .context("spawning core thread")?;

        // Wait for the PJRT client to come up so failures surface here.
        ready_rx
            .recv()
            .map_err(|_| anyhow!("core {core_id} thread died during startup"))??;

        let handle = DeviceHandle {
            core_id,
            tx: tx.clone(),
            stats,
            spawned_at: Instant::now(),
        };
        Ok(Self { handle, join: Some(join), tx })
    }
}

impl Drop for DeviceCore {
    fn drop(&mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn core_main(
    core_id: usize,
    rx: mpsc::Receiver<Command>,
    stats: Arc<CoreStats>,
    ready: mpsc::Sender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu failed: {e:?}")));
            return;
        }
    };
    let mut programs: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut slots: HashMap<String, xla::PjRtBuffer> = HashMap::new();

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Command::Shutdown => break,
            Command::Cache { slot, tensor, reply } => {
                let res = (|| -> Result<()> {
                    // Either storage form (owned vector or Arc-shared arena
                    // view) lands here as a plain slice: the only copy is
                    // the host->device transfer itself (DESIGN.md §11).
                    let buf = if let Ok(v) = tensor.as_f32() {
                        client
                            .buffer_from_host_buffer(v, &tensor.shape, None)
                            .map_err(|e| anyhow!("cache {slot}: {e:?}"))?
                    } else {
                        client
                            .buffer_from_host_buffer(tensor.as_i32()?, &tensor.shape, None)
                            .map_err(|e| anyhow!("cache {slot}: {e:?}"))?
                    };
                    slots.insert(slot, buf);
                    Ok(())
                })();
                let _ = reply.send(res);
            }
            Command::Compile { key, path, reply } => {
                let res = (|| -> Result<()> {
                    let proto = xla::HloModuleProto::from_text_file(
                        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                    )
                    .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client
                        .compile(&comp)
                        .map_err(|e| anyhow!("compile {key}: {e:?}"))?;
                    programs.insert(key, exe);
                    Ok(())
                })();
                let _ = reply.send(res);
            }
            Command::Execute { key, inputs, cached, reply } => {
                let t0 = Instant::now();
                let res = (|| -> Result<Vec<HostTensor>> {
                    let exe = programs
                        .get(&key)
                        .ok_or_else(|| anyhow!("core {core_id}: program {key:?} not compiled"))?;
                    let mut out = if cached.is_empty() {
                        // host -> device, then execute (programs return one tuple)
                        let literals: Vec<xla::Literal> = inputs
                            .iter()
                            .map(|t| t.to_literal())
                            .collect::<Result<_>>()?;
                        exe.execute::<xla::Literal>(&literals)
                            .map_err(|e| anyhow!("execute {key}: {e:?}"))?
                    } else {
                        // buffer path: fresh inputs become device buffers; the
                        // cached positions reuse device-resident slots.
                        let total = inputs.len() + cached.len();
                        let fresh: Vec<xla::PjRtBuffer> = inputs
                            .iter()
                            .map(|t| {
                                if let Ok(v) = t.as_f32() {
                                    client
                                        .buffer_from_host_buffer(v, &t.shape, None)
                                        .map_err(|e| anyhow!("h2d {key}: {e:?}"))
                                } else {
                                    client
                                        .buffer_from_host_buffer(t.as_i32()?, &t.shape, None)
                                        .map_err(|e| anyhow!("h2d {key}: {e:?}"))
                                }
                            })
                            .collect::<Result<_>>()?;
                        let mut ordered: Vec<Option<&xla::PjRtBuffer>> = vec![None; total];
                        for (pos, slot) in &cached {
                            let buf = slots.get(slot).ok_or_else(|| {
                                anyhow!("core {core_id}: cache slot {slot:?} empty")
                            })?;
                            ordered[*pos] = Some(buf);
                        }
                        let mut it = fresh.iter();
                        for o in ordered.iter_mut() {
                            if o.is_none() {
                                *o = Some(it.next().expect("fresh input count"));
                            }
                        }
                        let args: Vec<&xla::PjRtBuffer> =
                            ordered.into_iter().map(|o| o.unwrap()).collect();
                        exe.execute_b(&args)
                            .map_err(|e| anyhow!("execute_b {key}: {e:?}"))?
                    };
                    let buf = out
                        .pop()
                        .and_then(|mut reps| reps.pop())
                        .ok_or_else(|| anyhow!("execute {key}: empty result"))?;
                    let lit = buf
                        .to_literal_sync()
                        .map_err(|e| anyhow!("d2h {key}: {e:?}"))?;
                    let parts = lit
                        .to_tuple()
                        .map_err(|e| anyhow!("untuple {key}: {e:?}"))?;
                    parts.iter().map(HostTensor::from_literal).collect()
                })();
                stats
                    .busy_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                stats.executions.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(res);
            }
        }
    }
}
