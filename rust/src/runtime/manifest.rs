//! Artifact manifest: what `python -m compile.aot` produced.
//!
//! The manifest is the contract between the build path (L1/L2 python) and
//! the runtime (L3 rust): program files, input/output specs, and per-agent
//! metadata (flat parameter sizes, observation geometry, trajectory shapes).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String, // "f32" | "i32" | "u32"
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.req("name")?.as_str().ok_or_else(|| anyhow!("name"))?.to_string(),
            dtype: j.req("dtype")?.as_str().ok_or_else(|| anyhow!("dtype"))?.to_string(),
            shape: j.req("shape")?.as_usize_vec()?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Per-agent metadata (see aot.py `ex.agents[...]`).
#[derive(Clone, Debug)]
pub struct AgentMeta {
    pub name: String,
    pub kind: String, // "sebulba" | "anakin" | "muzero"
    pub param_size: usize,
    pub opt_size: usize,
    pub obs_shape: Vec<usize>,
    pub num_actions: usize,
    pub raw: Json,
}

impl AgentMeta {
    pub fn obs_numel(&self) -> usize {
        self.obs_shape.iter().product()
    }

    /// Extra integer field from the raw metadata (e.g. "batch", "unroll").
    pub fn extra_usize(&self, key: &str) -> Result<usize> {
        self.raw
            .req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("agent {}: {key} not an integer", self.name))
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub programs: BTreeMap<String, ProgramSpec>,
    pub agents: BTreeMap<String, AgentMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut programs = BTreeMap::new();
        for (name, pj) in j.req("programs")?.as_obj().ok_or_else(|| anyhow!("programs"))? {
            let file = dir.join(
                pj.req("file")?.as_str().ok_or_else(|| anyhow!("file"))?,
            );
            let inputs = pj
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = pj
                .req("outputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("outputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            programs.insert(
                name.clone(),
                ProgramSpec { name: name.clone(), file, inputs, outputs },
            );
        }
        let mut agents = BTreeMap::new();
        for (name, aj) in j.req("agents")?.as_obj().ok_or_else(|| anyhow!("agents"))? {
            agents.insert(
                name.clone(),
                AgentMeta {
                    name: name.clone(),
                    kind: aj.req("kind")?.as_str().unwrap_or("").to_string(),
                    param_size: aj
                        .req("param_size")?
                        .as_usize()
                        .ok_or_else(|| anyhow!("param_size"))?,
                    opt_size: aj
                        .req("opt_size")?
                        .as_usize()
                        .ok_or_else(|| anyhow!("opt_size"))?,
                    obs_shape: aj.req("obs_shape")?.as_usize_vec()?,
                    num_actions: aj
                        .req("num_actions")?
                        .as_usize()
                        .ok_or_else(|| anyhow!("num_actions"))?,
                    raw: aj.clone(),
                },
            );
        }
        Ok(Self { dir: dir.to_path_buf(), programs, agents })
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow!("program {name:?} not in manifest (have: {:?})",
                self.programs.keys().take(8).collect::<Vec<_>>()))
    }

    pub fn agent(&self, name: &str) -> Result<&AgentMeta> {
        self.agents
            .get(name)
            .ok_or_else(|| anyhow!("agent {name:?} not in manifest"))
    }

    /// Validate a set of host tensors against a program's input specs.
    pub fn check_inputs(
        &self,
        program: &str,
        inputs: &[crate::runtime::tensor::HostTensor],
    ) -> Result<()> {
        let spec = self.program(program)?;
        if spec.inputs.len() != inputs.len() {
            bail!(
                "{program}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (s, t) in spec.inputs.iter().zip(inputs) {
            if s.shape != t.shape {
                bail!("{program}: input {:?} shape {:?} != {:?}", s.name, s.shape, t.shape);
            }
            if s.dtype != t.dtype_name() {
                bail!("{program}: input {:?} dtype {} != {}", s.name, s.dtype, t.dtype_name());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "programs": {
        "toy_infer": {
          "file": "toy_infer.hlo.txt",
          "inputs": [
            {"name": "params", "dtype": "f32", "shape": [10]},
            {"name": "obs", "dtype": "f32", "shape": [4, 5]},
            {"name": "seed", "dtype": "i32", "shape": []}
          ],
          "outputs": [{"name": "out0", "dtype": "i32", "shape": [4]}]
        }
      },
      "agents": {
        "toy": {"kind": "sebulba", "param_size": 10, "opt_size": 10,
                 "obs_shape": [5], "num_actions": 3, "batch": 4}
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let p = m.program("toy_infer").unwrap();
        assert_eq!(p.inputs.len(), 3);
        assert_eq!(p.inputs[1].shape, vec![4, 5]);
        assert_eq!(p.inputs[1].numel(), 20);
        assert_eq!(p.file, Path::new("/tmp/a/toy_infer.hlo.txt"));
        let a = m.agent("toy").unwrap();
        assert_eq!(a.param_size, 10);
        assert_eq!(a.extra_usize("batch").unwrap(), 4);
        assert!(a.extra_usize("nope").is_err());
    }

    #[test]
    fn missing_program_is_error() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert!(m.program("nope").is_err());
    }

    #[test]
    fn check_inputs_validates() {
        use crate::runtime::tensor::HostTensor;
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let good = vec![
            HostTensor::zeros_f32(vec![10]),
            HostTensor::zeros_f32(vec![4, 5]),
            HostTensor::scalar_i32(1),
        ];
        m.check_inputs("toy_infer", &good).unwrap();
        let bad_shape = vec![
            HostTensor::zeros_f32(vec![10]),
            HostTensor::zeros_f32(vec![4, 6]),
            HostTensor::scalar_i32(1),
        ];
        assert!(m.check_inputs("toy_infer", &bad_shape).is_err());
        let bad_dtype = vec![
            HostTensor::zeros_f32(vec![10]),
            HostTensor::zeros_f32(vec![4, 5]),
            HostTensor::scalar_f32(1.0),
        ];
        assert!(m.check_inputs("toy_infer", &bad_dtype).is_err());
        assert!(m.check_inputs("toy_infer", &good[..2]).is_err());
    }

    #[test]
    fn bad_manifest_is_error() {
        assert!(Manifest::parse(Path::new("/"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/"), "not json").is_err());
    }
}
