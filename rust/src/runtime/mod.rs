//! Runtime: the simulated TPU pod (PJRT CPU clients on dedicated threads),
//! host tensors, and the artifact manifest. See DESIGN.md §1 for how this
//! maps onto the paper's TPU topology.

pub mod device;
pub mod manifest;
pub mod pod;
pub mod tensor;

pub use device::{DeviceCore, DeviceHandle};
pub use manifest::{AgentMeta, Manifest, ProgramSpec, TensorSpec};
pub use pod::Pod;
pub use tensor::{Data, HostTensor};
