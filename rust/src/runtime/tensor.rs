//! Host-side tensors crossing the Rust <-> XLA boundary.
//!
//! `HostTensor` is the only currency between the coordinator and the device
//! cores: a shape plus f32 or i32 data (the two dtypes the exported programs
//! use). Conversion to/from `xla::Literal` happens on the device-core thread
//! (the "host->device transfer" of the simulated TPU).

use anyhow::{anyhow, bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Self { shape, data: Data::F32(data) })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Self { shape, data: Data::I32(data) })
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self { shape: vec![], data: Data::I32(vec![v]) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self { shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: Data::F32(vec![0.0; n]) }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match &self.data {
            Data::F32(_) => "f32",
            Data::I32(_) => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("expected f32 tensor, got {}", self.dtype_name())),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => Err(anyhow!("expected f32 tensor, got i32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => Err(anyhow!("expected i32 tensor, got {}", self.dtype_name())),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("expected f32 tensor")),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self.data {
            Data::I32(v) => Ok(v),
            _ => Err(anyhow!("expected i32 tensor")),
        }
    }

    /// Scalar f32 value (shape []).
    pub fn scalar_value_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, shape {:?}", self.shape);
        }
        Ok(v[0])
    }

    // -- Literal marshalling (called on device-core threads only) ---------

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            Data::F32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims).context("reshape f32 literal")?
                }
            }
            Data::I32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims).context("reshape i32 literal")?
                }
            }
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Self { shape: dims, data: Data::F32(lit.to_vec()?) }),
            xla::ElementType::S32 => Ok(Self { shape: dims, data: Data::I32(lit.to_vec()?) }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
    }

    #[test]
    fn dtype_accessors() {
        let t = HostTensor::i32(vec![2], vec![1, 2]).unwrap();
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
        assert_eq!(t.dtype_name(), "i32");
    }

    #[test]
    fn scalar_value() {
        let t = HostTensor::scalar_f32(4.5);
        assert_eq!(t.scalar_value_f32().unwrap(), 4.5);
        let bad = HostTensor::f32(vec![2], vec![1.0, 2.0]).unwrap();
        assert!(bad.scalar_value_f32().is_err());
    }

    #[test]
    fn zeros_helper() {
        let t = HostTensor::zeros_f32(vec![3, 4]);
        assert_eq!(t.len(), 12);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }
}
