//! Host-side tensors crossing the Rust <-> XLA boundary.
//!
//! `HostTensor` is the only currency between the coordinator and the device
//! cores: a shape plus f32 or i32 data (the two dtypes the exported programs
//! use). Conversion to/from `xla::Literal` happens on the device-core thread
//! (the "host->device transfer" of the simulated TPU).
//!
//! Storage comes in two forms (§Perf L3-2, DESIGN.md §11): `Owned` vectors
//! (program outputs, scratch) and `Shared` views — an `Arc`'d buffer plus an
//! offset — so trajectory-arena shards and parameter snapshots flow to the
//! device without ever being copied on the host. The two compare equal when
//! their logical contents match; consumers that only read go through
//! `as_f32`/`as_i32` and never see the difference.

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// Zero-copy view into an `Arc`-shared f32 buffer (trajectory arena
    /// column, parameter snapshot): `buf[offset .. offset + len]`.
    F32Shared { buf: Arc<Vec<f32>>, offset: usize, len: usize },
    /// Zero-copy view into an `Arc`-shared i32 buffer (arena actions).
    I32Shared { buf: Arc<Vec<i32>>, offset: usize, len: usize },
}

impl Data {
    fn f32_view(&self) -> Option<&[f32]> {
        match self {
            Data::F32(v) => Some(v),
            Data::F32Shared { buf, offset, len } => Some(&buf[*offset..*offset + *len]),
            _ => None,
        }
    }

    fn i32_view(&self) -> Option<&[i32]> {
        match self {
            Data::I32(v) => Some(v),
            Data::I32Shared { buf, offset, len } => Some(&buf[*offset..*offset + *len]),
            _ => None,
        }
    }
}

/// Logical equality: same dtype and same contents, regardless of whether
/// the storage is owned or a shared view.
impl PartialEq for Data {
    fn eq(&self, other: &Self) -> bool {
        match (self.f32_view(), other.f32_view()) {
            (Some(a), Some(b)) => return a == b,
            (None, None) => {}
            _ => return false,
        }
        match (self.i32_view(), other.i32_view()) {
            (Some(a), Some(b)) => a == b,
            _ => false,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Self { shape, data: Data::F32(data) })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Self { shape, data: Data::I32(data) })
    }

    /// Zero-copy tensor over `buf[offset .. offset + shape.product()]`.
    /// The buffer is `Arc`-shared: cloning the tensor, queueing it, or
    /// moving it to a device-core thread never copies the data.
    pub fn f32_shared(shape: Vec<usize>, buf: Arc<Vec<f32>>, offset: usize) -> Result<Self> {
        let n: usize = shape.iter().product();
        if offset + n > buf.len() {
            bail!(
                "shape {shape:?} wants {n} elements at offset {offset}, buffer has {}",
                buf.len()
            );
        }
        Ok(Self { shape, data: Data::F32Shared { buf, offset, len: n } })
    }

    /// Zero-copy i32 tensor over a shared buffer (see [`Self::f32_shared`]).
    pub fn i32_shared(shape: Vec<usize>, buf: Arc<Vec<i32>>, offset: usize) -> Result<Self> {
        let n: usize = shape.iter().product();
        if offset + n > buf.len() {
            bail!(
                "shape {shape:?} wants {n} elements at offset {offset}, buffer has {}",
                buf.len()
            );
        }
        Ok(Self { shape, data: Data::I32Shared { buf, offset, len: n } })
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self { shape: vec![], data: Data::I32(vec![v]) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self { shape: vec![], data: Data::F32(vec![v]) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: Data::F32(vec![0.0; n]) }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::F32Shared { len, .. } | Data::I32Shared { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match &self.data {
            Data::F32(_) | Data::F32Shared { .. } => "f32",
            Data::I32(_) | Data::I32Shared { .. } => "i32",
        }
    }

    /// True when the storage is a shared view (no owned buffer).
    pub fn is_shared(&self) -> bool {
        matches!(self.data, Data::F32Shared { .. } | Data::I32Shared { .. })
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        self.data
            .f32_view()
            .ok_or_else(|| anyhow!("expected f32 tensor, got {}", self.dtype_name()))
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            // Copy-on-write: writers of a shared view get a private buffer
            // when other holders exist (rare; no caller does this today).
            Data::F32Shared { buf, offset, len } => {
                Ok(&mut Arc::make_mut(buf)[*offset..*offset + *len])
            }
            _ => Err(anyhow!("expected f32 tensor, got i32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        self.data
            .i32_view()
            .ok_or_else(|| anyhow!("expected i32 tensor, got {}", self.dtype_name()))
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            Data::F32(v) => Ok(v),
            Data::F32Shared { buf, offset, len } => {
                if offset == 0 && len == buf.len() {
                    // Sole holder: reclaim the buffer without a copy.
                    Ok(Arc::try_unwrap(buf).unwrap_or_else(|arc| (*arc).clone()))
                } else {
                    Ok(buf[offset..offset + len].to_vec())
                }
            }
            _ => Err(anyhow!("expected f32 tensor")),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self.data {
            Data::I32(v) => Ok(v),
            Data::I32Shared { buf, offset, len } => {
                if offset == 0 && len == buf.len() {
                    Ok(Arc::try_unwrap(buf).unwrap_or_else(|arc| (*arc).clone()))
                } else {
                    Ok(buf[offset..offset + len].to_vec())
                }
            }
            _ => Err(anyhow!("expected i32 tensor")),
        }
    }

    /// Scalar f32 value (shape []).
    pub fn scalar_value_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, shape {:?}", self.shape);
        }
        Ok(v[0])
    }

    // -- Literal marshalling (called on device-core threads only) ---------

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = if let Some(v) = self.data.f32_view() {
            if self.shape.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(v).reshape(&dims).context("reshape f32 literal")?
            }
        } else {
            let v = self.as_i32()?;
            if self.shape.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(v).reshape(&dims).context("reshape i32 literal")?
            }
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Self { shape: dims, data: Data::F32(lit.to_vec()?) }),
            xla::ElementType::S32 => Ok(Self { shape: dims, data: Data::I32(lit.to_vec()?) }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
    }

    #[test]
    fn dtype_accessors() {
        let t = HostTensor::i32(vec![2], vec![1, 2]).unwrap();
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
        assert_eq!(t.dtype_name(), "i32");
    }

    #[test]
    fn scalar_value() {
        let t = HostTensor::scalar_f32(4.5);
        assert_eq!(t.scalar_value_f32().unwrap(), 4.5);
        let bad = HostTensor::f32(vec![2], vec![1.0, 2.0]).unwrap();
        assert!(bad.scalar_value_f32().is_err());
    }

    #[test]
    fn zeros_helper() {
        let t = HostTensor::zeros_f32(vec![3, 4]);
        assert_eq!(t.len(), 12);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn shared_view_is_a_window_not_a_copy() {
        let buf = Arc::new((0..12).map(|i| i as f32).collect::<Vec<f32>>());
        let t = HostTensor::f32_shared(vec![2, 3], buf.clone(), 6).unwrap();
        assert_eq!(t.len(), 6);
        assert!(t.is_shared());
        let view = t.as_f32().unwrap();
        assert_eq!(view, &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        // pointer identity: the view aliases the shared buffer
        assert!(std::ptr::eq(view.as_ptr(), buf[6..].as_ptr()));
    }

    #[test]
    fn shared_view_bounds_checked() {
        let buf = Arc::new(vec![0.0f32; 8]);
        assert!(HostTensor::f32_shared(vec![3, 3], buf.clone(), 0).is_err());
        assert!(HostTensor::f32_shared(vec![2, 2], buf.clone(), 5).is_err());
        assert!(HostTensor::f32_shared(vec![2, 2], buf, 4).is_ok());
    }

    #[test]
    fn shared_and_owned_compare_by_contents() {
        let owned = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let buf = Arc::new(vec![0.0, 1.0, 2.0, 3.0]);
        let shared = HostTensor::f32_shared(vec![3], buf, 1).unwrap();
        assert_eq!(owned, shared);
        let other = HostTensor::f32(vec![3], vec![1.0, 2.0, 4.0]).unwrap();
        assert_ne!(shared, other);
        // dtype mismatch is never equal
        let ints = HostTensor::i32(vec![3], vec![1, 2, 3]).unwrap();
        assert_ne!(owned, ints);
    }

    #[test]
    fn into_f32_reclaims_unique_shared_buffer() {
        let buf = Arc::new(vec![5.0f32; 4]);
        let ptr = buf.as_ptr();
        let t = HostTensor::f32_shared(vec![4], buf, 0).unwrap();
        let v = t.into_f32().unwrap();
        // sole holder: the Vec comes back without a copy
        assert!(std::ptr::eq(v.as_ptr(), ptr));

        // window view: materializes just the window
        let buf = Arc::new((0..6).collect::<Vec<i32>>());
        let t = HostTensor::i32_shared(vec![2], buf.clone(), 2).unwrap();
        assert_eq!(t.into_i32().unwrap(), vec![2, 3]);
        assert_eq!(buf.len(), 6); // original untouched
    }

    #[test]
    fn shared_i32_roundtrip() {
        let buf = Arc::new(vec![7, 8, 9]);
        let t = HostTensor::i32_shared(vec![3], buf, 0).unwrap();
        assert_eq!(t.as_i32().unwrap(), &[7, 8, 9]);
        assert!(t.as_f32().is_err());
        assert_eq!(t.dtype_name(), "i32");
    }
}
