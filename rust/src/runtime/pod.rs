//! A simulated TPU pod slice: N device cores + the artifact manifest.
//!
//! `Pod::new(artifacts_dir, n_cores)` spawns the core threads;
//! `load_program(keys, cores)` compiles a program onto a set of cores in
//! parallel (each core owns its own client, so compilation is concurrent —
//! just like per-device program loading on a real pod).

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{anyhow, Result};

use super::device::{DeviceCore, DeviceHandle};
use super::manifest::Manifest;

pub struct Pod {
    pub manifest: Manifest,
    cores: Vec<DeviceCore>,
    loaded: BTreeSet<(usize, String)>,
}

impl Pod {
    pub fn new(artifacts_dir: &Path, n_cores: usize) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let mut cores = Vec::with_capacity(n_cores);
        for i in 0..n_cores {
            cores.push(DeviceCore::spawn(i)?);
        }
        Ok(Self { manifest, cores, loaded: BTreeSet::new() })
    }

    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    pub fn core(&self, i: usize) -> Result<DeviceHandle> {
        self.cores
            .get(i)
            .map(|c| c.handle.clone())
            .ok_or_else(|| anyhow!("core {i} out of range ({} cores)", self.cores.len()))
    }

    pub fn cores(&self) -> Vec<DeviceHandle> {
        self.cores.iter().map(|c| c.handle.clone()).collect()
    }

    /// Handles for a specific set of cores, in the given order — what a
    /// driver hands to per-replica threads (the threaded Anakin driver
    /// gives each replica thread its core this way).
    pub fn handles_for(&self, core_ids: &[usize]) -> Result<Vec<DeviceHandle>> {
        core_ids.iter().map(|&i| self.core(i)).collect()
    }

    /// Compile `program` (manifest name) onto the given cores, in parallel.
    pub fn load_program(&mut self, program: &str, core_ids: &[usize]) -> Result<()> {
        let spec = self.manifest.program(program)?.clone();
        let mut waits = Vec::new();
        for &cid in core_ids {
            if self.loaded.contains(&(cid, program.to_string())) {
                continue;
            }
            let handle = self.core(cid)?;
            waits.push((cid, handle.compile_async(program, spec.file.clone())?));
        }
        for (cid, rx) in waits {
            rx.recv()
                .map_err(|_| anyhow!("core {cid} died compiling {program}"))??;
            self.loaded.insert((cid, program.to_string()));
        }
        log::debug!("loaded {program} on cores {core_ids:?}");
        Ok(())
    }

    /// Compile several programs onto the same set of cores.
    pub fn load_programs(&mut self, programs: &[&str], core_ids: &[usize]) -> Result<()> {
        // Issue all compiles first (they queue per-core and run concurrently
        // across cores), then join.
        let mut waits = Vec::new();
        for &program in programs {
            let spec = self.manifest.program(program)?.clone();
            for &cid in core_ids {
                if self.loaded.contains(&(cid, program.to_string())) {
                    continue;
                }
                let handle = self.core(cid)?;
                waits.push((cid, program.to_string(), handle.compile_async(program, spec.file.clone())?));
            }
        }
        for (cid, program, rx) in waits {
            rx.recv()
                .map_err(|_| anyhow!("core {cid} died compiling {program}"))??;
            self.loaded.insert((cid, program));
        }
        Ok(())
    }

    /// Validated execute: checks inputs against the manifest spec first.
    /// The hot paths skip this and call `DeviceHandle::execute` directly.
    pub fn execute_checked(
        &self,
        core_id: usize,
        program: &str,
        inputs: Vec<super::tensor::HostTensor>,
    ) -> Result<Vec<super::tensor::HostTensor>> {
        self.manifest.check_inputs(program, &inputs)?;
        self.core(core_id)?.execute(program, inputs)
    }
}
