//! # Podracer — scalable RL architectures (Anakin & Sebulba) in Rust + JAX
//!
//! A reproduction of *"Podracer architectures for scalable Reinforcement
//! Learning"* (Hessel et al., DeepMind 2021) as a three-layer system:
//!
//! * **L1** — Pallas kernels (V-trace / GAE / λ-returns) compiled at build
//!   time (`python/compile/kernels/`).
//! * **L2** — JAX programs (networks, losses, the Anakin on-device loop, the
//!   Sebulba inference/grad/apply programs), AOT-lowered to HLO text in
//!   `artifacts/` (`python/compile/aot.py`).
//! * **L3** — this crate: the coordination architectures themselves. Python
//!   never runs on the request path; the binary consumes `artifacts/` only.
//!
//! ## Layout
//!
//! * [`experiment`] — **the public API**: `Experiment` builder, typed
//!   `Topology`/`EnvKind`, the `Runner` trait and the unified `Report`
//!   (DESIGN.md §12). Start here.
//! * [`runtime`] — the simulated TPU pod: device cores (threads owning PJRT
//!   CPU clients), host tensors, the artifact manifest.
//! * [`envs`] — host-side environments (Catch, GridWorld, CartPole, Chain,
//!   the Atari-like pixel game) + the batched environment / worker pool.
//! * [`coordinator`] — **Sebulba**: actor threads, learner thread, trajectory
//!   queues, gradient collective, parameter store, replicas.
//! * [`anakin`] — **Anakin**: the replicated on-device loop driver.
//! * [`serve`] — policy serving: live client sessions fed through the
//!   actor's infer loop via the `BatchSource` seam, with continuous
//!   batching and hot parameter swaps (DESIGN.md §14).
//! * [`search`] — MCTS for the MuZero-style search agent.
//! * [`checkpoint`] — elastic-pod checkpoint/restore: the versioned,
//!   CRC'd on-disk snapshot format and its typed errors (DESIGN.md §13).
//! * [`transport`] — the multi-pod seam: `Transport`/`Connection` traits,
//!   the CRC-framed wire format, TCP + loopback pipes, and the
//!   `DistSebulba` learner-pod/actor-pod runner (DESIGN.md §15).
//! * [`plan`] — the cost-model-driven topology planner: measured per-stage
//!   costs in, ranked feasible topologies out (`Topology::auto`,
//!   `podracer plan` — DESIGN.md §17).
//! * [`league`] — round-robin self-play league: concurrent experiments
//!   scheduled over shared pods with deterministic per-match seeds.
//! * [`benchkit`] / [`testkit`] — bench harness and property-test support.
//!
//! ## Quickstart
//!
//! ```bash
//! make artifacts                      # python: AOT-lower the XLA programs
//! cargo run --release --example quickstart
//! ```
//!
//! ```no_run
//! use podracer::experiment::{Arch, EnvKind, Experiment, Topology};
//!
//! let report = Experiment::new(Arch::Sebulba)
//!     .env(EnvKind::Catch)
//!     .topology(Topology::split(2, 2))
//!     .updates(200)
//!     .build()?
//!     .run()?;
//! println!("{}", report.summary());
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod anakin;
pub mod benchkit;
pub mod checkpoint;
pub mod coordinator;
pub mod envs;
pub mod experiment;
pub mod league;
pub mod plan;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod testkit;
pub mod transport;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";

/// Resolve the artifacts directory: `$PODRACER_ARTIFACTS`, or walk up from
/// the current directory looking for `artifacts/manifest.json`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PODRACER_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(DEFAULT_ARTIFACTS);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return DEFAULT_ARTIFACTS.into();
        }
    }
}
