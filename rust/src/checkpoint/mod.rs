//! Checkpoint/restore: elastic-pod state snapshots with a bit-identical
//! continuation contract (DESIGN.md §13).
//!
//! A [`Checkpoint`] is a named-section container written atomically
//! (temp file + rename, so teardown mid-write never leaves a partial file
//! behind) in the binary format of [`format`]. Each architecture stores
//! its resume state as typed sections:
//!
//! * all archs — [`MetaSection`]: agent, seed, env kind, rounds done.
//! * Sebulba / MuZero — [`StoreSection`] (ParamStore params + optimiser
//!   state + published version) and [`ActorSection`] (actor RNG, window
//!   counter, boundary observation, per-env serialized state).
//! * Anakin — [`StoreSection`] (per-core params/opt are identical after
//!   every collective, so the model is stored once; `version` carries the
//!   outer-iteration count) plus one [`CoreEnvSection`] per core for the
//!   in-graph environment state.
//!
//! The restore contract: run K updates → checkpoint → restore in a fresh
//! process → run K more ≡ an uninterrupted 2K run, bit-identical in
//! `final_params` (`rust/tests/restore_equivalence.rs`). Corrupt or
//! mismatched files are typed [`CheckpointError`]s — never a panic, never
//! a silent fresh start.

pub mod format;

use std::path::{Path, PathBuf};

pub use format::{CheckpointError, SectionReader, SectionWriter};

use crate::experiment::{Arch, Topology};

/// Wire tag for each architecture (0 is reserved so an all-zero header
/// never decodes as a valid arch).
fn arch_tag(arch: Arch) -> u32 {
    match arch {
        Arch::Anakin => 1,
        Arch::Sebulba => 2,
        Arch::MuZero => 3,
    }
}

fn arch_from_tag(tag: u32) -> Option<Arch> {
    match tag {
        1 => Some(Arch::Anakin),
        2 => Some(Arch::Sebulba),
        3 => Some(Arch::MuZero),
        _ => None,
    }
}

/// When and where a run writes checkpoints.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckpointSpec {
    /// Write after every `every` learner updates / outer iterations.
    pub every: u64,
    /// Target file; each write replaces it atomically.
    pub path: PathBuf,
}

impl CheckpointSpec {
    pub fn new(every: u64, path: impl Into<PathBuf>) -> Self {
        Self { every: every.max(1), path: path.into() }
    }

    /// Is a checkpoint due after completing `rounds_done` updates?
    pub fn due(&self, rounds_done: u64) -> bool {
        rounds_done > 0 && rounds_done % self.every == 0
    }
}

/// A named-section snapshot of one run's resumable state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub arch: Arch,
    pub topology_fingerprint: u64,
    /// Insertion-ordered (name, payload) pairs; names are unique.
    sections: Vec<(String, Vec<u8>)>,
}

impl Checkpoint {
    pub fn new(arch: Arch, topo: &Topology) -> Self {
        Self { arch, topology_fingerprint: topo.fingerprint(), sections: Vec::new() }
    }

    /// Insert (or replace) a section.
    pub fn insert(&mut self, name: &str, payload: Vec<u8>) {
        if let Some(slot) = self.sections.iter_mut().find(|(n, _)| n == name) {
            slot.1 = payload;
        } else {
            self.sections.push((name.to_string(), payload));
        }
    }

    /// A required section's payload; absence is a typed error.
    pub fn section(&self, name: &str) -> Result<&[u8], CheckpointError> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p.as_slice())
            .ok_or_else(|| CheckpointError::MissingSection { section: name.to_string() })
    }

    pub fn has_section(&self, name: &str) -> bool {
        self.sections.iter().any(|(n, _)| n == name)
    }

    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.iter().map(|(n, _)| n.as_str())
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        format::encode_file(arch_tag(self.arch), self.topology_fingerprint, &self.sections)
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self, CheckpointError> {
        let (tag, topo, sections) = format::decode_file(bytes)?;
        let arch = arch_from_tag(tag).ok_or(CheckpointError::Corrupt {
            section: "<header>".into(),
            detail: format!("unknown arch tag {tag}"),
        })?;
        Ok(Self { arch, topology_fingerprint: topo, sections })
    }

    /// Write atomically: serialize to `<path>.tmp`, fsync, rename over
    /// `path`. A crash or teardown mid-write leaves either the previous
    /// complete checkpoint or a stray `.tmp` — never a partial file at
    /// `path` (pinned by `rust/tests/fault_injection.rs`).
    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        let tmp = tmp_path(path);
        let write = || -> Result<(), CheckpointError> {
            let mut f = std::fs::File::create(&tmp)?;
            std::io::Write::write_all(&mut f, &self.to_bytes())?;
            f.sync_all()?;
            drop(f);
            std::fs::rename(&tmp, path)?;
            Ok(())
        };
        let out = write();
        if out.is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
        out
    }

    /// Read and structurally validate (magic, version, CRCs) — semantic
    /// checks against the restoring run are [`Checkpoint::verify`].
    pub fn load(path: &Path) -> Result<Self, CheckpointError> {
        let bytes = std::fs::read(path)?;
        Self::from_bytes(&bytes)
    }

    /// Semantic validation: the checkpoint must come from the same
    /// architecture and an identical topology.
    pub fn verify(&self, arch: Arch, topo: &Topology) -> Result<(), CheckpointError> {
        if self.arch != arch {
            return Err(CheckpointError::ArchMismatch {
                found: self.arch.to_string(),
                expected: arch.to_string(),
            });
        }
        let expected = topo.fingerprint();
        if self.topology_fingerprint != expected {
            return Err(CheckpointError::TopologyMismatch {
                found: self.topology_fingerprint,
                expected,
            });
        }
        Ok(())
    }

    /// `load` + `verify` in one step — the restore entrypoint runners use.
    pub fn load_for(path: &Path, arch: Arch, topo: &Topology) -> Result<Self, CheckpointError> {
        let ckpt = Self::load(path)?;
        ckpt.verify(arch, topo)?;
        Ok(ckpt)
    }
}

/// The sibling temp file `save` stages into before the atomic rename.
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Check a workload field against the checkpointed value; a disagreement
/// is a typed error, never a silent override.
pub fn expect_field<T: PartialEq + std::fmt::Display>(
    field: &'static str,
    found: T,
    expected: T,
) -> Result<(), CheckpointError> {
    if found != expected {
        return Err(CheckpointError::Mismatch {
            field,
            found: found.to_string(),
            expected: expected.to_string(),
        });
    }
    Ok(())
}

// -- typed sections -----------------------------------------------------------

/// Workload identity every architecture stores: restoring into a different
/// agent/seed/env would continue a *different* run, so each is verified.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MetaSection {
    pub agent: String,
    pub seed: u64,
    /// CLI name of the host env kind; empty for Anakin (in-graph envs).
    pub env: String,
    /// Learner updates (Sebulba/MuZero) or outer iterations (Anakin)
    /// completed when the checkpoint was written.
    pub rounds_done: u64,
}

pub const META_SECTION: &str = "meta";

impl MetaSection {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = SectionWriter::new();
        w.put_str(&self.agent);
        w.put_u64(self.seed);
        w.put_str(&self.env);
        w.put_u64(self.rounds_done);
        w.finish()
    }

    pub fn decode(payload: &[u8]) -> Result<Self, CheckpointError> {
        let mut r = SectionReader::new(META_SECTION, payload);
        let out = Self {
            agent: r.str()?,
            seed: r.u64()?,
            env: r.str()?,
            rounds_done: r.u64()?,
        };
        r.done()?;
        Ok(out)
    }
}

/// ParamStore contents: model parameters, optimiser state, published
/// version. For Anakin the "store" is the replicated in-graph model
/// (identical on every core after each collective) and `version` echoes
/// `rounds_done`.
#[derive(Clone, Debug, PartialEq)]
pub struct StoreSection {
    pub params: Vec<f32>,
    pub opt: Vec<f32>,
    pub version: u64,
}

pub const STORE_SECTION: &str = "store";

impl StoreSection {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = SectionWriter::new();
        w.put_u64(self.version);
        w.put_f32s(&self.params);
        w.put_f32s(&self.opt);
        w.finish()
    }

    pub fn decode(payload: &[u8]) -> Result<Self, CheckpointError> {
        let mut r = SectionReader::new(STORE_SECTION, payload);
        let version = r.u64()?;
        let params = r.f32s()?;
        let opt = r.f32s()?;
        r.done()?;
        Ok(Self { params, opt, version })
    }
}

/// One actor thread's boundary state: everything the Sebulba/MuZero actor
/// needs to produce window `windows_done` exactly as the uninterrupted run
/// would have (DESIGN.md §13: the deposit-before-push protocol).
#[derive(Clone, Debug, PartialEq)]
pub struct ActorSection {
    /// Windows fully produced (== the store version the next window waits
    /// for under checkpointed lockstep pacing).
    pub windows_done: u64,
    /// Snapshot of the actor's `Xoshiro256` stream.
    pub rng: [u64; 4],
    /// The bootstrap observation of the last finished window — the first
    /// observation of the next one.
    pub obs: Vec<f32>,
    /// Running per-env episode returns (stats continuity).
    pub episode_reward: Vec<f32>,
    /// `Environment::save_state` bytes, one per env slot.
    pub env_states: Vec<Vec<u8>>,
}

pub const ACTOR_SECTION: &str = "actor0";

impl ActorSection {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = SectionWriter::new();
        w.put_u64(self.windows_done);
        w.put_u64s(&self.rng);
        w.put_f32s(&self.obs);
        w.put_f32s(&self.episode_reward);
        w.put_u64(self.env_states.len() as u64);
        for s in &self.env_states {
            w.put_blob(s);
        }
        w.finish()
    }

    pub fn decode(payload: &[u8]) -> Result<Self, CheckpointError> {
        let mut r = SectionReader::new(ACTOR_SECTION, payload);
        let windows_done = r.u64()?;
        let rng_vec = r.u64s()?;
        let rng: [u64; 4] = rng_vec.try_into().map_err(|_| CheckpointError::Corrupt {
            section: ACTOR_SECTION.into(),
            detail: "rng state is not 4 words".into(),
        })?;
        let obs = r.f32s()?;
        let episode_reward = r.f32s()?;
        let n = r.u64()? as usize;
        let mut env_states = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            env_states.push(r.blob()?);
        }
        r.done()?;
        Ok(Self { windows_done, rng, obs, episode_reward, env_states })
    }
}

/// One Anakin core's in-graph environment state (a host tensor: shape +
/// f32 data). Section name: [`core_env_section`]`(core)`.
#[derive(Clone, Debug, PartialEq)]
pub struct CoreEnvSection {
    pub shape: Vec<u64>,
    pub data: Vec<f32>,
}

pub fn core_env_section(core: usize) -> String {
    format!("env_core{core}")
}

impl CoreEnvSection {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = SectionWriter::new();
        w.put_u64s(&self.shape);
        w.put_f32s(&self.data);
        w.finish()
    }

    pub fn decode(section: &str, payload: &[u8]) -> Result<Self, CheckpointError> {
        let mut r = SectionReader::new(section, payload);
        let shape = r.u64s()?;
        let data = r.f32s()?;
        r.done()?;
        let want: u64 = shape.iter().product();
        if want != data.len() as u64 {
            return Err(CheckpointError::Corrupt {
                section: section.to_string(),
                detail: format!("shape {shape:?} wants {want} elements, payload has {}", data.len()),
            });
        }
        Ok(Self { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("podracer_ckpt_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample() -> Checkpoint {
        let topo = Topology::split(1, 1);
        let mut c = Checkpoint::new(Arch::Sebulba, &topo);
        c.insert(
            META_SECTION,
            MetaSection { agent: "seb_catch".into(), seed: 55, env: "catch".into(), rounds_done: 2 }
                .encode(),
        );
        c.insert(
            STORE_SECTION,
            StoreSection { params: vec![1.0, -2.5], opt: vec![0.0; 4], version: 2 }.encode(),
        );
        c.insert(
            ACTOR_SECTION,
            ActorSection {
                windows_done: 2,
                rng: [1, 2, 3, 4],
                obs: vec![0.5; 6],
                episode_reward: vec![0.0, 1.0],
                env_states: vec![vec![9, 9], vec![]],
            }
            .encode(),
        );
        c
    }

    #[test]
    fn save_load_roundtrip_is_lossless_and_leaves_no_tmp() {
        let dir = scratch_dir("roundtrip");
        let path = dir.join("run.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        assert!(!tmp_path(&path).exists(), "atomic save must not leave its temp file");
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, c);
        let meta = MetaSection::decode(back.section(META_SECTION).unwrap()).unwrap();
        assert_eq!(meta.agent, "seb_catch");
        let actor = ActorSection::decode(back.section(ACTOR_SECTION).unwrap()).unwrap();
        assert_eq!(actor.env_states, vec![vec![9, 9], vec![]]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_replaces_atomically() {
        let dir = scratch_dir("replace");
        let path = dir.join("run.ckpt");
        let mut c = sample();
        c.save(&path).unwrap();
        c.insert("extra", vec![1]);
        c.save(&path).unwrap();
        assert!(Checkpoint::load(&path).unwrap().has_section("extra"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_into_missing_dir_is_a_typed_io_error() {
        let dir = scratch_dir("missdir");
        let path = dir.join("nonexistent").join("run.ckpt");
        match sample().save(&path) {
            Err(CheckpointError::Io(_)) => {}
            other => panic!("expected Io error, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_rejects_wrong_arch_and_topology() {
        let c = sample();
        assert!(matches!(
            c.verify(Arch::MuZero, &Topology::split(1, 1)),
            Err(CheckpointError::ArchMismatch { .. })
        ));
        assert!(matches!(
            c.verify(Arch::Sebulba, &Topology::split(2, 1)),
            Err(CheckpointError::TopologyMismatch { .. })
        ));
        c.verify(Arch::Sebulba, &Topology::split(1, 1)).unwrap();
    }

    #[test]
    fn missing_section_is_typed() {
        let c = sample();
        assert!(matches!(
            c.section("replay"),
            Err(CheckpointError::MissingSection { .. })
        ));
    }

    #[test]
    fn insert_replaces_in_place() {
        let mut c = sample();
        let before: Vec<String> = c.section_names().map(str::to_string).collect();
        c.insert(STORE_SECTION, StoreSection { params: vec![9.0], opt: vec![], version: 7 }.encode());
        let after: Vec<String> = c.section_names().map(str::to_string).collect();
        assert_eq!(before, after, "replacing a section must not reorder");
        let s = StoreSection::decode(c.section(STORE_SECTION).unwrap()).unwrap();
        assert_eq!(s.version, 7);
    }

    #[test]
    fn expect_field_mismatch_is_typed() {
        expect_field("seed", 5u64, 5u64).unwrap();
        assert!(matches!(
            expect_field("agent", "a".to_string(), "b".to_string()),
            Err(CheckpointError::Mismatch { field: "agent", .. })
        ));
    }

    #[test]
    fn core_env_section_validates_geometry() {
        let s = CoreEnvSection { shape: vec![2, 3], data: vec![0.0; 6] };
        let back = CoreEnvSection::decode("env_core0", &s.encode()).unwrap();
        assert_eq!(back, s);
        let mut w = SectionWriter::new();
        w.put_u64s(&[2, 3]);
        w.put_f32s(&[0.0; 5]);
        assert!(matches!(
            CoreEnvSection::decode("env_core0", &w.finish()),
            Err(CheckpointError::Corrupt { .. })
        ));
    }
}
