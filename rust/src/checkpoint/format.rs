//! The on-disk checkpoint encoding: a length-prefixed binary container
//! with a fixed header and per-section CRCs (DESIGN.md §13).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic            8  b"PODRCKPT"
//! format_version   u32
//! arch_tag         u32   (1 = anakin, 2 = sebulba, 3 = muzero)
//! topology_hash    u64   (Topology::fingerprint of the writing run)
//! section_count    u32
//! per section:
//!   name_len       u32
//!   name           name_len bytes (utf-8)
//!   payload_len    u64
//!   payload        payload_len bytes
//!   crc32          u32   (IEEE, over name bytes ++ payload bytes)
//! ```
//!
//! Every decode failure is a typed [`CheckpointError`] — corruption must
//! never panic and must never silently load (ISSUE 6). The vendored set has
//! no serde/crc crates, so the CRC and the framing are hand-rolled here.

use std::fmt;

/// File magic: identifies a Podracer checkpoint regardless of version.
pub const MAGIC: [u8; 8] = *b"PODRCKPT";

/// Current (and only) container format version.
pub const FORMAT_VERSION: u32 = 1;

/// Everything that can go wrong reading or writing a checkpoint. Restore
/// code paths return these (wrapped in `anyhow` at the workload layer) —
/// never `unwrap`, never a silent fallback to fresh state.
#[derive(Debug)]
pub enum CheckpointError {
    /// Underlying filesystem failure (open/read/write/rename).
    Io(std::io::Error),
    /// The file ended before a length-prefixed field it promised.
    Truncated { context: &'static str },
    /// The first 8 bytes are not a Podracer checkpoint.
    BadMagic { found: [u8; 8] },
    /// A format this build does not read.
    UnsupportedVersion { found: u32, supported: u32 },
    /// The checkpoint was written by a different architecture.
    ArchMismatch { found: String, expected: String },
    /// The checkpoint was written under a different `Topology`.
    TopologyMismatch { found: u64, expected: u64 },
    /// A section's stored CRC does not match its payload.
    CrcMismatch { section: String, stored: u32, computed: u32 },
    /// A section the restore path requires is absent.
    MissingSection { section: String },
    /// A section decoded but its payload is malformed.
    Corrupt { section: String, detail: String },
    /// A workload field (agent, seed, env, ...) disagrees with the run
    /// being restored into.
    Mismatch { field: &'static str, found: String, expected: String },
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint io error: {e}"),
            CheckpointError::Truncated { context } => {
                write!(f, "checkpoint truncated while reading {context}")
            }
            CheckpointError::BadMagic { found } => {
                write!(f, "not a podracer checkpoint (magic {found:02x?})")
            }
            CheckpointError::UnsupportedVersion { found, supported } => write!(
                f,
                "checkpoint format version {found} unsupported (this build reads {supported})"
            ),
            CheckpointError::ArchMismatch { found, expected } => write!(
                f,
                "checkpoint was written by a {found} run, cannot restore a {expected} run"
            ),
            CheckpointError::TopologyMismatch { found, expected } => write!(
                f,
                "checkpoint topology hash {found:#018x} != this run's {expected:#018x}"
            ),
            CheckpointError::CrcMismatch { section, stored, computed } => write!(
                f,
                "checkpoint section {section:?} corrupt: crc stored {stored:#010x}, computed {computed:#010x}"
            ),
            CheckpointError::MissingSection { section } => {
                write!(f, "checkpoint is missing required section {section:?}")
            }
            CheckpointError::Corrupt { section, detail } => {
                write!(f, "checkpoint section {section:?} malformed: {detail}")
            }
            CheckpointError::Mismatch { field, found, expected } => write!(
                f,
                "checkpoint {field} mismatch: checkpoint has {found:?}, run expects {expected:?}"
            ),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

// -- CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) --------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC32 of `bytes` (the same polynomial zlib/ethernet use).
pub fn crc32(bytes: &[u8]) -> u32 {
    crc32_update(0xFFFF_FFFF, bytes) ^ 0xFFFF_FFFF
}

/// Streaming form: feed successive chunks into the running state (start
/// from `0xFFFF_FFFF`, finish by xoring with `0xFFFF_FFFF`).
pub fn crc32_update(state: u32, bytes: &[u8]) -> u32 {
    let mut c = state;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c
}

// -- primitive payload encoding ----------------------------------------------

/// Accumulates one section's payload. All slices are length-prefixed so the
/// reader never guesses geometry.
#[derive(Default)]
pub struct SectionWriter {
    buf: Vec<u8>,
}

impl SectionWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn put_blob(&mut self, bytes: &[u8]) {
        self.put_u64(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }

    pub fn put_u64s(&mut self, vs: &[u64]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_u64(v);
        }
    }

    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_f32(v);
        }
    }

    pub fn put_i32s(&mut self, vs: &[i32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Decodes one section's payload; every overrun or malformed field is a
/// typed [`CheckpointError::Corrupt`] carrying the section name.
pub struct SectionReader<'a> {
    section: &'a str,
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SectionReader<'a> {
    pub fn new(section: &'a str, buf: &'a [u8]) -> Self {
        Self { section, buf, pos: 0 }
    }

    fn corrupt(&self, detail: impl Into<String>) -> CheckpointError {
        CheckpointError::Corrupt { section: self.section.to_string(), detail: detail.into() }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CheckpointError> {
        if self.pos + n > self.buf.len() {
            return Err(self.corrupt(format!(
                "wanted {n} bytes at offset {}, payload has {}",
                self.pos,
                self.buf.len()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// A length prefix sanity-checked against the bytes actually left, so a
    /// corrupted count can't drive a huge allocation.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize, CheckpointError> {
        let n = self.u64()? as usize;
        let remaining = self.buf.len() - self.pos;
        if n.checked_mul(elem_bytes).map_or(true, |total| total > remaining) {
            return Err(self.corrupt(format!(
                "length prefix {n} x {elem_bytes}B exceeds the {remaining} bytes left"
            )));
        }
        Ok(n)
    }

    pub fn u32(&mut self) -> Result<u32, CheckpointError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, CheckpointError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f32(&mut self) -> Result<f32, CheckpointError> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn str(&mut self) -> Result<String, CheckpointError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| self.corrupt("string field is not utf-8"))
    }

    pub fn blob(&mut self) -> Result<Vec<u8>, CheckpointError> {
        let n = self.len_prefix(1)?;
        Ok(self.take(n)?.to_vec())
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>, CheckpointError> {
        let n = self.len_prefix(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, CheckpointError> {
        let n = self.len_prefix(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn i32s(&mut self) -> Result<Vec<i32>, CheckpointError> {
        let n = self.len_prefix(4)?;
        (0..n)
            .map(|_| {
                let b = self.take(4)?;
                Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            })
            .collect()
    }

    /// Assert the payload is fully consumed — trailing garbage is corruption,
    /// not something to ignore.
    pub fn done(&self) -> Result<(), CheckpointError> {
        if self.pos != self.buf.len() {
            return Err(self.corrupt(format!(
                "{} trailing bytes after the last field",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// -- file framing -------------------------------------------------------------

/// Serialize the container: header + CRC'd sections.
pub fn encode_file(arch_tag: u32, topology_hash: u64, sections: &[(String, Vec<u8>)]) -> Vec<u8> {
    let body: usize = sections.iter().map(|(n, p)| 4 + n.len() + 8 + p.len() + 4).sum();
    let mut out = Vec::with_capacity(8 + 4 + 4 + 8 + 4 + body);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&arch_tag.to_le_bytes());
    out.extend_from_slice(&topology_hash.to_le_bytes());
    out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for (name, payload) in sections {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(payload);
        let crc = crc32_update(crc32_update(0xFFFF_FFFF, name.as_bytes()), payload) ^ 0xFFFF_FFFF;
        out.extend_from_slice(&crc.to_le_bytes());
    }
    out
}

struct FileReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> FileReader<'a> {
    fn take(&mut self, n: usize, context: &'static str) -> Result<&'a [u8], CheckpointError> {
        if self.pos + n > self.buf.len() {
            return Err(CheckpointError::Truncated { context });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self, context: &'static str) -> Result<u32, CheckpointError> {
        let b = self.take(4, context)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, context: &'static str) -> Result<u64, CheckpointError> {
        let b = self.take(8, context)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

/// Decode the container, verifying magic, format version and every
/// section CRC. Arch/topology are returned raw — semantic verification
/// against the restoring run happens in [`super::Checkpoint::verify`].
#[allow(clippy::type_complexity)]
pub fn decode_file(
    bytes: &[u8],
) -> Result<(u32, u64, Vec<(String, Vec<u8>)>), CheckpointError> {
    let mut r = FileReader { buf: bytes, pos: 0 };
    let magic = r.take(8, "magic")?;
    if magic != MAGIC {
        let mut found = [0u8; 8];
        found.copy_from_slice(magic);
        return Err(CheckpointError::BadMagic { found });
    }
    let version = r.u32("format version")?;
    if version != FORMAT_VERSION {
        return Err(CheckpointError::UnsupportedVersion {
            found: version,
            supported: FORMAT_VERSION,
        });
    }
    let arch_tag = r.u32("arch tag")?;
    let topology_hash = r.u64("topology hash")?;
    let count = r.u32("section count")? as usize;
    let mut sections = Vec::with_capacity(count.min(64));
    for _ in 0..count {
        let name_len = r.u32("section name length")? as usize;
        let name_bytes = r.take(name_len, "section name")?;
        let name = String::from_utf8(name_bytes.to_vec()).map_err(|_| {
            CheckpointError::Corrupt {
                section: String::from_utf8_lossy(name_bytes).into_owned(),
                detail: "section name is not utf-8".into(),
            }
        })?;
        let payload_len = r.u64("section payload length")? as usize;
        let payload = r.take(payload_len, "section payload")?.to_vec();
        let stored = r.u32("section crc")?;
        let computed =
            crc32_update(crc32_update(0xFFFF_FFFF, name.as_bytes()), &payload) ^ 0xFFFF_FFFF;
        if stored != computed {
            return Err(CheckpointError::CrcMismatch { section: name, stored, computed });
        }
        sections.push((name, payload));
    }
    if r.pos != bytes.len() {
        return Err(CheckpointError::Corrupt {
            section: "<file>".into(),
            detail: format!("{} trailing bytes after the last section", bytes.len() - r.pos),
        });
    }
    Ok((arch_tag, topology_hash, sections))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // the classic zlib test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn writer_reader_roundtrip_every_primitive() {
        let mut w = SectionWriter::new();
        w.put_u32(7);
        w.put_u64(u64::MAX - 3);
        w.put_f32(-1.5);
        w.put_str("catch");
        w.put_blob(&[1, 2, 3]);
        w.put_u64s(&[9, 8]);
        w.put_f32s(&[0.25, -0.5, 1e9]);
        w.put_i32s(&[-1, 0, i32::MAX]);
        let bytes = w.finish();
        let mut r = SectionReader::new("t", &bytes);
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.str().unwrap(), "catch");
        assert_eq!(r.blob().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.u64s().unwrap(), vec![9, 8]);
        assert_eq!(r.f32s().unwrap(), vec![0.25, -0.5, 1e9]);
        assert_eq!(r.i32s().unwrap(), vec![-1, 0, i32::MAX]);
        r.done().unwrap();
    }

    #[test]
    fn reader_overrun_and_trailing_are_corrupt_not_panic() {
        let mut w = SectionWriter::new();
        w.put_u32(1);
        let bytes = w.finish();
        let mut r = SectionReader::new("t", &bytes);
        assert!(matches!(r.u64(), Err(CheckpointError::Corrupt { .. })));
        let mut r = SectionReader::new("t", &bytes);
        r.u32().unwrap();
        r.done().unwrap();
        let r = SectionReader::new("t", &bytes);
        assert!(matches!(r.done(), Err(CheckpointError::Corrupt { .. })));
    }

    #[test]
    fn hostile_length_prefix_cannot_drive_allocation() {
        let mut w = SectionWriter::new();
        w.put_u64(u64::MAX); // claims 2^64-1 f32s follow
        let bytes = w.finish();
        let mut r = SectionReader::new("t", &bytes);
        assert!(matches!(r.f32s(), Err(CheckpointError::Corrupt { .. })));
    }

    #[test]
    fn file_roundtrip_and_each_corruption_is_typed() {
        let sections = vec![
            ("meta".to_string(), b"hello".to_vec()),
            ("store".to_string(), vec![0u8; 64]),
        ];
        let bytes = encode_file(2, 0xABCD, &sections);
        let (tag, topo, back) = decode_file(&bytes).unwrap();
        assert_eq!((tag, topo), (2, 0xABCD));
        assert_eq!(back, sections);

        // truncation — anywhere in the file
        for cut in [3, 9, 20, bytes.len() - 1] {
            let err = decode_file(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, CheckpointError::Truncated { .. }),
                "cut at {cut}: {err}"
            );
        }
        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(decode_file(&bad).unwrap_err(), CheckpointError::BadMagic { .. }));
        // wrong format version
        let mut bad = bytes.clone();
        bad[8] = 99;
        assert!(matches!(
            decode_file(&bad).unwrap_err(),
            CheckpointError::UnsupportedVersion { found: 99, .. }
        ));
        // payload bit-flip -> CRC mismatch
        let mut bad = bytes.clone();
        let n = bad.len();
        bad[n - 10] ^= 0x40; // inside the last section's payload
        assert!(matches!(decode_file(&bad).unwrap_err(), CheckpointError::CrcMismatch { .. }));
        // trailing garbage
        let mut bad = bytes.clone();
        bad.push(0);
        assert!(matches!(decode_file(&bad).unwrap_err(), CheckpointError::Corrupt { .. }));
    }
}
