//! Round-robin self-play league: many concurrent `Experiment`s against
//! shared pods (DESIGN.md §17).
//!
//! The league is the repo's first multi-agent workload — and deliberately
//! its nastiest scheduling customer: every worker owns one [`Pod`] and runs
//! match after match on it, so the shared-pod busy-baseline accounting
//! (PRs 3–4) and the planner's predictions get exercised under real
//! contention and core reuse.
//!
//! ## Shape
//!
//! * `players` agents, all instances of the same manifest agent, made
//!   distinct by deterministic per-match seeds derived from the league
//!   seed (`match_seed` — a SplitMix64 mix over round/home/away/side).
//! * Each round is a full round-robin: every unordered pair `(i, j)` meets
//!   once. A match runs one short Sebulba training `Experiment` per side
//!   and scores the higher mean episode reward as the win (exact ties
//!   draw). Results carry each side's `final_params` CRC so bit-identity
//!   is checkable across schedules.
//! * A matchmaking queue feeds `concurrency` worker threads; each worker
//!   runs its matches on its own long-lived pod. Because results are
//!   re-sorted into canonical `(round, home, away)` order before ratings
//!   are computed, the standings are identical however many workers raced
//!   over the queue — `rust/tests/league.rs` pins concurrent == serial
//!   down to the params CRCs.
//! * Ratings are Elo (K = 32) folded over matches in canonical order, so
//!   the win/return table is a pure function of the match results.

pub mod cli;

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::experiment::{Arch, EnvKind, Experiment, Report, Topology};
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// Elo K-factor for the post-hoc rating fold.
const ELO_K: f64 = 32.0;
/// Every player starts here; ratings are zero-sum around it.
const ELO_BASE: f64 = 1000.0;

/// A fully-described league: workload, scale and schedule.
#[derive(Clone, Debug)]
pub struct LeagueConfig {
    /// Manifest agent tag every player instantiates (a Sebulba agent).
    pub agent: String,
    pub env: EnvKind,
    /// Number of players (>= 2).
    pub players: usize,
    /// Full round-robins to schedule (>= 1).
    pub rounds: usize,
    /// Learner updates per match side.
    pub updates: u64,
    /// League seed: every per-match seed derives from it.
    pub seed: u64,
    /// Worker threads, each owning one shared pod (>= 1).
    pub concurrency: usize,
    /// Core split every match runs on.
    pub topology: Topology,
    pub actor_batch: usize,
    pub unroll: usize,
    pub micro_batches: usize,
    /// Artifacts directory (defaults to [`crate::artifacts_dir`]).
    pub artifacts: PathBuf,
}

impl Default for LeagueConfig {
    fn default() -> Self {
        Self {
            agent: "seb_catch".to_string(),
            env: EnvKind::Catch,
            players: 4,
            rounds: 1,
            updates: 1,
            seed: 7,
            concurrency: 1,
            topology: Topology {
                actor_cores: 1,
                learner_cores: 2,
                threads_per_actor_core: 1,
                ..Topology::default()
            },
            actor_batch: 16,
            unroll: 20,
            micro_batches: 1,
            artifacts: crate::artifacts_dir(),
        }
    }
}

impl LeagueConfig {
    /// Hard-error validation: a league with fewer than two players has no
    /// matches to play and is rejected, never silently completed.
    pub fn validate(&self) -> Result<()> {
        if self.players < 2 {
            bail!("a league needs at least 2 players, got {}", self.players);
        }
        if self.rounds == 0 {
            bail!("--rounds expects a positive round count");
        }
        if self.updates == 0 {
            bail!("--updates expects a positive update count");
        }
        if self.concurrency == 0 {
            bail!("--concurrency expects a positive worker count");
        }
        self.topology.validate()?;
        self.topology.require_split()?;
        Ok(())
    }

    /// Matches per full schedule: `rounds * players*(players-1)/2`.
    pub fn total_matches(&self) -> usize {
        self.rounds * self.players * (self.players - 1) / 2
    }
}

/// The deterministic per-side seed: a SplitMix64 finalizer over the league
/// seed and the match coordinates. Distinct coordinates give (with
/// overwhelming probability) distinct, well-mixed seeds; identical
/// coordinates always give the identical seed — the property the
/// concurrent == serial oracle rests on.
pub fn match_seed(league_seed: u64, round: usize, home: usize, away: usize, side: usize) -> u64 {
    let mut sm = SplitMix64::new(league_seed);
    let k0 = sm.next_u64();
    let k1 = sm.next_u64();
    let k2 = sm.next_u64();
    let k3 = sm.next_u64();
    let mixed = league_seed
        ^ k0.wrapping_mul(round as u64 + 1)
        ^ k1.wrapping_mul(home as u64 + 1)
        ^ k2.wrapping_mul(away as u64 + 1)
        ^ k3.wrapping_mul(side as u64 + 1);
    SplitMix64::new(mixed).next_u64()
}

/// One scheduled pairing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct MatchSpec {
    round: usize,
    home: usize,
    away: usize,
}

/// One finished match. Every field is a pure function of the league config
/// and the match coordinates — no wall-clock — so two schedules of the
/// same league produce byte-identical result lists.
#[derive(Clone, Debug, PartialEq)]
pub struct MatchResult {
    pub round: usize,
    pub home: usize,
    pub away: usize,
    pub home_reward: f64,
    pub away_reward: f64,
    /// CRC32 over each side's `final_params` bits (bit-identity anchor).
    pub home_params_crc32: u32,
    pub away_params_crc32: u32,
    /// Winning player index, `None` on an exact tie.
    pub winner: Option<usize>,
}

/// One row of the final win/return table.
#[derive(Clone, Debug, PartialEq)]
pub struct Standing {
    pub player: usize,
    pub wins: usize,
    pub losses: usize,
    pub draws: usize,
    /// Mean of the player's per-match mean episode rewards.
    pub mean_reward: f64,
    /// Elo rating after folding every match in canonical order.
    pub rating: f64,
}

/// What `League::run` returns: canonical-order results + the table.
#[derive(Clone, Debug, PartialEq)]
pub struct LeagueReport {
    pub matches: Vec<MatchResult>,
    pub standings: Vec<Standing>,
}

impl LeagueReport {
    /// The standings + match log table `podracer league` prints.
    pub fn table(&self) -> String {
        let mut out = format!(
            "{:>6} {:>5} {:>7} {:>6} {:>12} {:>8}\n",
            "player", "wins", "losses", "draws", "mean_reward", "rating"
        );
        for s in &self.standings {
            out.push_str(&format!(
                "{:>6} {:>5} {:>7} {:>6} {:>12.3} {:>8.1}\n",
                s.player, s.wins, s.losses, s.draws, s.mean_reward, s.rating
            ));
        }
        out.push_str(&format!("matches={}\n", self.matches.len()));
        for m in &self.matches {
            let outcome = match m.winner {
                Some(w) => format!("winner={w}"),
                None => "draw".to_string(),
            };
            out.push_str(&format!(
                "  r{} {}v{}: reward {:.3} vs {:.3} ({outcome}) params_crc {:08x}/{:08x}\n",
                m.round,
                m.home,
                m.away,
                m.home_reward,
                m.away_reward,
                m.home_params_crc32,
                m.away_params_crc32,
            ));
        }
        out
    }

    /// Machine-readable form (`--report-json`). Deterministic for a fixed
    /// league config: no timing fields, so `diff` doubles as the
    /// reproducibility oracle in `scripts/league_smoke.sh`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "matches",
                Json::Arr(
                    self.matches
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("round", Json::num(m.round as f64)),
                                ("home", Json::num(m.home as f64)),
                                ("away", Json::num(m.away as f64)),
                                ("home_reward", Json::num(m.home_reward)),
                                ("away_reward", Json::num(m.away_reward)),
                                ("home_params_crc32", Json::num(m.home_params_crc32 as f64)),
                                ("away_params_crc32", Json::num(m.away_params_crc32 as f64)),
                                (
                                    "winner",
                                    match m.winner {
                                        Some(w) => Json::num(w as f64),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "standings",
                Json::Arr(
                    self.standings
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("player", Json::num(s.player as f64)),
                                ("wins", Json::num(s.wins as f64)),
                                ("losses", Json::num(s.losses as f64)),
                                ("draws", Json::num(s.draws as f64)),
                                ("mean_reward", Json::num(s.mean_reward)),
                                ("rating", Json::num(s.rating)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The scheduler itself. Construct with a validated [`LeagueConfig`].
pub struct League {
    cfg: LeagueConfig,
}

impl League {
    pub fn new(cfg: LeagueConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Self { cfg })
    }

    pub fn config(&self) -> &LeagueConfig {
        &self.cfg
    }

    /// Play the full schedule and return canonical-order results.
    pub fn run(&self) -> Result<LeagueReport> {
        let cfg = &self.cfg;
        let mut schedule = VecDeque::new();
        for round in 0..cfg.rounds {
            for home in 0..cfg.players {
                for away in home + 1..cfg.players {
                    schedule.push_back(MatchSpec { round, home, away });
                }
            }
        }
        let expected = schedule.len();
        let queue = Mutex::new(schedule);
        let results: Mutex<Vec<MatchResult>> = Mutex::new(Vec::with_capacity(expected));

        std::thread::scope(|scope| -> Result<()> {
            let mut workers = Vec::new();
            for worker in 0..cfg.concurrency {
                let queue = &queue;
                let results = &results;
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("league-worker-{worker}"))
                        .spawn_scoped(scope, move || self.worker_loop(queue, results))
                        .context("spawning league worker")?,
                );
            }
            let mut first_err = None;
            for w in workers {
                if let Err(e) = w.join().unwrap_or_else(bail_panic) {
                    first_err.get_or_insert(e);
                }
            }
            match first_err {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })?;

        let mut matches = results.into_inner().expect("league results mutex poisoned");
        anyhow::ensure!(
            matches.len() == expected,
            "league played {} of {expected} scheduled matches",
            matches.len()
        );
        // Canonical order: ratings and standings must not depend on which
        // worker finished first.
        matches.sort_by_key(|m| (m.round, m.home, m.away));
        let standings = standings(cfg.players, &matches);
        Ok(LeagueReport { matches, standings })
    }

    /// One worker: own pod, drain the matchmaking queue.
    fn worker_loop(
        &self,
        queue: &Mutex<VecDeque<MatchSpec>>,
        results: &Mutex<Vec<MatchResult>>,
    ) -> Result<()> {
        let cfg = &self.cfg;
        // One long-lived pod per worker, reused across matches — each run
        // re-baselines against the pod's accumulated busy counters, which
        // is exactly the shared-pod stats path PRs 3–4 fixed.
        let mut pod = crate::runtime::Pod::new(&cfg.artifacts, cfg.topology.total_cores())?;
        loop {
            let spec = match queue.lock().expect("league queue mutex poisoned").pop_front() {
                Some(spec) => spec,
                None => return Ok(()),
            };
            let result = self.play(&mut pod, spec)?;
            results.lock().expect("league results mutex poisoned").push(result);
        }
    }

    fn play(&self, pod: &mut crate::runtime::Pod, spec: MatchSpec) -> Result<MatchResult> {
        let home = self.run_side(pod, spec, 0, spec.home)?;
        let away = self.run_side(pod, spec, 1, spec.away)?;
        let reward = |r: &Report| {
            r.as_actor_learner().map(|d| d.mean_episode_reward).unwrap_or(0.0)
        };
        let (home_reward, away_reward) = (reward(&home), reward(&away));
        let winner = if home_reward > away_reward {
            Some(spec.home)
        } else if away_reward > home_reward {
            Some(spec.away)
        } else {
            None
        };
        Ok(MatchResult {
            round: spec.round,
            home: spec.home,
            away: spec.away,
            home_reward,
            away_reward,
            home_params_crc32: home.final_params_crc32(),
            away_params_crc32: away.final_params_crc32(),
            winner,
        })
    }

    fn run_side(
        &self,
        pod: &mut crate::runtime::Pod,
        spec: MatchSpec,
        side: usize,
        player: usize,
    ) -> Result<Report> {
        let cfg = &self.cfg;
        let seed = match_seed(cfg.seed, spec.round, spec.home, spec.away, side);
        Experiment::new(Arch::Sebulba)
            .artifacts(&cfg.artifacts)
            .agent(&cfg.agent)
            .env(cfg.env)
            .topology(cfg.topology.clone())
            .actor_batch(cfg.actor_batch)
            .unroll(cfg.unroll)
            .micro_batches(cfg.micro_batches)
            .updates(cfg.updates)
            .seed(seed)
            .build()
            .with_context(|| format!("building match r{} {}v{}", spec.round, spec.home, spec.away))?
            .run_on(pod)
            .with_context(|| {
                format!("match r{} {}v{} side of player {player}", spec.round, spec.home, spec.away)
            })
    }
}

fn bail_panic(payload: Box<dyn std::any::Any + Send>) -> Result<()> {
    let msg = payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "league worker panicked".to_string());
    Err(anyhow::anyhow!("league worker panicked: {msg}"))
}

/// Fold canonical-order results into the win/return table + Elo ratings.
fn standings(players: usize, matches: &[MatchResult]) -> Vec<Standing> {
    let mut wins = vec![0usize; players];
    let mut losses = vec![0usize; players];
    let mut draws = vec![0usize; players];
    let mut reward_sum = vec![0.0f64; players];
    let mut played = vec![0usize; players];
    let mut rating = vec![ELO_BASE; players];

    for m in matches {
        reward_sum[m.home] += m.home_reward;
        reward_sum[m.away] += m.away_reward;
        played[m.home] += 1;
        played[m.away] += 1;
        let home_score = match m.winner {
            Some(w) if w == m.home => {
                wins[m.home] += 1;
                losses[m.away] += 1;
                1.0
            }
            Some(_) => {
                wins[m.away] += 1;
                losses[m.home] += 1;
                0.0
            }
            None => {
                draws[m.home] += 1;
                draws[m.away] += 1;
                0.5
            }
        };
        let expected_home =
            1.0 / (1.0 + 10f64.powf((rating[m.away] - rating[m.home]) / 400.0));
        let delta = ELO_K * (home_score - expected_home);
        rating[m.home] += delta;
        rating[m.away] -= delta;
    }

    let mut table: Vec<Standing> = (0..players)
        .map(|p| Standing {
            player: p,
            wins: wins[p],
            losses: losses[p],
            draws: draws[p],
            mean_reward: if played[p] > 0 { reward_sum[p] / played[p] as f64 } else { 0.0 },
            rating: rating[p],
        })
        .collect();
    table.sort_by(|a, b| {
        b.rating
            .partial_cmp(&a.rating)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.player.cmp(&b.player))
    });
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_rejects_degenerate_leagues() {
        for players in [0usize, 1] {
            let cfg = LeagueConfig { players, ..LeagueConfig::default() };
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains("at least 2 players"), "{err}");
        }
        assert!(LeagueConfig { rounds: 0, ..Default::default() }.validate().is_err());
        assert!(LeagueConfig { concurrency: 0, ..Default::default() }.validate().is_err());
        assert!(LeagueConfig { updates: 0, ..Default::default() }.validate().is_err());
        assert!(LeagueConfig::default().validate().is_ok());
    }

    #[test]
    fn match_seeds_are_deterministic_and_distinct() {
        let a = match_seed(7, 0, 0, 1, 0);
        assert_eq!(a, match_seed(7, 0, 0, 1, 0));
        let mut seen = std::collections::BTreeSet::new();
        for round in 0..3 {
            for home in 0..4 {
                for away in home + 1..4 {
                    for side in 0..2 {
                        assert!(
                            seen.insert(match_seed(7, round, home, away, side)),
                            "seed collision at r{round} {home}v{away} side {side}"
                        );
                    }
                }
            }
        }
        assert_ne!(match_seed(7, 0, 0, 1, 0), match_seed(8, 0, 0, 1, 0));
    }

    #[test]
    fn total_matches_counts_round_robin_pairs() {
        let cfg = LeagueConfig { players: 4, rounds: 2, ..Default::default() };
        assert_eq!(cfg.total_matches(), 12);
    }

    fn result(round: usize, home: usize, away: usize, hr: f64, ar: f64) -> MatchResult {
        MatchResult {
            round,
            home,
            away,
            home_reward: hr,
            away_reward: ar,
            home_params_crc32: 0,
            away_params_crc32: 0,
            winner: if hr > ar {
                Some(home)
            } else if ar > hr {
                Some(away)
            } else {
                None
            },
        }
    }

    #[test]
    fn standings_are_consistent_and_rating_sorted() {
        // 3 players, one round-robin: 0 beats 1 and 2; 1 beats 2.
        let matches =
            vec![result(0, 0, 1, 1.0, 0.0), result(0, 0, 2, 1.0, 0.0), result(0, 1, 2, 1.0, 0.0)];
        let table = standings(3, &matches);
        assert_eq!(table[0].player, 0);
        assert_eq!((table[0].wins, table[0].losses), (2, 0));
        assert_eq!((table[2].wins, table[2].losses), (0, 2));
        // every player's results sum to their match count
        for s in &table {
            assert_eq!(s.wins + s.losses + s.draws, 2);
        }
        // Elo is zero-sum around the base
        let total: f64 = table.iter().map(|s| s.rating).sum();
        assert!((total - 3.0 * 1000.0).abs() < 1e-9);
        assert!(table[0].rating > table[1].rating && table[1].rating > table[2].rating);
    }

    #[test]
    fn report_json_is_deterministic() {
        let matches = vec![result(0, 0, 1, 0.5, 0.5)];
        let report =
            LeagueReport { standings: standings(2, &matches), matches };
        assert_eq!(report.to_json().to_string(), report.to_json().to_string());
        assert!(report.table().contains("draw"));
    }
}
