//! `podracer league` — the self-play scheduler's CLI surface.
//!
//! Same hard-error flag discipline as every other subcommand: unknown
//! flags and degenerate leagues (`--players 0`) exit nonzero before any
//! pod is built. `--report-json` writes the deterministic league report
//! (`scripts/league_smoke.sh` diffs two same-seed runs byte-for-byte).

use anyhow::{bail, Context, Result};

use crate::experiment::{EnvKind, Topology};
use crate::util::cli::Args;

use super::{League, LeagueConfig};

/// Every flag `podracer league` accepts; anything else is a hard error.
pub const LEAGUE_FLAGS: &[&str] = &[
    "agent",
    "env",
    "players",
    "rounds",
    "updates",
    "seed",
    "concurrency",
    "actor-cores",
    "learner-cores",
    "threads",
    "pipeline-stages",
    "learner-pipeline",
    "batch",
    "unroll",
    "micro-batches",
    "report-json",
];

/// The `podracer league` entrypoint.
pub fn run(args: &Args) -> Result<()> {
    args.check_known("league", LEAGUE_FLAGS)?;
    let defaults = LeagueConfig::default();
    let topology = Topology {
        actor_cores: args.get_usize("actor-cores", defaults.topology.actor_cores)?,
        learner_cores: args.get_usize("learner-cores", defaults.topology.learner_cores)?,
        threads_per_actor_core: args
            .get_usize("threads", defaults.topology.threads_per_actor_core)?,
        pipeline_stages: args.get_usize("pipeline-stages", defaults.topology.pipeline_stages)?,
        learner_pipeline: args
            .get_usize("learner-pipeline", defaults.topology.learner_pipeline)?,
        ..defaults.topology.clone()
    };
    let env: EnvKind = args.get_str("env", defaults.env.as_str()).parse()?;
    let cfg = LeagueConfig {
        agent: args.get_str("agent", &defaults.agent),
        env,
        players: args.get_usize("players", defaults.players)?,
        rounds: args.get_usize("rounds", defaults.rounds)?,
        updates: args.get_u64("updates", defaults.updates)?,
        seed: args.get_u64("seed", defaults.seed)?,
        concurrency: args.get_usize("concurrency", defaults.concurrency)?,
        topology,
        actor_batch: args.get_usize("batch", defaults.actor_batch)?,
        unroll: args.get_usize("unroll", defaults.unroll)?,
        micro_batches: args.get_usize("micro-batches", defaults.micro_batches)?,
        artifacts: crate::artifacts_dir(),
    };
    let league = League::new(cfg)?;
    let cfg = league.config();
    println!(
        "league: agent={} env={} players={} rounds={} matches={} concurrency={} topology={}",
        cfg.agent,
        cfg.env,
        cfg.players,
        cfg.rounds,
        cfg.total_matches(),
        cfg.concurrency,
        crate::plan::topology_label(&cfg.topology),
    );
    let report = league.run()?;
    print!("{}", report.table());
    if let Some(path) = args.flags.get("report-json") {
        if path.is_empty() || path == "true" {
            bail!("--report-json expects a file path");
        }
        std::fs::write(path, format!("{}\n", report.to_json()))
            .with_context(|| format!("writing {path}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn degenerate_leagues_hard_error_before_any_pod() {
        for players in ["0", "1"] {
            let err = run(&parse(&["--players", players])).unwrap_err().to_string();
            assert!(err.contains("at least 2 players"), "{err}");
        }
    }

    #[test]
    fn unknown_flags_hard_error() {
        let err = run(&parse(&["--playerz", "4"])).unwrap_err().to_string();
        assert!(err.contains("--playerz"), "{err}");
    }
}
