//! Top-level Sebulba orchestration: wire the pod, spawn actors + learners,
//! run to the update target, shut down cleanly, report.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::checkpoint::{
    expect_field, ActorSection, Checkpoint, MetaSection, StoreSection, ACTOR_SECTION,
    META_SECTION, STORE_SECTION,
};
use crate::envs::{make_factory, WorkerPool};
use crate::experiment::{
    ActorLearnerDetail, Arch, Detail, EnvKind, Report, RunSpec, Runner, Topology,
};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{DeviceHandle, Pod};

use super::actor::{spawn_actor, ActorCheckpoint, ActorConfig, ShardBundle, SnapshotSlot};
use super::collective::GradientBus;
use super::config::SebulbaConfig;
use super::learner::{learner_main, LearnerCheckpoint, LearnerConfig, LearnerHandles};
use super::param_store::ParamStore;
use super::queue::BoundedQueue;
use super::stats::RunStats;

/// Wake every thread parked on the pod's seams: set the stop flag, shut all
/// trajectory queues, shut the gradient bus. Idempotent; called by a failing
/// learner from its own thread (so in-order joins can't deadlock on a
/// sibling parked in the bus or a queue) and by the coordinator at teardown.
pub(crate) fn unblock_pod(
    stop: &AtomicBool,
    queues: &[Arc<BoundedQueue<ShardBundle>>],
    bus: &GradientBus,
) {
    stop.store(true, Ordering::Relaxed);
    for q in queues {
        q.shutdown();
    }
    bus.shutdown();
}

/// Drop guard for a learner thread: unblocks the pod unless disarmed.
/// Destructors run during unwinding, so this covers the panic path too —
/// a panicking learner must not leave siblings parked in the bus while the
/// coordinator's in-order joins wait on them. Disarmed only on clean
/// completion (an early unblock there could error a sibling mid-collect).
struct UnblockOnDrop {
    stop: Arc<AtomicBool>,
    queues: Vec<Arc<BoundedQueue<ShardBundle>>>,
    bus: Arc<GradientBus>,
    armed: bool,
}

impl Drop for UnblockOnDrop {
    fn drop(&mut self) {
        if self.armed {
            unblock_pod(&self.stop, &self.queues, &self.bus);
        }
    }
}

/// Spawn a learner thread whose exit always leaves the pod joinable: the
/// guard above unblocks every seam on an `Err` return *and* on a panic, so
/// `join_pod_threads`' in-order joins can't deadlock on a parked sibling.
pub(crate) fn spawn_guarded_learner(
    name: String,
    lcfg: LearnerConfig,
    handles: LearnerHandles,
    opt: Vec<f32>,
    stop: Arc<AtomicBool>,
    queues: Vec<Arc<BoundedQueue<ShardBundle>>>,
    bus: Arc<GradientBus>,
) -> std::thread::JoinHandle<Result<(Vec<f32>, Vec<f32>)>> {
    std::thread::Builder::new()
        .name(name)
        .spawn(move || {
            let mut guard = UnblockOnDrop { stop, queues, bus, armed: true };
            let res = learner_main(&lcfg, &handles, opt);
            guard.armed = res.is_err();
            res // guard drops here: unblocks on Err (and on panic)
        })
        .expect("spawn learner")
}

/// Join learners (in index order — safe because a failing learner unblocks
/// the pod from its own spawn wrapper) and then actors, aggregating every
/// failure into one error chain (the first joined error may be a secondary
/// "bus shut down" from a sibling unblocking the pod, not the root cause).
/// Returns replica 0's (params, opt_state) on success.
#[allow(clippy::type_complexity)]
pub(crate) fn join_pod_threads(
    label: &str,
    stop: &AtomicBool,
    queues: &[Arc<BoundedQueue<ShardBundle>>],
    bus: &GradientBus,
    learner_joins: Vec<std::thread::JoinHandle<Result<(Vec<f32>, Vec<f32>)>>>,
    actor_joins: Vec<std::thread::JoinHandle<Result<()>>>,
) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
    let mut replica0: Option<(Vec<f32>, Vec<f32>)> = None;
    let mut learner_err: Option<anyhow::Error> = None;
    for (r, j) in learner_joins.into_iter().enumerate() {
        match j.join() {
            Ok(Ok(out)) => {
                if r == 0 {
                    replica0 = Some(out);
                }
            }
            Ok(Err(e)) => {
                learner_err = Some(match learner_err.take() {
                    None => e.context(format!("{label} learner {r} failed")),
                    Some(prev) => prev.context(format!("{label} learner {r} also failed: {e:#}")),
                });
                unblock_pod(stop, queues, bus);
            }
            Err(_) => {
                learner_err = Some(match learner_err.take() {
                    None => anyhow::anyhow!("{label} learner {r} panicked"),
                    Some(prev) => prev.context(format!("{label} learner {r} also panicked")),
                });
                unblock_pod(stop, queues, bus);
            }
        }
    }
    unblock_pod(stop, queues, bus);
    let mut actor_err: Option<anyhow::Error> = None;
    for j in actor_joins {
        match j.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                if actor_err.is_none() {
                    actor_err = Some(e.context(format!("{label} actor failed")));
                }
            }
            Err(_) => {
                if actor_err.is_none() {
                    actor_err = Some(anyhow::anyhow!("{label} actor panicked"));
                }
            }
        }
    }
    if let Some(e) = learner_err {
        return Err(match actor_err {
            Some(a) => e.context(format!("{label} actor also failed: {a:#}")),
            None => e,
        });
    }
    if let Some(e) = actor_err {
        return Err(e);
    }
    Ok(replica0)
}

/// The Sebulba *workload*: everything about a run except the core split,
/// which arrives as a [`Topology`] through the [`Runner`] trait. Reached
/// through `experiment::Experiment::new(Arch::Sebulba)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Sebulba {
    /// Agent tag in the artifact manifest.
    pub agent: String,
    /// Host environment (typed — unknown names fail at parse time).
    pub env_kind: EnvKind,
    /// Environments per actor thread (Fig 4b's actor batch).
    pub actor_batch: usize,
    /// Trajectory length T.
    pub unroll: usize,
    /// Sequential updates per trajectory.
    pub micro_batches: usize,
    pub discount: f32,
    /// Learner updates per replica.
    pub total_updates: u64,
    pub seed: u64,
    /// Materializing data-path oracle (DESIGN.md §11).
    pub copy_path: bool,
    /// Optional `(params, opt_state)` from a previous run — stages long
    /// trainings with intermediate reports (`examples/sebulba_atari.rs`).
    pub warm_start: Option<(Vec<f32>, Vec<f32>)>,
}

impl Default for Sebulba {
    fn default() -> Self {
        let cfg = SebulbaConfig::default();
        Self {
            agent: cfg.agent,
            env_kind: cfg.env_kind,
            actor_batch: cfg.actor_batch,
            unroll: cfg.unroll,
            micro_batches: cfg.micro_batches,
            discount: cfg.discount,
            total_updates: cfg.total_updates,
            seed: cfg.seed,
            copy_path: cfg.copy_path,
            warm_start: None,
        }
    }
}

impl Runner for Sebulba {
    fn arch(&self) -> Arch {
        Arch::Sebulba
    }

    fn run_checkpointed(&self, pod: &mut Pod, topo: &Topology, spec: &RunSpec) -> Result<Report> {
        run_resolved(pod, &self.resolved(topo), self.warm_start.clone(), spec)
    }
}

impl Sebulba {
    /// Merge this workload with a core split into the resolved config the
    /// coordinator spawns from.
    pub fn resolved(&self, topo: &Topology) -> SebulbaConfig {
        SebulbaConfig {
            agent: self.agent.clone(),
            env_kind: self.env_kind,
            actor_cores: topo.actor_cores,
            learner_cores: topo.learner_cores,
            threads_per_actor_core: topo.threads_per_actor_core,
            actor_batch: self.actor_batch,
            pipeline_stages: topo.pipeline_stages,
            learner_pipeline: topo.learner_pipeline,
            unroll: self.unroll,
            micro_batches: self.micro_batches,
            discount: self.discount,
            queue_capacity: topo.queue_capacity,
            env_workers: topo.env_workers,
            replicas: topo.replicas,
            total_updates: self.total_updates,
            seed: self.seed,
            copy_path: self.copy_path,
        }
    }
}

/// The coordinator proper: validate, wire the pod, spawn actors + learners,
/// run to the update target, shut down cleanly, report.
pub(crate) fn run_resolved(
    pod: &mut Pod,
    cfg: &SebulbaConfig,
    warm: Option<(Vec<f32>, Vec<f32>)>,
    spec: &RunSpec,
) -> Result<Report> {
    cfg.validate()?;
    cfg.topology().validate_for_pod(pod.n_cores())?;

    // Elasticity runs under lockstep pacing (DESIGN.md §13): the actor gate
    // equates "windows produced" with "updates published", which only holds
    // when exactly one actor thread feeds one serial learner round per
    // window. Reject every geometry where that invariant breaks.
    if !spec.is_plain() {
        ensure!(
            cfg.actor_cores * cfg.threads_per_actor_core == 1,
            "checkpoint/restore/fault runs need exactly 1 actor thread (got {} cores x {} threads)",
            cfg.actor_cores,
            cfg.threads_per_actor_core
        );
        ensure!(cfg.pipeline_stages == 1, "checkpoint/restore/fault runs need pipeline_stages == 1");
        ensure!(cfg.learner_pipeline == 1, "checkpoint/restore/fault runs need learner_pipeline == 1");
        ensure!(cfg.replicas == 1, "checkpoint/restore/fault runs need replicas == 1");
        ensure!(
            cfg.micro_batches == 1,
            "checkpoint/restore/fault runs need micro_batches == 1 \
             (one window must feed exactly one update)"
        );
    }

    // ---- restore (DESIGN.md §13) -----------------------------------------
    // Structural validation (magic/version/CRC) and the arch + topology
    // check happen in `load_for`; the workload identity is then matched
    // field by field. Every disagreement is a typed `CheckpointError`.
    let restored = match &spec.restore_from {
        Some(path) => {
            let ckpt = Checkpoint::load_for(path, Arch::Sebulba, &cfg.topology())
                .with_context(|| format!("restoring from {}", path.display()))?;
            let meta = MetaSection::decode(ckpt.section(META_SECTION)?)?;
            expect_field("agent", meta.agent.clone(), cfg.agent.clone())?;
            expect_field("seed", meta.seed, cfg.seed)?;
            expect_field("env", meta.env.clone(), cfg.env_kind.as_str().to_string())?;
            let store = StoreSection::decode(ckpt.section(STORE_SECTION)?)?;
            let actor = ActorSection::decode(ckpt.section(ACTOR_SECTION)?)?;
            // Lockstep invariants the save upheld; a disagreement means the
            // file pairs state from different rounds.
            expect_field("store version", store.version, meta.rounds_done)?;
            expect_field("actor windows", actor.windows_done, meta.rounds_done)?;
            Some((meta, store, actor))
        }
        None => None,
    };

    let agent = pod.manifest.agent(&cfg.agent)?.clone();
    let obs_shape = agent.obs_shape.clone();
    let num_actions = agent.num_actions;

    let n_per = cfg.cores_per_replica();

    // ---- program loading ------------------------------------------------
    let infer = cfg.infer_program();
    let grad = cfg.grad_program();
    let apply = cfg.apply_program();
    let init = cfg.init_program();

    let mut actor_core_ids = Vec::new();
    let mut learner_core_ids = Vec::new();
    let mut learner0_ids = Vec::new();
    for r in 0..cfg.replicas {
        let base = r * n_per;
        actor_core_ids.extend(base..base + cfg.actor_cores);
        learner_core_ids
            .extend(base + cfg.actor_cores..base + cfg.actor_cores + cfg.learner_cores);
        learner0_ids.push(base + cfg.actor_cores);
    }
    pod.load_program(&infer, &actor_core_ids)
        .with_context(|| format!("loading {infer}"))?;
    pod.load_program(&grad, &learner_core_ids)
        .with_context(|| format!("loading {grad}"))?;
    pod.load_program(&apply, &learner0_ids)?;
    pod.load_program(&init, &[learner0_ids[0]])?;

    // Pre-run busy baseline, taken before this run executes anything:
    // on a shared or warm-started pod (`run_on_with` staged trainings)
    // the cores' cumulative busy counters include previous runs' device
    // time, and charging it to this run inflated
    // `actor/learner_busy_seconds` and deflated `projected_fps` — the
    // same reused-pod bug PR 3 fixed for Anakin's `projected_sps`.
    let busy0: Vec<f64> = (0..cfg.total_cores())
        .map(|cid| Ok(pod.core(cid)?.busy_seconds()))
        .collect::<Result<_>>()?;

    // ---- init params (or warm start, or restore) -------------------------
    let (params0, opt0) = match (&restored, warm) {
        (Some(_), Some(_)) => bail!("warm_start cannot be combined with a checkpoint restore"),
        (Some((_, s, _)), None) => (s.params.clone(), s.opt.clone()),
        (None, Some((p, o))) => (p, o),
        (None, None) => {
            let outs = pod
                .core(learner0_ids[0])?
                .execute(&init, vec![HostTensor::scalar_i32(cfg.seed as i32)])?;
            (outs[0].clone().into_f32()?, outs[1].clone().into_f32()?)
        }
    };
    log::info!(
        "sebulba[{}]: params={} opt={} replicas={} cores={}A+{}L batch={}x{} T={} lpipe={}",
        cfg.agent,
        params0.len(),
        opt0.len(),
        cfg.replicas,
        cfg.actor_cores,
        cfg.learner_cores,
        cfg.pipeline_stages,
        cfg.stage_batch(),
        cfg.unroll,
        cfg.learner_pipeline
    );

    // ---- shared state ----------------------------------------------------
    let stats = Arc::new(RunStats::new());
    let stop = Arc::new(AtomicBool::new(false));
    let bus = Arc::new(GradientBus::new(cfg.replicas));
    let factory: Arc<crate::envs::EnvFactory> =
        Arc::new(make_factory(cfg.env_kind, cfg.seed));

    let mut actor_joins = Vec::new();
    let mut learner_joins = Vec::new();
    // All queues exist up front so a failing learner can unblock every
    // replica's threads, not just its own (see the spawn below).
    let queues: Vec<Arc<BoundedQueue<ShardBundle>>> = (0..cfg.replicas)
        .map(|_| Arc::new(BoundedQueue::<ShardBundle>::new(cfg.queue_capacity)))
        .collect();

    // ---- checkpoint + fault wiring (replicas == 1 whenever any is on) ----
    if let Some(after) = spec.fault.as_ref().and_then(|f| f.poison_queue_after) {
        for q in &queues {
            q.poison_after_pushes(after);
        }
    }
    let start_round = restored.as_ref().map_or(0, |(m, _, _)| m.rounds_done);
    let slot: SnapshotSlot = Arc::new(Mutex::new(BTreeMap::new()));
    let actor_ck = if spec.checkpoint.is_some() || restored.is_some() {
        Some(ActorCheckpoint {
            // Restore-only run: keep the lockstep gate, but a period of
            // u64::MAX never divides a window count, so nothing deposits.
            every: spec.checkpoint.as_ref().map_or(u64::MAX, |c| c.every),
            slot: slot.clone(),
            resume: restored.as_ref().map(|(_, _, a)| a.clone()),
        })
    } else {
        None
    };
    let t_start = Instant::now();

    for r in 0..cfg.replicas {
        let base = r * n_per;
        let store = Arc::new(match &restored {
            Some((_, s, _)) => ParamStore::with_version(params0.clone(), s.version),
            None => ParamStore::new(params0.clone()),
        });
        let queue = queues[r].clone();
        let pool = WorkerPool::new(cfg.env_workers);

        // actors: threads_per_actor_core per actor core
        for ac in 0..cfg.actor_cores {
            let core = pod.core(base + ac)?;
            for th in 0..cfg.threads_per_actor_core {
                let actor_id = (r * cfg.actor_cores + ac) * cfg.threads_per_actor_core + th;
                let acfg = ActorConfig {
                    actor_id,
                    batch: cfg.actor_batch,
                    pipeline_stages: cfg.pipeline_stages,
                    unroll: cfg.unroll,
                    discount: cfg.discount,
                    num_shards: cfg.learner_cores * cfg.micro_batches,
                    infer_program: infer.clone(),
                    obs_shape: obs_shape.clone(),
                    num_actions,
                    seed: cfg.seed,
                    copy_path: cfg.copy_path,
                    checkpoint: actor_ck.clone(),
                };
                actor_joins.push(spawn_actor(
                    acfg,
                    core.clone(),
                    factory.clone(),
                    pool.clone(),
                    store.clone(),
                    queue.clone(),
                    stats.clone(),
                    stop.clone(),
                ));
            }
        }

        // learner thread per replica
        let lcfg = LearnerConfig {
            replica_id: r,
            grad_program: grad.clone(),
            apply_program: apply.clone(),
            shards_per_round: cfg.learner_cores,
            total_updates: cfg.total_updates,
            pipeline: cfg.learner_pipeline,
            checkpoint: spec.checkpoint.as_ref().map(|cs| LearnerCheckpoint {
                spec: cs.clone(),
                slot: slot.clone(),
                meta: MetaSection {
                    agent: cfg.agent.clone(),
                    seed: cfg.seed,
                    env: cfg.env_kind.as_str().to_string(),
                    rounds_done: 0,
                },
                arch: Arch::Sebulba,
                topology: cfg.topology(),
            }),
            fault: spec.fault.clone(),
            start_round,
        };
        let cores: Vec<DeviceHandle> = (0..cfg.learner_cores)
            .map(|i| pod.core(base + cfg.actor_cores + i))
            .collect::<Result<_>>()?;
        let handles = LearnerHandles {
            cores,
            store: store.clone(),
            queue: queue.clone(),
            stats: stats.clone(),
            bus: bus.clone(),
        };
        learner_joins.push(spawn_guarded_learner(
            format!("learner-{r}"),
            lcfg,
            handles,
            opt0.clone(),
            stop.clone(),
            queues.clone(),
            bus.clone(),
        ));
    }

    // ---- wait for learners, then tear down actors ------------------------
    // Every thread is joined even on a learner error: returning early
    // would leave actors running against a shut-down queue and drop
    // their `Result`s (and other replicas' learners parked on the bus).
    let mut final_params = params0;
    let mut final_opt_state = opt0;
    if let Some((params, opt)) =
        join_pod_threads("sebulba", &stop, &queues, &bus, learner_joins, actor_joins)?
    {
        final_params = params;
        final_opt_state = opt;
    }

    // ---- report ----------------------------------------------------------
    let elapsed = t_start.elapsed().as_secs_f64();
    // All busy totals are *this run's*: the pre-run baseline is
    // subtracted per core (see `busy0` above).
    let mut actor_busy = 0.0;
    for &cid in &actor_core_ids {
        actor_busy += pod.core(cid)?.busy_seconds() - busy0[cid];
    }
    let mut learner_busy = 0.0;
    let mut critical_path: f64 = 1e-12;
    for &cid in &learner_core_ids {
        learner_busy += pod.core(cid)?.busy_seconds() - busy0[cid];
    }
    for cid in 0..cfg.total_cores() {
        critical_path = critical_path.max(pod.core(cid)?.busy_seconds() - busy0[cid]);
    }
    // An exposed learner schedule lengthens the critical path
    // (DESIGN.md §9): a learner thread's active seconds (wall minus
    // data starvation) bound how fast its replica can retire rounds
    // even on truly parallel cores. Fully overlapped, this collapses to
    // the learner cores' busy time and the per-core max wins.
    critical_path = critical_path.max(stats.learner_active_max_seconds());
    let frames = stats.env_frames.frames();
    let report = Report {
        arch: Arch::Sebulba,
        steps: frames,
        updates: stats.updates.load(Ordering::Relaxed),
        elapsed,
        throughput: frames as f64 / elapsed.max(1e-12),
        projected_throughput: frames as f64 / critical_path,
        final_params,
        detail: Detail::ActorLearner(ActorLearnerDetail {
            mean_staleness: stats.mean_staleness(),
            mean_episode_reward: stats.mean_episode_reward(),
            episodes: stats.episodes.load(Ordering::Relaxed),
            last_loss: stats.last_loss(),
            actor_busy_seconds: actor_busy,
            learner_busy_seconds: learner_busy,
            actor_infer_seconds: stats.actor_infer_seconds(),
            actor_env_step_seconds: stats.actor_env_seconds(),
            actor_loop_seconds: stats.actor_loop_seconds(),
            actor_overlap_seconds: stats.actor_overlap_seconds(),
            learner_grad_seconds: stats.learner_grad_seconds(),
            learner_collective_seconds: stats.learner_collective_seconds(),
            learner_apply_seconds: stats.learner_apply_seconds(),
            learner_active_seconds: stats.learner_active_seconds(),
            learner_overlap_seconds: stats.learner_overlap_seconds(),
            queue_push_block_seconds: queues.iter().map(|q| q.push_block_seconds()).sum(),
            queue_pop_block_seconds: queues.iter().map(|q| q.pop_block_seconds()).sum(),
            infer_calls: stats.infer_calls(),
            grad_calls: stats.grad_calls(),
            apply_calls: stats.apply_calls(),
            env_step_calls: stats.env_step_calls(),
            pods_joined: 0,
            pods_evicted: 0,
            membership_epoch: 0,
            join_param_version: 0,
            final_opt_state,
        }),
    };
    log::info!("sebulba done: {}", stats.summary());
    Ok(report)
}
