//! Run statistics: FPS meters, update counters, staleness, latency
//! histograms. Everything is atomic so actor/learner threads update freely.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Counts environment frames and reports frames/sec.
pub struct FpsMeter {
    frames: AtomicU64,
    start: Instant,
}

impl Default for FpsMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl FpsMeter {
    pub fn new() -> Self {
        Self { frames: AtomicU64::new(0), start: Instant::now() }
    }

    pub fn add(&self, frames: u64) {
        self.frames.fetch_add(frames, Ordering::Relaxed);
    }

    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn fps(&self) -> f64 {
        let e = self.elapsed();
        if e > 0.0 {
            self.frames() as f64 / e
        } else {
            0.0
        }
    }
}

/// Fixed-bucket latency histogram (log-spaced, microseconds to seconds).
pub struct LatencyHistogram {
    // bucket i covers [2^i, 2^(i+1)) microseconds; 24 buckets ≈ up to 16s
    buckets: [AtomicU64; 24],
    count: AtomicU64,
    total_nanos: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            total_nanos: AtomicU64::new(0),
        }
    }

    pub fn record(&self, dur: std::time::Duration) {
        let micros = dur.as_micros().max(1) as u64;
        let bucket = (63 - micros.leading_zeros() as usize).min(23);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_nanos
            .fetch_add(dur.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_seconds(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.total_nanos.load(Ordering::Relaxed) as f64 * 1e-9 / c as f64
    }

    /// Approximate percentile from bucket boundaries (upper bound).
    pub fn percentile_seconds(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << (i + 1)) as f64 * 1e-6;
            }
        }
        (1u64 << 24) as f64 * 1e-6
    }

    /// Fold another histogram into this one. Bucket counts, the sample
    /// count and the nano total are all plain sums, so folding per-stage
    /// (or per-thread) histograms is associative and order-independent —
    /// the merged histogram answers percentiles exactly as if every sample
    /// had been recorded here directly (pinned by a proptest).
    pub fn merge_from(&self, other: &LatencyHistogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.total_nanos
            .fetch_add(other.total_nanos.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Frozen copy of every counter — lets tests (and reporters) compare
    /// two histograms for exact equality instead of sampling percentiles.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            total_nanos: self.total_nanos.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data view of a [`LatencyHistogram`] at one instant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: [u64; 24],
    pub count: u64,
    pub total_nanos: u64,
}

/// Everything the coordinator reports at the end of a run.
#[derive(Default)]
pub struct RunStats {
    pub env_frames: FpsMeter,
    pub updates: AtomicU64,
    pub trajectories: AtomicU64,
    /// Sum of (current_version - trajectory_version) over updates.
    pub staleness_sum: AtomicU64,
    pub inference_latency: LatencyHistogram,
    pub grad_latency: LatencyHistogram,
    pub apply_latency: LatencyHistogram,
    pub env_step_latency: LatencyHistogram,
    /// Serving path (serve/): end-to-end per-request latency, measured from
    /// the client posting an observation to its reply being sent — covers
    /// queueing for a sub-batch slot, the device call, and dispatch. The
    /// serve report's p50/p99 come from here.
    pub request_latency: LatencyHistogram,
    /// Sum over metric vector entries reported by the learner (loss etc.).
    pub last_loss_bits: AtomicU64,
    pub episodes: AtomicU64,
    pub episode_reward_sum_bits: AtomicU64,
    /// Pipeline overlap accounting (DESIGN.md §2), summed over actor
    /// threads: device time spent on this thread's inference calls
    /// (issue → harvest), host time spent stepping its environments
    /// (submission → last worker completion), wall time in the hot loop
    /// (excluding queue backpressure), and the hidden portion
    /// `max(0, device + env − wall)` per thread.
    pub actor_infer_nanos: AtomicU64,
    pub actor_env_nanos: AtomicU64,
    pub actor_loop_nanos: AtomicU64,
    pub actor_overlap_nanos: AtomicU64,
    /// Learner pipeline overlap accounting (DESIGN.md §9), summed over
    /// learner threads: grad-round spans (issue → harvest; includes device
    /// queueing when rounds overlap), host collective time (tree mean +
    /// GradientBus wait), apply spans, active wall time (hot loop minus
    /// queue starvation), and the hidden portion
    /// `max(0, grad + collective + apply − active)` per thread.
    pub learner_grad_nanos: AtomicU64,
    pub learner_collective_nanos: AtomicU64,
    pub learner_apply_nanos: AtomicU64,
    pub learner_active_nanos: AtomicU64,
    pub learner_overlap_nanos: AtomicU64,
    /// Max active wall time over learner threads — the exposed learner
    /// schedule, a critical-path candidate (DESIGN.md §9).
    pub learner_active_max_nanos: AtomicU64,
    /// Threaded-Anakin replica accounting (DESIGN.md §10), summed over
    /// replica threads: device time the replica was exposed to (recv-blocked
    /// harvest spans — at overlap the span covers host work issued under it —
    /// plus replica 0's Psum apply), host conversion + metric accumulation
    /// time, collective time (bus wait + reduction), active wall (loop wall
    /// minus collective wait — waiting on siblings is their deficit), and
    /// the hidden portion `max(0, device + host − active)` per replica.
    pub anakin_device_nanos: AtomicU64,
    pub anakin_host_nanos: AtomicU64,
    pub anakin_collective_nanos: AtomicU64,
    pub anakin_active_nanos: AtomicU64,
    pub anakin_overlap_nanos: AtomicU64,
    /// Max per-replica busy time `min(device + host, active)` — the
    /// post-overlap replica schedule, a critical-path candidate for
    /// `projected_sps` (DESIGN.md §10).
    pub anakin_busy_max_nanos: AtomicU64,
    /// Multi-pod wire accounting (DESIGN.md §15): frames and bytes this
    /// process put on / took off the transport, summed over connections.
    /// Counts full wire frames (header + payload + CRC), so they match
    /// what a network capture would see.
    pub wire_tx_frames: AtomicU64,
    pub wire_rx_frames: AtomicU64,
    pub wire_tx_bytes: AtomicU64,
    pub wire_rx_bytes: AtomicU64,
    /// Elastic membership accounting (DESIGN.md §16): pods admitted and
    /// retired over the run, and the final membership epoch. Static runs
    /// leave all three at 0.
    pub pods_joined: AtomicU64,
    pub pods_evicted: AtomicU64,
    pub membership_epoch: AtomicU64,
}

impl RunStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_update(&self, staleness: u64, loss: f32) {
        self.updates.fetch_add(1, Ordering::Relaxed);
        self.staleness_sum.fetch_add(staleness, Ordering::Relaxed);
        self.last_loss_bits
            .store(loss.to_bits() as u64, Ordering::Relaxed);
    }

    /// One frame sent over the pod-to-pod transport (`n` = wire bytes).
    pub fn record_wire_tx(&self, n: u64) {
        self.wire_tx_frames.fetch_add(1, Ordering::Relaxed);
        self.wire_tx_bytes.fetch_add(n, Ordering::Relaxed);
    }

    /// One frame received over the pod-to-pod transport (`n` = wire bytes).
    pub fn record_wire_rx(&self, n: u64) {
        self.wire_rx_frames.fetch_add(1, Ordering::Relaxed);
        self.wire_rx_bytes.fetch_add(n, Ordering::Relaxed);
    }

    pub fn record_episodes(&self, n: u64, reward_sum: f64) {
        if n == 0 {
            return;
        }
        self.episodes.fetch_add(n, Ordering::Relaxed);
        // accumulate f64 reward via compare-and-swap on bits
        let mut cur = self.episode_reward_sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + reward_sum).to_bits();
            match self.episode_reward_sum_bits.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Record one actor thread's lifetime totals: device-busy, host-env-busy
    /// and hot-loop wall time. The overlapped share is what the pipeline hid
    /// — with `pipeline_stages = 1` the loop is serial and it is ~0.
    pub fn record_actor_overlap(
        &self,
        infer: std::time::Duration,
        env: std::time::Duration,
        loop_wall: std::time::Duration,
    ) {
        let i = infer.as_nanos() as u64;
        let e = env.as_nanos() as u64;
        let w = loop_wall.as_nanos() as u64;
        self.actor_infer_nanos.fetch_add(i, Ordering::Relaxed);
        self.actor_env_nanos.fetch_add(e, Ordering::Relaxed);
        self.actor_loop_nanos.fetch_add(w, Ordering::Relaxed);
        self.actor_overlap_nanos
            .fetch_add((i + e).saturating_sub(w), Ordering::Relaxed);
    }

    /// Record one learner thread's lifetime totals: grad-round spans, host
    /// collective time, apply spans, and active wall time (hot loop minus
    /// time blocked popping trajectory bundles — starvation is the actor
    /// side's deficit). The overlapped share is what the learner pipeline
    /// hid — with `learner_pipeline = 1` the rounds are serial and it is ~0.
    pub fn record_learner_overlap(
        &self,
        grad: std::time::Duration,
        collective: std::time::Duration,
        apply: std::time::Duration,
        active: std::time::Duration,
    ) {
        let g = grad.as_nanos() as u64;
        let c = collective.as_nanos() as u64;
        let a = apply.as_nanos() as u64;
        let w = active.as_nanos() as u64;
        self.learner_grad_nanos.fetch_add(g, Ordering::Relaxed);
        self.learner_collective_nanos.fetch_add(c, Ordering::Relaxed);
        self.learner_apply_nanos.fetch_add(a, Ordering::Relaxed);
        self.learner_active_nanos.fetch_add(w, Ordering::Relaxed);
        self.learner_overlap_nanos
            .fetch_add((g + c + a).saturating_sub(w), Ordering::Relaxed);
        self.learner_active_max_nanos.fetch_max(w, Ordering::Relaxed);
    }

    /// Record one Anakin replica thread's lifetime totals: exposed device
    /// time (recv-blocked harvest spans + replica 0's Psum apply), collective
    /// time (bus wait + reduction), host conversion + metric time, and
    /// active wall (loop wall minus collective wait). The overlapped share
    /// is what the replica schedule hid — the serial driver records one
    /// pseudo-replica whose exposed spans fill its active wall, so it is ~0
    /// there (DESIGN.md §10). The per-replica busy time
    /// `min(device + host, active)` is the post-overlap schedule length;
    /// its max over replicas joins the `projected_sps` critical path.
    pub fn record_anakin_overlap(
        &self,
        device: std::time::Duration,
        collective: std::time::Duration,
        host: std::time::Duration,
        active: std::time::Duration,
    ) {
        let d = device.as_nanos() as u64;
        let c = collective.as_nanos() as u64;
        let h = host.as_nanos() as u64;
        let w = active.as_nanos() as u64;
        self.anakin_device_nanos.fetch_add(d, Ordering::Relaxed);
        self.anakin_collective_nanos.fetch_add(c, Ordering::Relaxed);
        self.anakin_host_nanos.fetch_add(h, Ordering::Relaxed);
        self.anakin_active_nanos.fetch_add(w, Ordering::Relaxed);
        self.anakin_overlap_nanos
            .fetch_add((d + h).saturating_sub(w), Ordering::Relaxed);
        self.anakin_busy_max_nanos
            .fetch_max((d + h).min(w), Ordering::Relaxed);
    }

    pub fn anakin_device_seconds(&self) -> f64 {
        self.anakin_device_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn anakin_collective_seconds(&self) -> f64 {
        self.anakin_collective_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn anakin_host_seconds(&self) -> f64 {
        self.anakin_host_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn anakin_active_seconds(&self) -> f64 {
        self.anakin_active_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn anakin_overlap_seconds(&self) -> f64 {
        self.anakin_overlap_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn anakin_busy_max_seconds(&self) -> f64 {
        self.anakin_busy_max_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn learner_grad_seconds(&self) -> f64 {
        self.learner_grad_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn learner_collective_seconds(&self) -> f64 {
        self.learner_collective_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn learner_apply_seconds(&self) -> f64 {
        self.learner_apply_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn learner_active_seconds(&self) -> f64 {
        self.learner_active_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn learner_overlap_seconds(&self) -> f64 {
        self.learner_overlap_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn learner_active_max_seconds(&self) -> f64 {
        self.learner_active_max_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn actor_infer_seconds(&self) -> f64 {
        self.actor_infer_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn actor_env_seconds(&self) -> f64 {
        self.actor_env_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn actor_loop_seconds(&self) -> f64 {
        self.actor_loop_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn actor_overlap_seconds(&self) -> f64 {
        self.actor_overlap_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn last_loss(&self) -> f32 {
        f32::from_bits(self.last_loss_bits.load(Ordering::Relaxed) as u32)
    }

    /// Completed inference calls (the inference histogram's sample count —
    /// the denominator behind its per-call latency, and the call-count
    /// surface `Report::to_json` exposes to the planner).
    pub fn infer_calls(&self) -> u64 {
        self.inference_latency.count()
    }

    /// Completed learner grad rounds.
    pub fn grad_calls(&self) -> u64 {
        self.grad_latency.count()
    }

    /// Completed apply rounds.
    pub fn apply_calls(&self) -> u64 {
        self.apply_latency.count()
    }

    /// Batched env-step rounds recorded by actor threads.
    pub fn env_step_calls(&self) -> u64 {
        self.env_step_latency.count()
    }

    pub fn mean_staleness(&self) -> f64 {
        let u = self.updates.load(Ordering::Relaxed);
        if u == 0 {
            return 0.0;
        }
        self.staleness_sum.load(Ordering::Relaxed) as f64 / u as f64
    }

    pub fn mean_episode_reward(&self) -> f64 {
        let n = self.episodes.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        f64::from_bits(self.episode_reward_sum_bits.load(Ordering::Relaxed)) / n as f64
    }

    /// Fold a membership snapshot into the counters (learner pod, on every
    /// change): totals are monotone, so plain stores are fine.
    pub fn record_membership(&self, joined: u64, evicted: u64, epoch: u64) {
        self.pods_joined.store(joined, Ordering::Relaxed);
        self.pods_evicted.store(evicted, Ordering::Relaxed);
        self.membership_epoch.store(epoch, Ordering::Relaxed);
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "frames={} fps={:.0} updates={} traj={} staleness={:.2} loss={:.4} episodes={} ep_reward={:.3} | infer p50={:.1}ms grad p50={:.1}ms",
            self.env_frames.frames(),
            self.env_frames.fps(),
            self.updates.load(Ordering::Relaxed),
            self.trajectories.load(Ordering::Relaxed),
            self.mean_staleness(),
            self.last_loss(),
            self.episodes.load(Ordering::Relaxed),
            self.mean_episode_reward(),
            self.inference_latency.percentile_seconds(50.0) * 1e3,
            self.grad_latency.percentile_seconds(50.0) * 1e3,
        );
        let epoch = self.membership_epoch.load(Ordering::Relaxed);
        if epoch > 0 {
            s.push_str(&format!(
                " | membership epoch={} joined={} evicted={}",
                epoch,
                self.pods_joined.load(Ordering::Relaxed),
                self.pods_evicted.load(Ordering::Relaxed),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fps_counts() {
        let m = FpsMeter::new();
        m.add(100);
        m.add(50);
        assert_eq!(m.frames(), 150);
        assert!(m.fps() > 0.0);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        let p50 = h.percentile_seconds(50.0);
        let p95 = h.percentile_seconds(95.0);
        assert!(p50 <= p95);
        assert!(h.mean_seconds() > 0.0);
    }

    #[test]
    fn histogram_merge_folds_counters() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        let whole = LatencyHistogram::new();
        for (i, us) in [3u64, 17, 90, 1500, 40_000].iter().enumerate() {
            let d = Duration::from_micros(*us);
            if i % 2 == 0 { a.record(d) } else { b.record(d) }
            whole.record(d);
        }
        a.merge_from(&b);
        assert_eq!(a.snapshot(), whole.snapshot());
        assert_eq!(a.count(), 5);
        assert_eq!(
            a.percentile_seconds(99.0),
            whole.percentile_seconds(99.0)
        );
    }

    #[test]
    fn histogram_snapshot_is_a_frozen_copy() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(10));
        let snap = h.snapshot();
        h.record(Duration::from_micros(10));
        assert_eq!(snap.count, 1);
        assert_eq!(h.snapshot().count, 2);
        assert_eq!(snap.buckets.iter().sum::<u64>(), 1);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_seconds(99.0), 0.0);
        assert_eq!(h.mean_seconds(), 0.0);
    }

    #[test]
    fn staleness_mean() {
        let s = RunStats::new();
        s.record_update(2, 0.5);
        s.record_update(4, 0.25);
        assert_eq!(s.mean_staleness(), 3.0);
        assert_eq!(s.last_loss(), 0.25);
    }

    #[test]
    fn overlap_is_hidden_work_clamped_at_zero() {
        let s = RunStats::new();
        // serial thread: infer + env == wall -> nothing hidden
        s.record_actor_overlap(
            Duration::from_millis(30),
            Duration::from_millis(70),
            Duration::from_millis(100),
        );
        assert!(s.actor_overlap_seconds() < 1e-9);
        // pipelined thread: 30ms of env stepping ran under the inference
        s.record_actor_overlap(
            Duration::from_millis(60),
            Duration::from_millis(50),
            Duration::from_millis(80),
        );
        assert!((s.actor_overlap_seconds() - 0.030).abs() < 1e-6);
        assert!((s.actor_infer_seconds() - 0.090).abs() < 1e-6);
        assert!((s.actor_env_seconds() - 0.120).abs() < 1e-6);
        assert!((s.actor_loop_seconds() - 0.180).abs() < 1e-6);
    }

    #[test]
    fn learner_overlap_mirrors_actor_accounting() {
        let s = RunStats::new();
        // serial learner: grad + collective + apply fills the active wall
        s.record_learner_overlap(
            Duration::from_millis(40),
            Duration::from_millis(5),
            Duration::from_millis(15),
            Duration::from_millis(60),
        );
        assert!(s.learner_overlap_seconds() < 1e-9);
        // pipelined: 20ms of collective+apply ran under the next round's grads
        s.record_learner_overlap(
            Duration::from_millis(50),
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(60),
        );
        assert!((s.learner_overlap_seconds() - 0.020).abs() < 1e-6);
        assert!((s.learner_grad_seconds() - 0.090).abs() < 1e-6);
        assert!((s.learner_collective_seconds() - 0.015).abs() < 1e-6);
        assert!((s.learner_apply_seconds() - 0.035).abs() < 1e-6);
        assert!((s.learner_active_seconds() - 0.120).abs() < 1e-6);
        // critical-path candidate is the max per-thread active time
        assert!((s.learner_active_max_seconds() - 0.060).abs() < 1e-6);
    }

    #[test]
    fn anakin_overlap_mirrors_learner_accounting() {
        let s = RunStats::new();
        // serial pseudo-replica: exposed device + host + collective fill the wall
        s.record_anakin_overlap(
            Duration::from_millis(70),
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(90),
        );
        assert!(s.anakin_overlap_seconds() < 1e-9);
        // threaded replica: 15ms of metric accumulation ran under the next call
        s.record_anakin_overlap(
            Duration::from_millis(60),
            Duration::from_millis(5),
            Duration::from_millis(15),
            Duration::from_millis(60),
        );
        assert!((s.anakin_overlap_seconds() - 0.015).abs() < 1e-6);
        assert!((s.anakin_device_seconds() - 0.130).abs() < 1e-6);
        assert!((s.anakin_host_seconds() - 0.035).abs() < 1e-6);
        assert!((s.anakin_collective_seconds() - 0.015).abs() < 1e-6);
        assert!((s.anakin_active_seconds() - 0.150).abs() < 1e-6);
        // busy = min(device + host, active); the max over replicas is the
        // critical-path candidate: max(min(90, 90), min(75, 60)) = 90ms
        assert!((s.anakin_busy_max_seconds() - 0.090).abs() < 1e-6);
    }

    #[test]
    fn episode_rewards_accumulate() {
        let s = RunStats::new();
        s.record_episodes(2, 3.0);
        s.record_episodes(1, -1.0);
        assert_eq!(s.episodes.load(Ordering::Relaxed), 3);
        assert!((s.mean_episode_reward() - 2.0 / 3.0).abs() < 1e-9);
    }
}
