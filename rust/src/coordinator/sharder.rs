//! Trajectory sharding: split a batch of trajectories along the batch
//! dimension, one shard per learner core (paper: "splits the batch of
//! trajectories along the batch dimension, sends each shard directly to one
//! of the learners").

use anyhow::{bail, Result};

use super::trajectory::Trajectory;

/// Split `traj` into `n` equal shards along the batch dimension.
/// Requires `traj.batch % n == 0` (the geometry the artifacts were lowered
/// for); the caller picks compatible actor batch / learner counts.
pub fn shard(traj: &Trajectory, n: usize) -> Result<Vec<Trajectory>> {
    if n == 0 {
        bail!("cannot shard into 0 parts");
    }
    if traj.batch % n != 0 {
        bail!("batch {} not divisible by {} learners", traj.batch, n);
    }
    let bs = traj.batch / n; // shard batch
    let d = traj.obs_numel();
    let a = traj.num_actions;
    let t = traj.t_len;

    let mut shards = Vec::with_capacity(n);
    for s in 0..n {
        let col0 = s * bs;
        let mut out = Trajectory {
            t_len: t,
            batch: bs,
            obs_shape: traj.obs_shape.clone(),
            num_actions: a,
            obs: Vec::with_capacity((t + 1) * bs * d),
            actions: Vec::with_capacity(t * bs),
            rewards: Vec::with_capacity(t * bs),
            discounts: Vec::with_capacity(t * bs),
            behaviour_logits: Vec::with_capacity(t * bs * a),
            param_version: traj.param_version,
            actor_id: traj.actor_id,
        };
        // time-major copies: row t, columns [col0, col0+bs)
        for ti in 0..=t {
            let row = ti * traj.batch * d;
            out.obs
                .extend_from_slice(&traj.obs[row + col0 * d..row + (col0 + bs) * d]);
        }
        for ti in 0..t {
            let row = ti * traj.batch;
            out.actions
                .extend_from_slice(&traj.actions[row + col0..row + col0 + bs]);
            out.rewards
                .extend_from_slice(&traj.rewards[row + col0..row + col0 + bs]);
            out.discounts
                .extend_from_slice(&traj.discounts[row + col0..row + col0 + bs]);
            let lrow = ti * traj.batch * a;
            out.behaviour_logits.extend_from_slice(
                &traj.behaviour_logits[lrow + col0 * a..lrow + (col0 + bs) * a],
            );
        }
        shards.push(out);
    }
    Ok(shards)
}

/// Reassemble shards into one trajectory (test/verification helper —
/// the inverse of `shard`).
pub fn unshard(shards: &[Trajectory]) -> Result<Trajectory> {
    if shards.is_empty() {
        bail!("no shards");
    }
    let t = shards[0].t_len;
    let bs = shards[0].batch;
    let d = shards[0].obs_numel();
    let a = shards[0].num_actions;
    let total_b = bs * shards.len();
    let mut out = Trajectory {
        t_len: t,
        batch: total_b,
        obs_shape: shards[0].obs_shape.clone(),
        num_actions: a,
        obs: vec![0.0; (t + 1) * total_b * d],
        actions: vec![0; t * total_b],
        rewards: vec![0.0; t * total_b],
        discounts: vec![0.0; t * total_b],
        behaviour_logits: vec![0.0; t * total_b * a],
        param_version: shards[0].param_version,
        actor_id: shards[0].actor_id,
    };
    for (s, sh) in shards.iter().enumerate() {
        if sh.t_len != t || sh.batch != bs || sh.num_actions != a {
            bail!("inconsistent shard geometry");
        }
        let col0 = s * bs;
        for ti in 0..=t {
            let src = ti * bs * d;
            let dst = ti * total_b * d + col0 * d;
            out.obs[dst..dst + bs * d].copy_from_slice(&sh.obs[src..src + bs * d]);
        }
        for ti in 0..t {
            let src = ti * bs;
            let dst = ti * total_b + col0;
            out.actions[dst..dst + bs].copy_from_slice(&sh.actions[src..src + bs]);
            out.rewards[dst..dst + bs].copy_from_slice(&sh.rewards[src..src + bs]);
            out.discounts[dst..dst + bs].copy_from_slice(&sh.discounts[src..src + bs]);
            let lsrc = ti * bs * a;
            let ldst = ti * total_b * a + col0 * a;
            out.behaviour_logits[ldst..ldst + bs * a]
                .copy_from_slice(&sh.behaviour_logits[lsrc..lsrc + bs * a]);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trajectory::TrajectoryBuilder;

    fn make_traj(t: usize, b: usize, d: usize, a: usize) -> Trajectory {
        let mut builder = TrajectoryBuilder::new(t, b, &[d], a);
        for ti in 0..t {
            let obs: Vec<f32> = (0..b * d).map(|i| (ti * 1000 + i) as f32).collect();
            let actions: Vec<i32> = (0..b).map(|i| (ti + i) as i32).collect();
            let logits: Vec<f32> = (0..b * a).map(|i| (ti * 7 + i) as f32 * 0.1).collect();
            let rewards: Vec<f32> = (0..b).map(|i| i as f32).collect();
            let discounts = vec![0.99; b];
            builder.push_step(&obs, &actions, &logits, &rewards, &discounts).unwrap();
        }
        let final_obs: Vec<f32> = (0..b * d).map(|i| -(i as f32)).collect();
        builder.finish(&final_obs, 3, 0).unwrap()
    }

    #[test]
    fn shard_unshard_roundtrip() {
        let traj = make_traj(4, 6, 3, 2);
        let shards = shard(&traj, 3).unwrap();
        assert_eq!(shards.len(), 3);
        assert!(shards.iter().all(|s| s.batch == 2));
        let back = unshard(&shards).unwrap();
        assert_eq!(back.obs, traj.obs);
        assert_eq!(back.actions, traj.actions);
        assert_eq!(back.rewards, traj.rewards);
        assert_eq!(back.discounts, traj.discounts);
        assert_eq!(back.behaviour_logits, traj.behaviour_logits);
    }

    #[test]
    fn shard_columns_are_contiguous_envs() {
        let traj = make_traj(2, 4, 1, 2);
        let shards = shard(&traj, 2).unwrap();
        // shard 0 gets envs {0,1}: at t=0 obs are [0,1]
        assert_eq!(shards[0].obs[..2], [0.0, 1.0]);
        // shard 1 gets envs {2,3}
        assert_eq!(shards[1].obs[..2], [2.0, 3.0]);
        // actions at t=1 for shard 1: (1+2, 1+3)
        assert_eq!(shards[1].actions[2..], [3, 4]);
    }

    #[test]
    fn indivisible_batch_rejected() {
        let traj = make_traj(2, 5, 1, 2);
        assert!(shard(&traj, 2).is_err());
        assert!(shard(&traj, 0).is_err());
        assert!(shard(&traj, 5).is_ok());
    }

    #[test]
    fn single_shard_is_identity() {
        let traj = make_traj(3, 4, 2, 3);
        let shards = shard(&traj, 1).unwrap();
        assert_eq!(shards[0].obs, traj.obs);
        assert_eq!(shards[0].actions, traj.actions);
    }

    #[test]
    fn metadata_propagates() {
        let traj = make_traj(2, 4, 1, 2);
        let shards = shard(&traj, 2).unwrap();
        assert!(shards.iter().all(|s| s.param_version == 3));
    }
}
