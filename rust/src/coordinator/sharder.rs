//! Trajectory sharding: split a window along the batch dimension, one shard
//! per learner slot (paper: "splits the batch of trajectories along the
//! batch dimension, sends each shard directly to one of the learners").
//!
//! The arena is laid out shard-major ([`TrajArena`]), so [`shard`] is pure
//! pointer arithmetic — each [`TrajShard`] is an `Arc` handle plus a column
//! range, and no experience data moves. [`shard_copying`] is the
//! pre-refactor materializing path, kept as the bit-exactness oracle
//! (DESIGN.md §11): it produces shards with identical contents in freshly
//! copied single-shard arenas, so any divergence between the two paths is a
//! layout bug, not nondeterminism.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::trajectory::{TrajArena, TrajShard, Trajectory};

/// Split the window into its `arena.num_shards` shard views. Zero-copy:
/// every returned shard aliases `arena`'s buffers.
pub fn shard(arena: &Arc<TrajArena>) -> Vec<TrajShard> {
    (0..arena.num_shards).map(|i| TrajShard::new(arena.clone(), i)).collect()
}

/// The copying reference path: materialize each shard's columns into its
/// own single-shard arena (what `shard()` did before the arena refactor).
/// Contents are bitwise identical to the views from [`shard`]; only the
/// backing storage differs. Enabled end-to-end via
/// `SebulbaConfig::copy_path` so the zero-copy path can be pinned against
/// it at fixed seed.
pub fn shard_copying(arena: &Arc<TrajArena>) -> Result<Vec<TrajShard>> {
    (0..arena.num_shards)
        .map(|i| {
            let view = TrajShard::new(arena.clone(), i);
            let copy = TrajArena::from_columns(
                arena.t_len,
                arena.shard_batch(),
                &arena.obs_shape,
                arena.num_actions,
                1,
                view.obs().to_vec(),
                view.actions().to_vec(),
                view.rewards().to_vec(),
                view.discounts().to_vec(),
                view.behaviour_logits().to_vec(),
                arena.param_version,
                arena.actor_id,
            )?;
            Ok(TrajShard::new(copy, 0))
        })
        .collect()
}

/// Reassemble shards into one materialized trajectory (test/verification
/// helper — the inverse of `shard`). Shard `s` supplies the column block
/// `[s * bs, (s + 1) * bs)` of the full window.
pub fn unshard(shards: &[TrajShard]) -> Result<Trajectory> {
    if shards.is_empty() {
        bail!("no shards");
    }
    let t = shards[0].t_len();
    let bs = shards[0].batch();
    let d = shards[0].obs_numel();
    let a = shards[0].num_actions();
    let total_b = bs * shards.len();
    let mut out = Trajectory {
        t_len: t,
        batch: total_b,
        obs_shape: shards[0].arena().obs_shape.clone(),
        num_actions: a,
        obs: vec![0.0; (t + 1) * total_b * d],
        actions: vec![0; t * total_b],
        rewards: vec![0.0; t * total_b],
        discounts: vec![0.0; t * total_b],
        behaviour_logits: vec![0.0; t * total_b * a],
        param_version: shards[0].param_version(),
        actor_id: shards[0].actor_id(),
    };
    for (s, sh) in shards.iter().enumerate() {
        if sh.t_len() != t || sh.batch() != bs || sh.num_actions() != a || sh.obs_numel() != d {
            bail!("inconsistent shard geometry");
        }
        // One decoder for the block layout (`Trajectory::fill_block`),
        // shared with `TrajArena::to_trajectory`.
        out.fill_block(
            s * bs,
            sh.obs(),
            sh.actions(),
            sh.rewards(),
            sh.discounts(),
            sh.behaviour_logits(),
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trajectory::TrajectoryBuilder;

    fn make_arena(t: usize, b: usize, d: usize, a: usize, n: usize) -> Arc<TrajArena> {
        let mut builder = TrajectoryBuilder::new(t, b, &[d], a, n);
        for ti in 0..t {
            let obs: Vec<f32> = (0..b * d).map(|i| (ti * 1000 + i) as f32).collect();
            let actions: Vec<i32> = (0..b).map(|i| (ti + i) as i32).collect();
            let logits: Vec<f32> = (0..b * a).map(|i| (ti * 7 + i) as f32 * 0.1).collect();
            let rewards: Vec<f32> = (0..b).map(|i| i as f32).collect();
            let discounts = vec![0.99; b];
            builder.push_step(&obs, &actions, &logits, &rewards, &discounts).unwrap();
        }
        let final_obs: Vec<f32> = (0..b * d).map(|i| -(i as f32)).collect();
        builder.finish(&final_obs, 3, 0).unwrap()
    }

    #[test]
    fn shard_unshard_roundtrip() {
        let arena = make_arena(4, 6, 3, 2, 3);
        let canonical = arena.to_trajectory();
        let shards = shard(&arena);
        assert_eq!(shards.len(), 3);
        assert!(shards.iter().all(|s| s.batch() == 2));
        let back = unshard(&shards).unwrap();
        assert_eq!(back.obs, canonical.obs);
        assert_eq!(back.actions, canonical.actions);
        assert_eq!(back.rewards, canonical.rewards);
        assert_eq!(back.discounts, canonical.discounts);
        assert_eq!(back.behaviour_logits, canonical.behaviour_logits);
    }

    #[test]
    fn shard_is_copy_free() {
        // The zero-copy invariant: every shard aliases the arena's columns,
        // tiling them end to end without materializing anything.
        let arena = make_arena(2, 4, 1, 2, 2);
        let shards = shard(&arena);
        for (i, s) in shards.iter().enumerate() {
            assert!(Arc::ptr_eq(s.arena(), &arena), "shard {i} rebound its arena");
            assert!(
                std::ptr::eq(s.obs().as_ptr(), arena.obs[i * arena.obs_block()..].as_ptr()),
                "shard {i} copied its obs block"
            );
            assert!(std::ptr::eq(
                s.actions().as_ptr(),
                arena.actions[i * arena.scalar_block()..].as_ptr()
            ));
            assert!(std::ptr::eq(
                s.behaviour_logits().as_ptr(),
                arena.behaviour_logits[i * arena.logit_block()..].as_ptr()
            ));
        }
    }

    #[test]
    fn copying_oracle_matches_views_bitwise() {
        let arena = make_arena(3, 6, 2, 3, 3);
        let views = shard(&arena);
        let copies = shard_copying(&arena).unwrap();
        assert_eq!(views.len(), copies.len());
        for (v, c) in views.iter().zip(&copies) {
            // contents identical...
            assert_eq!(v.obs(), c.obs());
            assert_eq!(v.actions(), c.actions());
            assert_eq!(v.rewards(), c.rewards());
            assert_eq!(v.discounts(), c.discounts());
            assert_eq!(v.behaviour_logits(), c.behaviour_logits());
            assert_eq!(v.param_version(), c.param_version());
            // ...and the grad-program inputs compare equal tensor-for-tensor
            assert_eq!(v.to_tensors().unwrap(), c.to_tensors().unwrap());
            // but the oracle really did copy (fresh storage)
            assert!(!Arc::ptr_eq(v.arena(), c.arena()));
        }
    }

    #[test]
    fn shard_columns_are_contiguous_envs() {
        let arena = make_arena(2, 4, 1, 2, 2);
        let shards = shard(&arena);
        // shard 0 gets envs {0,1}: at t=0 obs are [0,1]
        assert_eq!(shards[0].obs()[..2], [0.0, 1.0]);
        // shard 1 gets envs {2,3}
        assert_eq!(shards[1].obs()[..2], [2.0, 3.0]);
        // actions at t=1 for shard 1: (1+2, 1+3)
        assert_eq!(shards[1].actions()[2..], [3, 4]);
    }

    #[test]
    fn single_shard_is_identity() {
        let arena = make_arena(3, 4, 2, 3, 1);
        let canonical = arena.to_trajectory();
        let shards = shard(&arena);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].obs(), canonical.obs.as_slice());
        assert_eq!(shards[0].actions(), canonical.actions.as_slice());
    }

    #[test]
    fn metadata_propagates() {
        let arena = make_arena(2, 4, 1, 2, 2);
        let shards = shard(&arena);
        assert!(shards.iter().all(|s| s.param_version() == 3));
        let copies = shard_copying(&arena).unwrap();
        assert!(copies.iter().all(|s| s.param_version() == 3));
    }

    #[test]
    fn inconsistent_geometry_rejected_by_unshard() {
        let a1 = make_arena(2, 4, 1, 2, 2);
        let a2 = make_arena(3, 6, 1, 2, 3); // different t_len/bs
        let mixed = vec![shard(&a1).remove(0), shard(&a2).remove(0)];
        assert!(unshard(&mixed).is_err());
        assert!(unshard(&[]).is_err());
    }
}
