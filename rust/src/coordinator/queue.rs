//! Bounded trajectory queue with backpressure — the actor->learner seam.
//!
//! The paper's actors "place the Python reference to this tensor data onto a
//! Python queue"; a bounded queue is what keeps actors from racing ahead of
//! the learner (off-policy staleness control). `push` blocks when full
//! (backpressure), `pop` blocks when empty; both wake on shutdown. Depth and
//! block-time counters feed the run stats.
//!
//! For fault-injection tests the queue can also be *poisoned*
//! ([`BoundedQueue::poison_after_pushes`]): past the trigger, every
//! operation fails with [`QueueError::Poisoned`] — modelling a transport
//! that died mid-run, as opposed to the orderly drain of `shutdown`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    // metrics
    pushed: AtomicU64,
    popped: AtomicU64,
    push_block_nanos: AtomicU64,
    pop_block_nanos: AtomicU64,
}

struct Inner<T> {
    items: VecDeque<T>,
    shutdown: bool,
    poisoned: bool,
    /// Fault injection: poison once `pushed` reaches this count.
    poison_at: Option<u64>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum QueueError {
    Shutdown,
    /// The queue was killed by fault injection — an abrupt transport death,
    /// not an orderly drain. Items still enqueued are lost by design.
    Poisoned,
}

impl std::fmt::Display for QueueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueueError::Shutdown => write!(f, "queue shut down"),
            QueueError::Poisoned => write!(f, "queue poisoned (injected fault)"),
        }
    }
}

impl std::error::Error for QueueError {}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity),
                shutdown: false,
                poisoned: false,
                poison_at: None,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
            pushed: AtomicU64::new(0),
            popped: AtomicU64::new(0),
            push_block_nanos: AtomicU64::new(0),
            pop_block_nanos: AtomicU64::new(0),
        }
    }

    /// Blocking push (backpressure). Errors only on shutdown. Blocked time
    /// is recorded on the shutdown exit too: a producer parked on a full
    /// queue until teardown was still backpressured, and dropping that span
    /// would undercount `push_block_seconds` at exactly the moment the
    /// run's totals are read (same undercount class as `pop_timeout`'s
    /// timeout path, fixed in PR 2).
    pub fn push(&self, item: T) -> Result<(), QueueError> {
        let t0 = Instant::now();
        let mut g = self.inner.lock().unwrap();
        while g.items.len() >= self.capacity && !g.shutdown && !g.poisoned {
            g = self.not_full.wait(g).unwrap();
        }
        if g.poisoned {
            self.push_block_nanos
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            return Err(QueueError::Poisoned);
        }
        if g.shutdown {
            self.push_block_nanos
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            return Err(QueueError::Shutdown);
        }
        g.items.push_back(item);
        let total = self.pushed.fetch_add(1, Ordering::Relaxed) + 1;
        if g.poison_at.is_some_and(|at| total >= at) {
            g.poisoned = true;
            drop(g);
            self.push_block_nanos
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            self.not_full.notify_all();
            self.not_empty.notify_all();
            // the triggering push itself still succeeded
            return Ok(());
        }
        self.push_block_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop. Errors on shutdown *after* the queue is drained, so
    /// in-flight work is not lost. Starvation time is recorded on the
    /// shutdown exit too (see `push` — the teardown undercount class).
    pub fn pop(&self) -> Result<T, QueueError> {
        let t0 = Instant::now();
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.poisoned {
                // abrupt transport death: remaining items are lost, unlike
                // the drain-first shutdown path below
                self.pop_block_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                return Err(QueueError::Poisoned);
            }
            if let Some(item) = g.items.pop_front() {
                self.popped.fetch_add(1, Ordering::Relaxed);
                self.pop_block_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                drop(g);
                self.not_full.notify_one();
                return Ok(item);
            }
            if g.shutdown {
                self.pop_block_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                return Err(QueueError::Shutdown);
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with a timeout; `Ok(None)` on timeout. Blocked time is recorded
    /// on every exit path — item, timeout *and* shutdown: a timed-out or
    /// torn-down wait is still consumer starvation, and dropping it would
    /// silently undercount `pop_block_seconds` for any timeout-polling
    /// consumer (the pipelined learner's bundle prefetch).
    pub fn pop_timeout(&self, dur: Duration) -> Result<Option<T>, QueueError> {
        let t0 = Instant::now();
        let deadline = t0 + dur;
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.poisoned {
                self.pop_block_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                return Err(QueueError::Poisoned);
            }
            if let Some(item) = g.items.pop_front() {
                self.popped.fetch_add(1, Ordering::Relaxed);
                self.pop_block_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                drop(g);
                self.not_full.notify_one();
                return Ok(Some(item));
            }
            if g.shutdown {
                self.pop_block_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                return Err(QueueError::Shutdown);
            }
            let now = Instant::now();
            if now >= deadline {
                self.pop_block_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                return Ok(None);
            }
            let (guard, _timeout) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Wake all blocked producers/consumers with a shutdown error.
    pub fn shutdown(&self) {
        let mut g = self.inner.lock().unwrap();
        g.shutdown = true;
        drop(g);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Fault injection: poison the queue as soon as `total_pushed` reaches
    /// `n` (immediately, if it already has). Past the trigger every push and
    /// pop fails with [`QueueError::Poisoned`] and any enqueued items are
    /// lost — an abrupt transport death for resilience tests, never used on
    /// the production path.
    pub fn poison_after_pushes(&self, n: u64) {
        let mut g = self.inner.lock().unwrap();
        if self.pushed.load(Ordering::Relaxed) >= n {
            g.poisoned = true;
            drop(g);
            self.not_full.notify_all();
            self.not_empty.notify_all();
        } else {
            g.poison_at = Some(n);
        }
    }

    pub fn is_poisoned(&self) -> bool {
        self.inner.lock().unwrap().poisoned
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn total_pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }

    pub fn total_popped(&self) -> u64 {
        self.popped.load(Ordering::Relaxed)
    }

    /// Cumulative seconds producers spent blocked in push (backpressure).
    pub fn push_block_seconds(&self) -> f64 {
        self.push_block_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Cumulative seconds consumers spent blocked in pop (starvation).
    pub fn pop_block_seconds(&self) -> f64 {
        self.pop_block_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop().unwrap(), i);
        }
    }

    #[test]
    fn capacity_blocks_producer() {
        let q = Arc::new(BoundedQueue::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.push(3));
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(q.len(), 2, "producer should be blocked at capacity");
        assert_eq!(q.pop().unwrap(), 1);
        t.join().unwrap().unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn pop_blocks_until_push() {
        let q = Arc::new(BoundedQueue::new(2));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop().unwrap());
        std::thread::sleep(Duration::from_millis(20));
        q.push(42).unwrap();
        assert_eq!(t.join().unwrap(), 42);
    }

    #[test]
    fn shutdown_wakes_everyone() {
        let q = Arc::new(BoundedQueue::<i32>::new(1));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        let q3 = q.clone();
        q3.push(1).unwrap();
        let q4 = q.clone();
        let producer = std::thread::spawn(move || {
            // queue is full after the consumer takes one and we re-fill:
            let _ = q4.push(2);
            q4.push(3) // will block until shutdown
        });
        std::thread::sleep(Duration::from_millis(30));
        q.shutdown();
        let c = consumer.join().unwrap();
        assert!(c.is_ok()); // got item 1
        let p = producer.join().unwrap();
        assert_eq!(p, Err(QueueError::Shutdown));
    }

    #[test]
    fn pop_drains_after_shutdown() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.shutdown();
        assert_eq!(q.pop().unwrap(), 1);
        assert_eq!(q.pop().unwrap(), 2);
        assert_eq!(q.pop(), Err(QueueError::Shutdown));
    }

    #[test]
    fn pop_timeout_returns_none() {
        let q: BoundedQueue<i32> = BoundedQueue::new(1);
        let r = q.pop_timeout(Duration::from_millis(10)).unwrap();
        assert!(r.is_none());
    }

    #[test]
    fn pop_timeout_records_block_time_on_timeout() {
        // Regression: the timeout path used to drop its blocked time, so
        // timeout-polling consumers undercounted starvation.
        let q: BoundedQueue<i32> = BoundedQueue::new(1);
        let r = q.pop_timeout(Duration::from_millis(30)).unwrap();
        assert!(r.is_none());
        assert!(
            q.pop_block_seconds() >= 0.025,
            "timed-out wait not counted: {}s",
            q.pop_block_seconds()
        );
    }

    #[test]
    fn pop_timeout_records_block_time_on_item() {
        let q = Arc::new(BoundedQueue::<i32>::new(1));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_timeout(Duration::from_millis(500)));
        std::thread::sleep(Duration::from_millis(30));
        q.push(7).unwrap();
        assert_eq!(t.join().unwrap().unwrap(), Some(7));
        // No wall-clock lower bound: on a loaded host the popper may only
        // enter pop_timeout after the push landed. Any positive value is
        // the regression signal — the old item path recorded nothing.
        assert!(
            q.pop_block_seconds() > 0.0,
            "blocked wait before the item landed not counted"
        );
    }

    /// Flag-then-sleep: the spawned thread raises `entered` immediately
    /// before its blocking queue call, and the test sleeps only after
    /// seeing it — so the measured block span can't be cut short by the
    /// thread getting scheduled late on a loaded host.
    fn await_entry(entered: &std::sync::atomic::AtomicBool) {
        use std::sync::atomic::Ordering;
        while !entered.load(Ordering::Acquire) {
            std::thread::yield_now();
        }
        std::thread::sleep(Duration::from_millis(40));
    }

    #[test]
    fn push_records_block_time_on_shutdown() {
        // Regression (ISSUE 4): the shutdown exit used to drop the
        // producer's accumulated backpressure time, undercounting
        // push_block_seconds at teardown.
        use std::sync::atomic::{AtomicBool, Ordering};
        let q = Arc::new(BoundedQueue::new(1));
        q.push(1).unwrap();
        let entered = Arc::new(AtomicBool::new(false));
        let (q2, e2) = (q.clone(), entered.clone());
        let producer = std::thread::spawn(move || {
            e2.store(true, Ordering::Release);
            q2.push(2)
        });
        await_entry(&entered);
        q.shutdown();
        assert_eq!(producer.join().unwrap(), Err(QueueError::Shutdown));
        assert!(
            q.push_block_seconds() >= 0.025,
            "blocked push torn down without recording: {}s",
            q.push_block_seconds()
        );
    }

    #[test]
    fn pop_records_block_time_on_shutdown() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let q = Arc::new(BoundedQueue::<i32>::new(1));
        let entered = Arc::new(AtomicBool::new(false));
        let (q2, e2) = (q.clone(), entered.clone());
        let consumer = std::thread::spawn(move || {
            e2.store(true, Ordering::Release);
            q2.pop()
        });
        await_entry(&entered);
        q.shutdown();
        assert_eq!(consumer.join().unwrap(), Err(QueueError::Shutdown));
        assert!(
            q.pop_block_seconds() >= 0.025,
            "blocked pop torn down without recording: {}s",
            q.pop_block_seconds()
        );
    }

    #[test]
    fn pop_timeout_records_block_time_on_shutdown() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let q = Arc::new(BoundedQueue::<i32>::new(1));
        let entered = Arc::new(AtomicBool::new(false));
        let (q2, e2) = (q.clone(), entered.clone());
        let consumer = std::thread::spawn(move || {
            e2.store(true, Ordering::Release);
            q2.pop_timeout(Duration::from_millis(2000))
        });
        await_entry(&entered);
        q.shutdown();
        assert_eq!(consumer.join().unwrap(), Err(QueueError::Shutdown));
        assert!(
            q.pop_block_seconds() >= 0.025,
            "timed pop torn down without recording: {}s",
            q.pop_block_seconds()
        );
    }

    #[test]
    fn poison_trips_at_the_push_count() {
        let q = BoundedQueue::new(8);
        q.poison_after_pushes(3);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap(); // the trigger push itself succeeds
        assert!(q.is_poisoned());
        assert_eq!(q.push(4), Err(QueueError::Poisoned));
        // abrupt death: enqueued items are lost, unlike shutdown's drain
        assert_eq!(q.pop(), Err(QueueError::Poisoned));
        assert_eq!(q.pop_timeout(Duration::from_millis(5)), Err(QueueError::Poisoned));
    }

    #[test]
    fn poison_wakes_blocked_consumer() {
        let q = Arc::new(BoundedQueue::<i32>::new(1));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.poison_after_pushes(0); // already reached: poison now
        assert_eq!(consumer.join().unwrap(), Err(QueueError::Poisoned));
    }

    #[test]
    fn counters_track_traffic() {
        let q = BoundedQueue::new(8);
        for i in 0..6 {
            q.push(i).unwrap();
        }
        for _ in 0..4 {
            q.pop().unwrap();
        }
        assert_eq!(q.total_pushed(), 6);
        assert_eq!(q.total_popped(), 4);
        assert_eq!(q.len(), 2);
    }
}
