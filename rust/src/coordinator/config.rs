//! The resolved Sebulba run configuration.
//!
//! Since the `experiment` API landed (DESIGN.md §12) this is an *internal*
//! resolved form: `experiment::Experiment` merges a [`super::Sebulba`]
//! workload with a [`Topology`] into one `SebulbaConfig` before spawning
//! anything (the legacy entrypoints that accepted it directly are gone —
//! their one-PR deprecation window closed). `runner()`/`topology()` split
//! it back — the round-trip is pinned by tests below.

use anyhow::{bail, Result};

use crate::experiment::{EnvKind, Topology, ONE_POD};

use super::sebulba::Sebulba;

#[derive(Clone, Debug, PartialEq)]
pub struct SebulbaConfig {
    /// Agent tag in the artifact manifest (e.g. "seb_catch", "seb_atari").
    pub agent: String,
    /// Host environment kind (typed — see `experiment::EnvKind`;
    /// `envs::make_factory` is infallible given one).
    pub env_kind: EnvKind,
    /// Actor cores per replica (paper: `A`).
    pub actor_cores: usize,
    /// Learner cores per replica (paper: `8 - A`).
    pub learner_cores: usize,
    /// Python-thread analogue: actor threads per actor core (paper: ≥1 to
    /// hide env stepping behind device compute).
    pub threads_per_actor_core: usize,
    /// Environments per actor thread (the "actor batch size" of Fig 4b).
    pub actor_batch: usize,
    /// Sub-batches each actor thread round-robins through the infer→step
    /// cycle (the paper: actors "split their batch of environments in two"
    /// so the device runs one half's inference while the host steps the
    /// other half — DESIGN.md §2). 1 = fully synchronous (the pre-pipeline
    /// schedule, bit-for-bit); 2 = double-buffered (default).
    pub pipeline_stages: usize,
    /// Grad/apply rounds the learner keeps in flight — the learner-side
    /// analogue of `pipeline_stages` (DESIGN.md §9). While round k runs the
    /// host-side collective and the apply program, round k+1's grad
    /// programs are already executing on the learner cores against the
    /// pre-apply parameter snapshot. 1 = the serial pop→grad→reduce→apply
    /// schedule (bit-for-bit the pre-pipeline learner); 2 = double-buffered
    /// (default). Each extra level costs one update of gradient staleness.
    pub learner_pipeline: usize,
    /// Trajectory length T (paper: 20 IMPALA, 60 Sebulba).
    pub unroll: usize,
    /// Split each trajectory into `micro_batches` sequential updates
    /// (the MuZero "N updates instead of a single larger one" trick).
    pub micro_batches: usize,
    /// Discount factor (must match the lowered loss config).
    pub discount: f32,
    /// Trajectory-queue capacity per replica (backpressure bound).
    pub queue_capacity: usize,
    /// Worker threads in the shared env-stepping pool, per replica.
    pub env_workers: usize,
    /// Replicas (each gets its own actor/learner cores + host state; the
    /// cross-replica gradient mean runs on the GradientBus).
    pub replicas: usize,
    /// Stop after this many learner updates per replica.
    pub total_updates: u64,
    pub seed: u64,
    /// Use the materializing (pre-refactor) sharder instead of zero-copy
    /// arena views — kept as the bit-exactness oracle for the arena data
    /// path (DESIGN.md §11), mirroring Anakin's `--driver serial` oracle.
    /// Identical results, strictly more host copies; default `false`.
    pub copy_path: bool,
}

impl Default for SebulbaConfig {
    fn default() -> Self {
        Self {
            agent: "seb_catch".into(),
            env_kind: EnvKind::Catch,
            actor_cores: 2,
            learner_cores: 2,
            threads_per_actor_core: 2,
            actor_batch: 32,
            pipeline_stages: 2,
            learner_pipeline: 2,
            unroll: 20,
            micro_batches: 1,
            discount: 0.99,
            queue_capacity: 4,
            env_workers: 2,
            replicas: 1,
            total_updates: 50,
            seed: 42,
            copy_path: false,
        }
    }
}

impl SebulbaConfig {
    pub fn cores_per_replica(&self) -> usize {
        self.actor_cores + self.learner_cores
    }

    pub fn total_cores(&self) -> usize {
        self.cores_per_replica() * self.replicas
    }

    /// The core-split half of this config, as the experiment API's typed
    /// [`Topology`].
    pub fn topology(&self) -> Topology {
        Topology {
            actor_cores: self.actor_cores,
            learner_cores: self.learner_cores,
            replicas: self.replicas,
            threads_per_actor_core: self.threads_per_actor_core,
            pipeline_stages: self.pipeline_stages,
            learner_pipeline: self.learner_pipeline,
            env_workers: self.env_workers,
            queue_capacity: self.queue_capacity,
            pods: ONE_POD,
        }
    }

    /// The workload half of this config, as the [`Sebulba`] runner.
    /// `runner().resolved(&topology())` reproduces `self` exactly.
    pub fn runner(&self) -> Sebulba {
        Sebulba {
            agent: self.agent.clone(),
            env_kind: self.env_kind,
            actor_batch: self.actor_batch,
            unroll: self.unroll,
            micro_batches: self.micro_batches,
            discount: self.discount,
            total_updates: self.total_updates,
            seed: self.seed,
            copy_path: self.copy_path,
            warm_start: None,
        }
    }

    /// Environments per pipeline stage: what one inference call batches and
    /// one trajectory window covers.
    pub fn stage_batch(&self) -> usize {
        self.actor_batch / self.pipeline_stages
    }

    /// Learner-shard batch size (what the grad program was lowered for).
    /// Each stage's trajectory is sharded independently, so the shard is a
    /// fraction of the *stage* batch, not the full actor batch.
    pub fn shard_batch(&self) -> usize {
        self.stage_batch() / (self.learner_cores * self.micro_batches)
    }

    /// Inference programs are shape-specialized per batch; the pipelined
    /// actor infers one stage at a time, so the program is lowered for the
    /// stage batch.
    pub fn infer_program(&self) -> String {
        format!("{}_infer_b{}", self.agent, self.stage_batch())
    }

    pub fn grad_program(&self) -> String {
        format!("{}_grad_t{}_b{}", self.agent, self.unroll, self.shard_batch())
    }

    pub fn apply_program(&self) -> String {
        format!("{}_apply", self.agent)
    }

    pub fn init_program(&self) -> String {
        format!("{}_init", self.agent)
    }

    pub fn validate(&self) -> Result<()> {
        // structural checks are shared with every architecture through the
        // topology; the geometry below is Sebulba-specific
        self.topology().validate()?;
        self.topology().require_split()?;
        if self.micro_batches == 0 {
            bail!("micro_batches must be >= 1");
        }
        if self.actor_batch % self.pipeline_stages != 0 {
            bail!(
                "actor_batch {} must divide into pipeline_stages = {}",
                self.actor_batch,
                self.pipeline_stages
            );
        }
        let shards = self.learner_cores * self.micro_batches;
        if self.stage_batch() % shards != 0 {
            bail!(
                "stage batch {} (actor_batch {} / pipeline_stages {}) must divide into \
                 learner_cores*micro_batches = {}",
                self.stage_batch(),
                self.actor_batch,
                self.pipeline_stages,
                shards
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        SebulbaConfig::default().validate().unwrap();
    }

    #[test]
    fn program_names() {
        let cfg = SebulbaConfig {
            agent: "seb_atari".into(),
            actor_batch: 64,
            pipeline_stages: 1,
            unroll: 60,
            learner_cores: 4,
            ..Default::default()
        };
        assert_eq!(cfg.infer_program(), "seb_atari_infer_b64");
        assert_eq!(cfg.grad_program(), "seb_atari_grad_t60_b16");
        assert_eq!(cfg.apply_program(), "seb_atari_apply");
    }

    #[test]
    fn pipeline_stages_shrink_the_infer_and_grad_geometry() {
        // Double-buffering infers one sub-batch at a time, so both the
        // inference batch and the learner shard halve.
        let cfg = SebulbaConfig {
            agent: "seb_atari".into(),
            actor_batch: 64,
            pipeline_stages: 2,
            unroll: 60,
            learner_cores: 4,
            ..Default::default()
        };
        cfg.validate().unwrap();
        assert_eq!(cfg.stage_batch(), 32);
        assert_eq!(cfg.infer_program(), "seb_atari_infer_b32");
        assert_eq!(cfg.grad_program(), "seb_atari_grad_t60_b8");
    }

    #[test]
    fn learner_pipeline_is_geometry_neutral() {
        // Pipelined rounds reuse the same grad/apply programs — depth only
        // changes the schedule, never the lowered shapes, so no new AOT
        // variants are needed.
        let serial = SebulbaConfig { learner_pipeline: 1, ..Default::default() };
        let piped = SebulbaConfig { learner_pipeline: 2, ..Default::default() };
        piped.validate().unwrap();
        assert_eq!(serial.grad_program(), piped.grad_program());
        assert_eq!(serial.apply_program(), piped.apply_program());
        assert_eq!(serial.infer_program(), piped.infer_program());
        assert_eq!(serial.shard_batch(), piped.shard_batch());
    }

    #[test]
    fn copy_path_is_geometry_neutral() {
        // The copying oracle changes only the host-side storage strategy:
        // same lowered programs, same shard geometry, still valid.
        let arena = SebulbaConfig::default();
        let copy = SebulbaConfig { copy_path: true, ..Default::default() };
        copy.validate().unwrap();
        assert_eq!(arena.grad_program(), copy.grad_program());
        assert_eq!(arena.infer_program(), copy.infer_program());
        assert_eq!(arena.shard_batch(), copy.shard_batch());
    }

    #[test]
    fn micro_batches_shrink_shards() {
        let cfg = SebulbaConfig {
            actor_batch: 32,
            pipeline_stages: 1,
            learner_cores: 2,
            micro_batches: 2,
            ..Default::default()
        };
        assert_eq!(cfg.shard_batch(), 8);
        cfg.validate().unwrap();
    }

    #[test]
    fn invalid_configs_rejected() {
        let bad = SebulbaConfig { actor_batch: 30, learner_cores: 4, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SebulbaConfig { learner_cores: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SebulbaConfig { actor_cores: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SebulbaConfig { threads_per_actor_core: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SebulbaConfig { pipeline_stages: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SebulbaConfig { learner_pipeline: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SebulbaConfig { replicas: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SebulbaConfig { queue_capacity: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SebulbaConfig { env_workers: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        // 32 envs cannot split into 3 equal stages
        let bad = SebulbaConfig { pipeline_stages: 3, ..Default::default() };
        assert!(bad.validate().is_err());
        // stage batch 8 cannot shard over 16 learner slots
        let bad = SebulbaConfig {
            pipeline_stages: 4,
            learner_cores: 4,
            micro_batches: 4,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn runner_topology_roundtrip_is_lossless() {
        // The experiment API splits a resolved config into (workload,
        // topology) and re-merges at run time; every field must survive.
        let cfg = SebulbaConfig {
            agent: "seb_atari".into(),
            env_kind: EnvKind::AtariLike,
            actor_cores: 1,
            learner_cores: 4,
            threads_per_actor_core: 3,
            actor_batch: 64,
            pipeline_stages: 2,
            learner_pipeline: 1,
            unroll: 60,
            micro_batches: 2,
            discount: 0.95,
            queue_capacity: 7,
            env_workers: 5,
            replicas: 2,
            total_updates: 9,
            seed: 1234,
            copy_path: true,
        };
        assert_eq!(cfg.runner().resolved(&cfg.topology()), cfg);
    }
}
