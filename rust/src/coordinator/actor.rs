//! Actor threads: the Sebulba experience generators, pipelined.
//!
//! Each actor thread owns `pipeline_stages` sub-batches of environments and
//! talks to one actor core (several threads may share a core — the paper's
//! GIL-hiding trick). Within a thread the sub-batches round-robin through
//! the infer→step cycle: while the core runs inference on sub-batch *k*,
//! the worker pool steps sub-batch *k−1*'s environments on the host, so env
//! latency hides behind device time (the paper: actors "split their batch
//! of environments in two"; schedule diagram in DESIGN.md §2).
//!
//! The batch-assembly/infer/dispatch cycle itself is generic over a
//! [`BatchSource`] (DESIGN.md §14): the loop owns the device side —
//! parameter refresh, async program launch, harvest, latency accounting —
//! and the source owns where observations come from and where actions go.
//! [`EnvPoolSource`] is the training implementation (env pool + trajectory
//! windows, bit-identical to the pre-seam actor); `serve::SessionSource`
//! feeds the same loop from live client sessions instead.
//!
//! With `pipeline_stages = 1` the loop degenerates to the fully synchronous
//! schedule (infer, step, accumulate — bit-for-bit the pre-pipeline actor).
//! Each stage accumulates its own window directly into an `Arc`-shared
//! [`TrajArena`] (shard-major, DESIGN.md §11); after T steps the stage's
//! window is sharded into zero-copy [`TrajShard`] views and queued for the
//! learners. Observation and parameter uploads are `Arc`-backed too, so the
//! whole actor→device seam moves references, not buffers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::checkpoint::ActorSection;
use crate::envs::{BatchedEnv, EnvFactory, StepTicket, WorkerPool};
use crate::runtime::tensor::HostTensor;
use crate::runtime::DeviceHandle;
use crate::util::rng::Xoshiro256;

use super::param_store::ParamStore;
use super::queue::BoundedQueue;
use super::sharder::{shard, shard_copying};
use super::stats::RunStats;
use super::trajectory::{TrajShard, TrajectoryBuilder};

/// A bundle of shards from one trajectory window: `micro_batches` rounds of
/// `learner_cores` shards each (see learner.rs). Shards are arena views —
/// pushing a bundle moves `Arc` handles, never experience data.
pub type ShardBundle = Vec<TrajShard>;

/// Deposit slot for actor boundary snapshots, keyed by `windows_done`.
/// A `BTreeMap` (not a single cell) because under checkpoint pacing the
/// actor may deposit window W+1's snapshot while the learner is still
/// between publishing round W and reading the slot — a lone cell could be
/// overwritten before the learner takes it.
pub type SnapshotSlot = Arc<Mutex<BTreeMap<u64, ActorSection>>>;

/// Checkpoint/restore wiring for one actor thread (DESIGN.md §13).
///
/// Lockstep contract: with this present the actor starts a trajectory
/// window only once `store.version() == windows_done` — i.e. the learner
/// has published every update of the previous window — which pins the
/// params each inference sees to exactly what the uninterrupted run's
/// actor would have seen. That is only sound when one window maps to one
/// learner round and nothing is pipelined; the coordinator enforces the
/// topology restrictions (`run_resolved`) before handing this out.
#[derive(Clone)]
pub struct ActorCheckpoint {
    /// Deposit a snapshot at every `every`-th window boundary.
    pub every: u64,
    /// Shared slot the learner reads when it writes the checkpoint file.
    pub slot: SnapshotSlot,
    /// Boundary state to resume from (None = fresh start).
    pub resume: Option<ActorSection>,
}

pub struct ActorConfig {
    pub actor_id: usize,
    /// Total environments owned by this thread (all stages together).
    pub batch: usize,
    /// Sub-batches round-robining through the infer→step cycle (>= 1).
    pub pipeline_stages: usize,
    pub unroll: usize,
    pub discount: f32,
    pub num_shards: usize,
    /// Inference program lowered for the *stage* batch (batch / stages).
    pub infer_program: String,
    pub obs_shape: Vec<usize>,
    pub num_actions: usize,
    pub seed: u64,
    /// Use the materializing (pre-refactor) sharder instead of arena views
    /// — the bit-exactness oracle for the zero-copy path (DESIGN.md §11).
    pub copy_path: bool,
    /// Checkpoint/restore wiring; None on plain runs.
    pub checkpoint: Option<ActorCheckpoint>,
}

/// Spawn an actor thread. It runs until `stop` is set or the queue shuts
/// down, then exits cleanly.
#[allow(clippy::too_many_arguments)]
pub fn spawn_actor(
    cfg: ActorConfig,
    core: DeviceHandle,
    factory: Arc<EnvFactory>,
    pool: Arc<WorkerPool>,
    store: Arc<ParamStore>,
    queue: Arc<BoundedQueue<ShardBundle>>,
    stats: Arc<RunStats>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<Result<()>> {
    std::thread::Builder::new()
        .name(format!("actor-{}", cfg.actor_id))
        .spawn(move || actor_main(cfg, core, factory, pool, store, queue, stats, stop))
        .expect("spawn actor thread")
}

/// What the source wants the loop to do after a hook returns: keep cycling,
/// or tear down cleanly (trajectory queue shut down, all sessions drained,
/// stop observed mid-gate — an `Ok(())` exit either way).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SourceStatus {
    Continue,
    Shutdown,
}

/// Where a batch of observations comes from and where its actions go — the
/// seam that lets the training env pool and the serving session frontend
/// share one infer loop (DESIGN.md §14).
///
/// [`run_infer_loop`] drives a source through the Sebulba schedule. With
/// sub-batches `0..stages()`, the contract per tick `t` (`s = t % stages`,
/// `s2 = (t+1) % stages`) is:
///
/// ```text
/// prime()                      once, before the first launch
/// launch(0)                    device: infer sub-batch 0
/// loop: harvest(s)             device actions/logits for sub-batch s
///       dispatch(s, ..)        source consumes them (non-blocking)
///       advance(s2)            source readies sub-batch s2's next obs
///                              (may block: env step / waiting for requests)
///       launch(s2)             device: infer sub-batch s2
/// ```
///
/// So `advance(s2)` runs while no inference is in flight for `s2` but the
/// other sub-batches' work is — that is where env stepping (or request
/// assembly) hides behind device time. Slot identity is the source's
/// business: the loop never inspects the batch beyond its flat length.
pub trait BatchSource {
    /// Number of sub-batches round-robining through the cycle (>= 1).
    fn stages(&self) -> usize;

    /// Called once before sub-batch 0's first launch. The env pool gates
    /// the first trajectory window here (checkpoint lockstep); the session
    /// source blocks until the first request arrives.
    fn prime(&mut self) -> Result<SourceStatus>;

    /// Sub-batch `s`'s current observations, flat `[slots * obs_dim]` —
    /// the next inference's input. `Arc`-shared so the device upload
    /// references it without copying.
    fn obs(&mut self, s: usize) -> Arc<Vec<f32>>;

    /// Consume sub-batch `s`'s harvested inference outputs. Must not
    /// block: anything slow belongs in `advance` where it can overlap the
    /// other sub-batches' device time. `param_version` is the store
    /// version the producing inference ran with (serve replies carry it;
    /// training stamps windows from `store.version()` instead).
    fn dispatch(
        &mut self,
        s: usize,
        actions: Vec<i32>,
        logits: Vec<f32>,
        param_version: u64,
        acc: &mut OverlapAcc,
    ) -> Result<()>;

    /// Bring sub-batch `s` to its next inference point: finish its
    /// outstanding env step and accumulate the transition (env pool), or
    /// retire/admit sessions and assemble pending requests (serve). `rng`
    /// is the loop's seed stream, read-only — the env pool snapshots its
    /// state at checkpointed window boundaries.
    fn advance(&mut self, s: usize, rng: &Xoshiro256, acc: &mut OverlapAcc)
        -> Result<SourceStatus>;
}

/// An in-flight inference on the actor core.
struct PendingInfer {
    rx: mpsc::Receiver<Result<Vec<HostTensor>>>,
    issued: Instant,
    /// Store version of the params this inference ran with.
    param_version: u64,
}

/// Per-thread overlap accumulators, flushed to `RunStats` on exit. Public
/// (with the loop) so out-of-module `BatchSource` impls can account their
/// host-side work into the same pipeline-overlap model.
#[derive(Default)]
pub struct OverlapAcc {
    pub infer_busy: Duration,
    pub env_busy: Duration,
    pub queue_blocked: Duration,
    /// Env construction + reset before the first tick — not hot-loop time.
    pub setup: Duration,
}

/// Device-side geometry for [`run_infer_loop`] — everything the loop needs
/// that is not the source's business.
pub struct InferLoopConfig {
    /// Names the device-resident parameter slot (`params#<id>`); unique
    /// per thread sharing a core.
    pub actor_id: usize,
    /// Inference program lowered for one sub-batch's slot count.
    pub infer_program: String,
    /// Upload shape of one sub-batch's observations: `[slots, obs...]`.
    pub batch_shape: Vec<usize>,
}

/// Fire an inference for sub-batch `s`: refresh parameters ("switch to the
/// latest parameters before each new inference step") only when a new
/// version was actually published (`latest_if_newer` — the no-news case is
/// one atomic load), then launch the infer program without waiting.
#[allow(clippy::too_many_arguments)]
fn launch_infer<S: BatchSource>(
    source: &mut S,
    s: usize,
    cfg: &InferLoopConfig,
    core: &DeviceHandle,
    store: &ParamStore,
    param_slot: &str,
    cached_version: &mut u64,
    rng: &mut Xoshiro256,
    pending: &mut [Option<PendingInfer>],
) -> Result<()> {
    // Device-resident parameter cache: parameters are uploaded to the actor
    // core once per published version and referenced by slot on every
    // inference call — the paper's "parameters stay on device" (§Perf L3-1).
    // The upload itself references the `ParamSnapshot`'s Arc'd buffer
    // (DESIGN.md §11), so no host-side copy is made either.
    if let Some(snap) = store.latest_if_newer(*cached_version) {
        core.cache(
            param_slot,
            HostTensor::f32_shared(vec![snap.params.len()], snap.params.clone(), 0)?,
        )?;
        *cached_version = snap.version;
    }
    let inputs = vec![
        HostTensor::f32_shared(cfg.batch_shape.clone(), source.obs(s), 0)?,
        HostTensor::scalar_i32(rng.next_program_seed()),
    ];
    let rx = core.execute_cached_async(&cfg.infer_program, inputs, vec![(0, param_slot.to_string())])?;
    pending[s] = Some(PendingInfer {
        rx,
        issued: Instant::now(),
        param_version: *cached_version,
    });
    Ok(())
}

/// The generic batch-assembly/infer/dispatch loop (schedule in the
/// [`BatchSource`] doc). Runs until `stop` is set or the source reports
/// `Shutdown`. One `rng.next_program_seed()` is consumed per launch, so
/// the seed stream — and with a frozen store, every device output — is a
/// pure function of the launch order.
#[allow(clippy::too_many_arguments)]
pub fn run_infer_loop<S: BatchSource>(
    cfg: &InferLoopConfig,
    core: &DeviceHandle,
    store: &ParamStore,
    stats: &RunStats,
    stop: &AtomicBool,
    rng: &mut Xoshiro256,
    source: &mut S,
    acc: &mut OverlapAcc,
) -> Result<()> {
    let stages = source.stages();
    anyhow::ensure!(stages >= 1, "batch source must have at least one sub-batch");
    let param_slot = format!("params#{}", cfg.actor_id);
    let mut cached_version = u64::MAX; // sentinel: first launch always uploads
    let mut pending: Vec<Option<PendingInfer>> = (0..stages).map(|_| None).collect();

    // Prologue: prime the pipeline with sub-batch 0's first inference.
    if matches!(source.prime()?, SourceStatus::Shutdown) {
        return Ok(());
    }
    launch_infer(source, 0, cfg, core, store, &param_slot, &mut cached_version, rng, &mut pending)?;

    let mut tick: usize = 0;
    while !stop.load(Ordering::Relaxed) {
        let s = tick % stages;

        // 1) Harvest sub-batch s's inference: the device has (or is
        //    finishing) its actions.
        let p = pending[s]
            .take()
            .expect("pipeline invariant: current sub-batch has an in-flight inference");
        let outs = p
            .rx
            .recv()
            .map_err(|_| anyhow::anyhow!("actor core {} died", core.core_id))?
            .context("batch inference")?;
        let span = p.issued.elapsed();
        acc.infer_busy += span;
        stats.inference_latency.record(span);
        let actions = outs[0].as_i32()?.to_vec();
        let logits = outs[1].as_f32()?.to_vec();

        // 2) Hand the outputs to the source — non-blocking (env stepping is
        //    submitted async; serve replies are channel sends).
        source.dispatch(s, actions, logits, p.param_version, acc)?;

        // 3) Rotate to the next sub-batch: let the source finish its
        //    outstanding work (it ran under sub-batch s's inference) and
        //    ready its next observations, then fire its next inference.
        let s2 = (tick + 1) % stages;
        if matches!(source.advance(s2, rng, acc)?, SourceStatus::Shutdown) {
            return Ok(());
        }
        launch_infer(source, s2, cfg, core, store, &param_slot, &mut cached_version, rng, &mut pending)?;

        tick += 1;
    }
    Ok(())
}

/// One pipeline stage: a sub-batch of environments plus everything needed
/// to carry its infer→step cycle and trajectory window independently.
struct Stage {
    env: BatchedEnv,
    /// Latest observation `[b * obs_dim]` — the next inference's input.
    /// `Arc`-shared so the upload references it without cloning; by the
    /// time the env ticket writes the buffer again, the device core has
    /// long dropped its handle, so `Arc::make_mut` is a plain `&mut` in
    /// steady state (and a safe copy-on-write in the worst case).
    obs: Arc<Vec<f32>>,
    /// Observation the most recent inference saw (trajectory `obs_t`).
    prev_obs: Arc<Vec<f32>>,
    actions: Vec<i32>,
    logits: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    discounts: Vec<f32>,
    episode_reward: Vec<f64>,
    builder: TrajectoryBuilder,
    step: Option<StepTicket>,
}

/// The training [`BatchSource`]: sub-batches of pooled environments whose
/// transitions accumulate into trajectory windows for the learner queue.
/// Construction does everything up to (not including) the first inference:
/// validation, env building/reset, checkpoint resume — and hands back the
/// seed stream (fresh or restored) the loop must run with.
pub struct EnvPoolSource<'a> {
    cfg: &'a ActorConfig,
    store: &'a ParamStore,
    queue: &'a BoundedQueue<ShardBundle>,
    stats: &'a RunStats,
    stop: &'a AtomicBool,
    stages: Vec<Stage>,
    /// Envs per stage (`cfg.batch / cfg.pipeline_stages`).
    sb: usize,
    windows_done: u64,
}

impl<'a> EnvPoolSource<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: &'a ActorConfig,
        factory: &EnvFactory,
        pool: &Arc<WorkerPool>,
        store: &'a ParamStore,
        queue: &'a BoundedQueue<ShardBundle>,
        stats: &'a RunStats,
        stop: &'a AtomicBool,
    ) -> Result<(Self, Xoshiro256)> {
        let stages_n = cfg.pipeline_stages;
        anyhow::ensure!(stages_n >= 1, "pipeline_stages must be >= 1");
        anyhow::ensure!(
            cfg.batch % stages_n == 0,
            "actor batch {} must divide into {} pipeline stages",
            cfg.batch,
            stages_n
        );
        let sb = cfg.batch / stages_n; // envs per stage
        anyhow::ensure!(
            cfg.num_shards >= 1 && sb % cfg.num_shards == 0,
            "stage batch {sb} must divide into {} shards",
            cfg.num_shards
        );
        if cfg.checkpoint.is_some() {
            // lockstep pacing is only sound unpipelined (see ActorCheckpoint)
            anyhow::ensure!(
                stages_n == 1,
                "checkpointed runs require pipeline_stages == 1 (got {stages_n})"
            );
        }
        let d: usize = cfg.obs_shape.iter().product();
        let a = cfg.num_actions;
        let mut rng = Xoshiro256::from_stream(cfg.seed, cfg.actor_id as u64);

        let mut stages: Vec<Stage> = (0..stages_n)
            .map(|s| -> Result<Stage> {
                let env = BatchedEnv::with_slot_offset(factory, sb, s * sb, pool.clone())
                    .with_context(|| format!("building batched env (stage {s})"))?;
                let mut obs = vec![0.0f32; sb * d];
                env.reset(&mut obs).with_context(|| format!("resetting envs (stage {s})"))?;
                Ok(Stage {
                    env,
                    obs: Arc::new(obs),
                    prev_obs: Arc::new(vec![0.0; sb * d]),
                    actions: vec![0; sb],
                    logits: vec![0.0; sb * a],
                    rewards: vec![0.0; sb],
                    dones: vec![false; sb],
                    discounts: vec![0.0; sb],
                    episode_reward: vec![0.0; sb],
                    builder: TrajectoryBuilder::new(cfg.unroll, sb, &cfg.obs_shape, a, cfg.num_shards),
                    step: None,
                })
            })
            .collect::<Result<_>>()?;

        // Resume: overwrite the fresh stage with the checkpointed boundary
        // state — envs, bootstrap observation, RNG stream and window counter
        // — so the next window is produced exactly as the uninterrupted
        // run's.
        let mut windows_done: u64 = 0;
        if let Some(res) = cfg.checkpoint.as_ref().and_then(|ck| ck.resume.as_ref()) {
            let stage = &mut stages[0];
            anyhow::ensure!(
                res.obs.len() == sb * d,
                "checkpoint observation has {} floats, actor expects {}",
                res.obs.len(),
                sb * d
            );
            anyhow::ensure!(
                res.episode_reward.len() == sb,
                "checkpoint tracks {} episode returns, actor has {} envs",
                res.episode_reward.len(),
                sb
            );
            stage.env.load_states(&res.env_states).context("restoring env states")?;
            stage.obs = Arc::new(res.obs.clone());
            stage.episode_reward = res.episode_reward.iter().map(|&x| x as f64).collect();
            rng = Xoshiro256::from_state(res.rng);
            windows_done = res.windows_done;
        }

        Ok((
            Self { cfg, store, queue, stats, stop, stages, sb, windows_done },
            rng,
        ))
    }

    /// Lockstep gate (checkpoint/restore runs only): block the start of a
    /// new window until the learner has published everything from the last
    /// one, so every inference sees exactly the params the uninterrupted
    /// run's would. `Shutdown` if the run is tearing down.
    fn window_gate(&self) -> SourceStatus {
        if self.cfg.checkpoint.is_none() {
            return SourceStatus::Continue;
        }
        loop {
            if self.store.version() >= self.windows_done {
                return SourceStatus::Continue;
            }
            if self.stop.load(Ordering::Relaxed) {
                return SourceStatus::Shutdown;
            }
            std::thread::yield_now();
        }
    }
}

impl BatchSource for EnvPoolSource<'_> {
    fn stages(&self) -> usize {
        self.stages.len()
    }

    fn prime(&mut self) -> Result<SourceStatus> {
        Ok(self.window_gate())
    }

    fn obs(&mut self, s: usize) -> Arc<Vec<f32>> {
        self.stages[s].obs.clone()
    }

    fn dispatch(
        &mut self,
        s: usize,
        actions: Vec<i32>,
        logits: Vec<f32>,
        _param_version: u64,
        _acc: &mut OverlapAcc,
    ) -> Result<()> {
        // Start stepping sub-batch s on the host — non-blocking, so the
        // pool works while the device serves the next sub-batch.
        let stage = &mut self.stages[s];
        stage.actions = actions;
        stage.logits = logits;
        std::mem::swap(&mut stage.prev_obs, &mut stage.obs);
        stage.step = Some(stage.env.step_async(&stage.actions));
        Ok(())
    }

    fn advance(
        &mut self,
        s: usize,
        rng: &Xoshiro256,
        acc: &mut OverlapAcc,
    ) -> Result<SourceStatus> {
        // Finish this sub-batch's outstanding env step (it ran under the
        // previous sub-batch's inference) and account the transition.
        let cfg = self.cfg;
        let sb = self.sb;
        let mut window_finished = false;
        let stage = &mut self.stages[s];
        if let Some(ticket) = stage.step.take() {
            let span = ticket
                .wait(Arc::make_mut(&mut stage.obs), &mut stage.rewards, &mut stage.dones)
                .context("stepping environments")?;
            acc.env_busy += span;
            self.stats.env_step_latency.record(span);

            // bookkeeping + accumulate
            let mut ended = 0u64;
            let mut ended_reward = 0.0f64;
            for i in 0..sb {
                stage.episode_reward[i] += stage.rewards[i] as f64;
                if stage.dones[i] {
                    ended += 1;
                    ended_reward += stage.episode_reward[i];
                    stage.episode_reward[i] = 0.0;
                    stage.discounts[i] = 0.0;
                } else {
                    stage.discounts[i] = cfg.discount;
                }
            }
            self.stats.record_episodes(ended, ended_reward);
            stage.builder.push_step(
                &stage.prev_obs,
                &stage.actions,
                &stage.logits,
                &stage.rewards,
                &stage.discounts,
            )?;

            // Window full: finish with the bootstrap obs, shard, enqueue.
            // The arena moves as Arc views; the copy path is the oracle.
            if stage.builder.is_full() {
                let version = self.store.version();
                let arena = stage.builder.finish(&stage.obs, version, cfg.actor_id)?;
                self.stats.env_frames.add(arena.frames() as u64);
                self.stats.trajectories.fetch_add(1, Ordering::Relaxed);
                let shards = if cfg.copy_path { shard_copying(&arena)? } else { shard(&arena) };
                self.windows_done += 1;
                // Deposit-before-push (DESIGN.md §13): the snapshot must be
                // in the slot before the learner can possibly retire this
                // window's round and go looking for it. The env is quiescent
                // here — the step ticket was waited above and the next
                // inference has not been launched.
                if let Some(ck) = &cfg.checkpoint {
                    if self.windows_done % ck.every == 0 {
                        let snap = ActorSection {
                            windows_done: self.windows_done,
                            rng: rng.state(),
                            obs: stage.obs.to_vec(),
                            episode_reward: stage
                                .episode_reward
                                .iter()
                                .map(|&x| x as f32)
                                .collect(),
                            env_states: stage.env.save_states(),
                        };
                        ck.slot.lock().unwrap().insert(self.windows_done, snap);
                    }
                }
                let t_push = Instant::now();
                let pushed = self.queue.push(shards);
                acc.queue_blocked += t_push.elapsed();
                if pushed.is_err() {
                    return Ok(SourceStatus::Shutdown); // queue shut down: clean exit
                }
                window_finished = true;
            }
        }
        // A new window starts with the next inference: under checkpoint
        // pacing, hold it until the learner catches up (see window_gate).
        if window_finished {
            return Ok(self.window_gate());
        }
        Ok(SourceStatus::Continue)
    }
}

#[allow(clippy::too_many_arguments)]
fn actor_main(
    cfg: ActorConfig,
    core: DeviceHandle,
    factory: Arc<EnvFactory>,
    pool: Arc<WorkerPool>,
    store: Arc<ParamStore>,
    queue: Arc<BoundedQueue<ShardBundle>>,
    stats: Arc<RunStats>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut acc = OverlapAcc::default();
    let loop_start = Instant::now();
    let result = (|| -> Result<()> {
        let setup_start = Instant::now();
        let (mut source, mut rng) =
            EnvPoolSource::new(&cfg, &factory, &pool, &store, &queue, &stats, &stop)?;
        let mut batch_shape = vec![source.sb];
        batch_shape.extend_from_slice(&cfg.obs_shape);
        let loop_cfg = InferLoopConfig {
            actor_id: cfg.actor_id,
            infer_program: cfg.infer_program.clone(),
            batch_shape,
        };
        acc.setup = setup_start.elapsed();
        run_infer_loop(&loop_cfg, &core, &store, &stats, &stop, &mut rng, &mut source, &mut acc)
    })();
    // Wall time excludes setup (env construction) and backpressure
    // (blocking on a full trajectory queue is the learner's deficit, not
    // the pipeline's).
    let wall = loop_start
        .elapsed()
        .saturating_sub(acc.queue_blocked)
        .saturating_sub(acc.setup);
    stats.record_actor_overlap(acc.infer_busy, acc.env_busy, wall);
    result
}
