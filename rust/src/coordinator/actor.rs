//! Actor threads: the Sebulba experience generators, pipelined.
//!
//! Each actor thread owns `pipeline_stages` sub-batches of environments and
//! talks to one actor core (several threads may share a core — the paper's
//! GIL-hiding trick). Within a thread the sub-batches round-robin through
//! the infer→step cycle: while the core runs inference on sub-batch *k*,
//! the worker pool steps sub-batch *k−1*'s environments on the host, so env
//! latency hides behind device time (the paper: actors "split their batch
//! of environments in two"; schedule diagram in DESIGN.md §2).
//!
//! With `pipeline_stages = 1` the loop degenerates to the fully synchronous
//! schedule (infer, step, accumulate — bit-for-bit the pre-pipeline actor).
//! Each stage accumulates its own window directly into an `Arc`-shared
//! [`TrajArena`] (shard-major, DESIGN.md §11); after T steps the stage's
//! window is sharded into zero-copy [`TrajShard`] views and queued for the
//! learners. Observation and parameter uploads are `Arc`-backed too, so the
//! whole actor→device seam moves references, not buffers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::checkpoint::ActorSection;
use crate::envs::{BatchedEnv, EnvFactory, StepTicket, WorkerPool};
use crate::runtime::tensor::HostTensor;
use crate::runtime::DeviceHandle;

use super::param_store::ParamStore;
use super::queue::BoundedQueue;
use super::sharder::{shard, shard_copying};
use super::stats::RunStats;
use super::trajectory::{TrajShard, TrajectoryBuilder};

/// A bundle of shards from one trajectory window: `micro_batches` rounds of
/// `learner_cores` shards each (see learner.rs). Shards are arena views —
/// pushing a bundle moves `Arc` handles, never experience data.
pub type ShardBundle = Vec<TrajShard>;

/// Deposit slot for actor boundary snapshots, keyed by `windows_done`.
/// A `BTreeMap` (not a single cell) because under checkpoint pacing the
/// actor may deposit window W+1's snapshot while the learner is still
/// between publishing round W and reading the slot — a lone cell could be
/// overwritten before the learner takes it.
pub type SnapshotSlot = Arc<Mutex<BTreeMap<u64, ActorSection>>>;

/// Checkpoint/restore wiring for one actor thread (DESIGN.md §13).
///
/// Lockstep contract: with this present the actor starts a trajectory
/// window only once `store.version() == windows_done` — i.e. the learner
/// has published every update of the previous window — which pins the
/// params each inference sees to exactly what the uninterrupted run's
/// actor would have seen. That is only sound when one window maps to one
/// learner round and nothing is pipelined; the coordinator enforces the
/// topology restrictions (`run_resolved`) before handing this out.
#[derive(Clone)]
pub struct ActorCheckpoint {
    /// Deposit a snapshot at every `every`-th window boundary.
    pub every: u64,
    /// Shared slot the learner reads when it writes the checkpoint file.
    pub slot: SnapshotSlot,
    /// Boundary state to resume from (None = fresh start).
    pub resume: Option<ActorSection>,
}

pub struct ActorConfig {
    pub actor_id: usize,
    /// Total environments owned by this thread (all stages together).
    pub batch: usize,
    /// Sub-batches round-robining through the infer→step cycle (>= 1).
    pub pipeline_stages: usize,
    pub unroll: usize,
    pub discount: f32,
    pub num_shards: usize,
    /// Inference program lowered for the *stage* batch (batch / stages).
    pub infer_program: String,
    pub obs_shape: Vec<usize>,
    pub num_actions: usize,
    pub seed: u64,
    /// Use the materializing (pre-refactor) sharder instead of arena views
    /// — the bit-exactness oracle for the zero-copy path (DESIGN.md §11).
    pub copy_path: bool,
    /// Checkpoint/restore wiring; None on plain runs.
    pub checkpoint: Option<ActorCheckpoint>,
}

/// Spawn an actor thread. It runs until `stop` is set or the queue shuts
/// down, then exits cleanly.
#[allow(clippy::too_many_arguments)]
pub fn spawn_actor(
    cfg: ActorConfig,
    core: DeviceHandle,
    factory: Arc<EnvFactory>,
    pool: Arc<WorkerPool>,
    store: Arc<ParamStore>,
    queue: Arc<BoundedQueue<ShardBundle>>,
    stats: Arc<RunStats>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<Result<()>> {
    std::thread::Builder::new()
        .name(format!("actor-{}", cfg.actor_id))
        .spawn(move || actor_main(cfg, core, factory, pool, store, queue, stats, stop))
        .expect("spawn actor thread")
}

/// An in-flight inference on the actor core.
struct PendingInfer {
    rx: mpsc::Receiver<Result<Vec<HostTensor>>>,
    issued: Instant,
}

/// One pipeline stage: a sub-batch of environments plus everything needed
/// to carry its infer→step cycle and trajectory window independently.
struct Stage {
    env: BatchedEnv,
    /// Latest observation `[b * obs_dim]` — the next inference's input.
    /// `Arc`-shared so the upload references it without cloning; by the
    /// time the env ticket writes the buffer again, the device core has
    /// long dropped its handle, so `Arc::make_mut` is a plain `&mut` in
    /// steady state (and a safe copy-on-write in the worst case).
    obs: Arc<Vec<f32>>,
    /// Observation the most recent inference saw (trajectory `obs_t`).
    prev_obs: Arc<Vec<f32>>,
    actions: Vec<i32>,
    logits: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    discounts: Vec<f32>,
    episode_reward: Vec<f64>,
    builder: TrajectoryBuilder,
    infer: Option<PendingInfer>,
    step: Option<StepTicket>,
}

/// Per-thread overlap accumulators, flushed to `RunStats` on exit.
#[derive(Default)]
struct OverlapAcc {
    infer_busy: Duration,
    env_busy: Duration,
    queue_blocked: Duration,
    /// Env construction + reset before the first tick — not hot-loop time.
    setup: Duration,
}

#[allow(clippy::too_many_arguments)]
fn actor_main(
    cfg: ActorConfig,
    core: DeviceHandle,
    factory: Arc<EnvFactory>,
    pool: Arc<WorkerPool>,
    store: Arc<ParamStore>,
    queue: Arc<BoundedQueue<ShardBundle>>,
    stats: Arc<RunStats>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut acc = OverlapAcc::default();
    let loop_start = Instant::now();
    let result = actor_loop(&cfg, &core, &factory, &pool, &store, &queue, &stats, &stop, &mut acc);
    // Wall time excludes setup (env construction) and backpressure
    // (blocking on a full trajectory queue is the learner's deficit, not
    // the pipeline's).
    let wall = loop_start
        .elapsed()
        .saturating_sub(acc.queue_blocked)
        .saturating_sub(acc.setup);
    stats.record_actor_overlap(acc.infer_busy, acc.env_busy, wall);
    result
}

#[allow(clippy::too_many_arguments)]
fn actor_loop(
    cfg: &ActorConfig,
    core: &DeviceHandle,
    factory: &EnvFactory,
    pool: &Arc<WorkerPool>,
    store: &ParamStore,
    queue: &BoundedQueue<ShardBundle>,
    stats: &RunStats,
    stop: &AtomicBool,
    acc: &mut OverlapAcc,
) -> Result<()> {
    let setup_start = Instant::now();
    let stages_n = cfg.pipeline_stages;
    anyhow::ensure!(stages_n >= 1, "pipeline_stages must be >= 1");
    anyhow::ensure!(
        cfg.batch % stages_n == 0,
        "actor batch {} must divide into {} pipeline stages",
        cfg.batch,
        stages_n
    );
    let sb = cfg.batch / stages_n; // envs per stage
    anyhow::ensure!(
        cfg.num_shards >= 1 && sb % cfg.num_shards == 0,
        "stage batch {sb} must divide into {} shards",
        cfg.num_shards
    );
    if cfg.checkpoint.is_some() {
        // lockstep pacing is only sound unpipelined (see ActorCheckpoint)
        anyhow::ensure!(
            stages_n == 1,
            "checkpointed runs require pipeline_stages == 1 (got {stages_n})"
        );
    }
    let d: usize = cfg.obs_shape.iter().product();
    let a = cfg.num_actions;
    let mut rng = crate::util::rng::Xoshiro256::from_stream(cfg.seed, cfg.actor_id as u64);

    let mut stages: Vec<Stage> = (0..stages_n)
        .map(|s| -> Result<Stage> {
            let env = BatchedEnv::with_slot_offset(factory, sb, s * sb, pool.clone())
                .with_context(|| format!("building batched env (stage {s})"))?;
            let mut obs = vec![0.0f32; sb * d];
            env.reset(&mut obs).with_context(|| format!("resetting envs (stage {s})"))?;
            Ok(Stage {
                env,
                obs: Arc::new(obs),
                prev_obs: Arc::new(vec![0.0; sb * d]),
                actions: vec![0; sb],
                logits: vec![0.0; sb * a],
                rewards: vec![0.0; sb],
                dones: vec![false; sb],
                discounts: vec![0.0; sb],
                episode_reward: vec![0.0; sb],
                builder: TrajectoryBuilder::new(cfg.unroll, sb, &cfg.obs_shape, a, cfg.num_shards),
                infer: None,
                step: None,
            })
        })
        .collect::<Result<_>>()?;

    // Resume: overwrite the fresh stage with the checkpointed boundary
    // state — envs, bootstrap observation, RNG stream and window counter —
    // so the next window is produced exactly as the uninterrupted run's.
    let mut windows_done: u64 = 0;
    if let Some(res) = cfg.checkpoint.as_ref().and_then(|ck| ck.resume.as_ref()) {
        let stage = &mut stages[0];
        anyhow::ensure!(
            res.obs.len() == sb * d,
            "checkpoint observation has {} floats, actor expects {}",
            res.obs.len(),
            sb * d
        );
        anyhow::ensure!(
            res.episode_reward.len() == sb,
            "checkpoint tracks {} episode returns, actor has {} envs",
            res.episode_reward.len(),
            sb
        );
        stage.env.load_states(&res.env_states).context("restoring env states")?;
        stage.obs = Arc::new(res.obs.clone());
        stage.episode_reward = res.episode_reward.iter().map(|&x| x as f64).collect();
        rng = crate::util::rng::Xoshiro256::from_state(res.rng);
        windows_done = res.windows_done;
    }

    // Device-resident parameter cache: parameters are uploaded to the actor
    // core once per published version and referenced by slot on every
    // inference call — the paper's "parameters stay on device" (§Perf L3-1).
    // The upload itself references the `ParamSnapshot`'s Arc'd buffer
    // (DESIGN.md §11), so no host-side copy is made either.
    let param_slot = format!("params#{}", cfg.actor_id);
    let mut cached_version = u64::MAX;

    let mut stage_batch_shape = vec![sb];
    stage_batch_shape.extend_from_slice(&cfg.obs_shape);

    // Launch an inference for `stage`: refresh parameters ("switch to the
    // latest parameters before each new inference step"), then fire the
    // infer program without waiting.
    let launch_infer = |stage: &mut Stage,
                            rng: &mut crate::util::rng::Xoshiro256,
                            cached_version: &mut u64|
     -> Result<()> {
        let snap = store.latest();
        if snap.version != *cached_version {
            core.cache(
                &param_slot,
                HostTensor::f32_shared(vec![snap.params.len()], snap.params.clone(), 0)?,
            )?;
            *cached_version = snap.version;
        }
        let inputs = vec![
            HostTensor::f32_shared(stage_batch_shape.clone(), stage.obs.clone(), 0)?,
            HostTensor::scalar_i32(rng.next_program_seed()),
        ];
        let rx = core.execute_cached_async(
            &cfg.infer_program,
            inputs,
            vec![(0, param_slot.clone())],
        )?;
        stage.infer = Some(PendingInfer { rx, issued: Instant::now() });
        Ok(())
    };

    acc.setup = setup_start.elapsed();

    // Lockstep gate (checkpoint/restore runs only): block the start of a
    // new window until the learner has published everything from the last
    // one, so every inference sees exactly the params the uninterrupted
    // run's would. Returns false if the run is tearing down.
    let window_gate = |windows_done: u64| -> bool {
        if cfg.checkpoint.is_none() {
            return true;
        }
        loop {
            if store.version() >= windows_done {
                return true;
            }
            if stop.load(Ordering::Relaxed) {
                return false;
            }
            std::thread::yield_now();
        }
    };

    // Prologue: prime the pipeline with stage 0's first inference.
    if !window_gate(windows_done) {
        return Ok(());
    }
    launch_infer(&mut stages[0], &mut rng, &mut cached_version)?;

    let mut tick: usize = 0;
    while !stop.load(Ordering::Relaxed) {
        let s = tick % stages_n;

        // 1) Harvest stage s's inference: the device has (or is finishing)
        //    its actions.
        {
            let stage = &mut stages[s];
            let pending = stage
                .infer
                .take()
                .expect("pipeline invariant: current stage has an in-flight inference");
            let outs = pending
                .rx
                .recv()
                .map_err(|_| anyhow::anyhow!("actor core {} died", core.core_id))?
                .context("actor inference")?;
            let span = pending.issued.elapsed();
            acc.infer_busy += span;
            stats.inference_latency.record(span);
            stage.actions = outs[0].as_i32()?.to_vec();
            stage.logits = outs[1].as_f32()?.to_vec();

            // 2) Start stepping stage s on the host — non-blocking, so the
            //    pool works while the device serves the next stage.
            std::mem::swap(&mut stage.prev_obs, &mut stage.obs);
            stage.step = Some(stage.env.step_async(&stage.actions));
        }

        // 3) Rotate to the next stage: finish its outstanding env step (it
        //    ran under stage s's inference), account the transition, and
        //    fire its next inference.
        let s2 = (tick + 1) % stages_n;
        let mut window_finished = false;
        let stage = &mut stages[s2];
        if let Some(ticket) = stage.step.take() {
            let span = ticket
                .wait(Arc::make_mut(&mut stage.obs), &mut stage.rewards, &mut stage.dones)
                .context("stepping environments")?;
            acc.env_busy += span;
            stats.env_step_latency.record(span);

            // 4) bookkeeping + accumulate
            let mut ended = 0u64;
            let mut ended_reward = 0.0f64;
            for i in 0..sb {
                stage.episode_reward[i] += stage.rewards[i] as f64;
                if stage.dones[i] {
                    ended += 1;
                    ended_reward += stage.episode_reward[i];
                    stage.episode_reward[i] = 0.0;
                    stage.discounts[i] = 0.0;
                } else {
                    stage.discounts[i] = cfg.discount;
                }
            }
            stats.record_episodes(ended, ended_reward);
            stage.builder.push_step(
                &stage.prev_obs,
                &stage.actions,
                &stage.logits,
                &stage.rewards,
                &stage.discounts,
            )?;

            // 5) window full: finish with the bootstrap obs, shard, enqueue.
            //    The arena moves as Arc views; the copy path is the oracle.
            if stage.builder.is_full() {
                let version = store.version();
                let arena = stage.builder.finish(&stage.obs, version, cfg.actor_id)?;
                stats.env_frames.add(arena.frames() as u64);
                stats.trajectories.fetch_add(1, Ordering::Relaxed);
                let shards = if cfg.copy_path { shard_copying(&arena)? } else { shard(&arena) };
                windows_done += 1;
                // Deposit-before-push (DESIGN.md §13): the snapshot must be
                // in the slot before the learner can possibly retire this
                // window's round and go looking for it. The env is quiescent
                // here — the step ticket was waited above and the next
                // inference has not been launched.
                if let Some(ck) = &cfg.checkpoint {
                    if windows_done % ck.every == 0 {
                        let snap = ActorSection {
                            windows_done,
                            rng: rng.state(),
                            obs: stage.obs.to_vec(),
                            episode_reward: stage
                                .episode_reward
                                .iter()
                                .map(|&x| x as f32)
                                .collect(),
                            env_states: stage.env.save_states(),
                        };
                        ck.slot.lock().unwrap().insert(windows_done, snap);
                    }
                }
                let t_push = Instant::now();
                let pushed = queue.push(shards);
                acc.queue_blocked += t_push.elapsed();
                if pushed.is_err() {
                    return Ok(()); // queue shut down: clean exit
                }
                window_finished = true;
            }
        }
        // A new window starts with the next inference: under checkpoint
        // pacing, hold it until the learner catches up (see window_gate).
        if window_finished && !window_gate(windows_done) {
            return Ok(());
        }
        launch_infer(stage, &mut rng, &mut cached_version)?;

        tick += 1;
    }
    Ok(())
}
