//! Actor threads: the Sebulba experience generators.
//!
//! Each actor thread owns a batched environment and talks to one actor core
//! (several threads may share a core — the paper's GIL-hiding trick: while
//! one thread steps its environments, the core runs another thread's
//! inference). Per step: grab the latest parameters, run batched inference
//! on the core, step the batched env, accumulate the trajectory; after T
//! steps, shard along the batch dimension and queue the bundle for the
//! learners.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::envs::{BatchedEnv, EnvFactory, WorkerPool};
use crate::runtime::tensor::HostTensor;
use crate::runtime::DeviceHandle;

use super::param_store::ParamStore;
use super::queue::BoundedQueue;
use super::sharder::shard;
use super::stats::RunStats;
use super::trajectory::{Trajectory, TrajectoryBuilder};

/// A bundle of shards from one trajectory window: `micro_batches` rounds of
/// `learner_cores` shards each (see learner.rs).
pub type ShardBundle = Vec<Trajectory>;

pub struct ActorConfig {
    pub actor_id: usize,
    pub batch: usize,
    pub unroll: usize,
    pub discount: f32,
    pub num_shards: usize,
    pub infer_program: String,
    pub obs_shape: Vec<usize>,
    pub num_actions: usize,
    pub seed: u64,
}

/// Spawn an actor thread. It runs until `stop` is set or the queue shuts
/// down, then exits cleanly.
#[allow(clippy::too_many_arguments)]
pub fn spawn_actor(
    cfg: ActorConfig,
    core: DeviceHandle,
    factory: Arc<EnvFactory>,
    pool: Arc<WorkerPool>,
    store: Arc<ParamStore>,
    queue: Arc<BoundedQueue<ShardBundle>>,
    stats: Arc<RunStats>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<Result<()>> {
    std::thread::Builder::new()
        .name(format!("actor-{}", cfg.actor_id))
        .spawn(move || actor_main(cfg, core, factory, pool, store, queue, stats, stop))
        .expect("spawn actor thread")
}

#[allow(clippy::too_many_arguments)]
fn actor_main(
    cfg: ActorConfig,
    core: DeviceHandle,
    factory: Arc<EnvFactory>,
    pool: Arc<WorkerPool>,
    store: Arc<ParamStore>,
    queue: Arc<BoundedQueue<ShardBundle>>,
    stats: Arc<RunStats>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let b = cfg.batch;
    let d: usize = cfg.obs_shape.iter().product();
    let a = cfg.num_actions;
    let mut rng = crate::util::rng::Xoshiro256::from_stream(cfg.seed, cfg.actor_id as u64);

    let env = BatchedEnv::new(&factory, b, pool).context("building batched env")?;
    let mut obs = vec![0.0f32; b * d];
    env.reset(&mut obs);

    let mut builder = TrajectoryBuilder::new(cfg.unroll, b, &cfg.obs_shape, a);
    let mut rewards = vec![0.0f32; b];
    let mut dones = vec![false; b];
    let mut discounts = vec![0.0f32; b];
    let mut episode_reward = vec![0.0f64; b];

    // Device-resident parameter cache: parameters are uploaded to the actor
    // core once per published version and referenced by slot on every
    // inference call — the paper's "parameters stay on device" (§Perf L3-1).
    let param_slot = format!("params#{}", cfg.actor_id);
    let mut cached_version = u64::MAX;

    let mut obs_batch_shape = vec![b];
    obs_batch_shape.extend_from_slice(&cfg.obs_shape);

    while !stop.load(Ordering::Relaxed) {
        for _t in 0..cfg.unroll {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            // 1) latest parameters ("switch to the latest parameters before
            //    each new inference step")
            let snap = store.latest();
            if snap.version != cached_version {
                core.cache(
                    &param_slot,
                    HostTensor::f32(vec![snap.params.len()], snap.params.clone())?,
                )?;
                cached_version = snap.version;
            }

            // 2) batched inference on the actor core
            let t0 = Instant::now();
            let inputs = vec![
                HostTensor::f32(obs_batch_shape.clone(), obs.clone())?,
                HostTensor::scalar_i32(rng.next_program_seed()),
            ];
            let outs = core
                .execute_cached(&cfg.infer_program, inputs, vec![(0, param_slot.clone())])
                .context("actor inference")?;
            stats.inference_latency.record(t0.elapsed());
            let actions = outs[0].as_i32()?.to_vec();
            let logits = outs[1].as_f32()?.to_vec();

            // 3) step the batched environment on the host
            let t1 = Instant::now();
            let prev_obs = obs.clone();
            env.step(&actions, &mut obs, &mut rewards, &mut dones);
            stats.env_step_latency.record(t1.elapsed());

            // 4) bookkeeping + accumulate
            let mut ended = 0u64;
            let mut ended_reward = 0.0f64;
            for i in 0..b {
                episode_reward[i] += rewards[i] as f64;
                if dones[i] {
                    ended += 1;
                    ended_reward += episode_reward[i];
                    episode_reward[i] = 0.0;
                    discounts[i] = 0.0;
                } else {
                    discounts[i] = cfg.discount;
                }
            }
            stats.record_episodes(ended, ended_reward);
            builder.push_step(&prev_obs, &actions, &logits, &rewards, &discounts)?;
        }

        // 5) finish the window, shard, enqueue
        let version = store.version();
        let traj = builder.finish(&obs, version, cfg.actor_id)?;
        stats.env_frames.add(traj.frames() as u64);
        stats
            .trajectories
            .fetch_add(1, Ordering::Relaxed);
        let shards = shard(&traj, cfg.num_shards)?;
        if queue.push(shards).is_err() {
            return Ok(()); // queue shut down: clean exit
        }
    }
    Ok(())
}
