//! Versioned parameter store — the host-side stand-in for "updated
//! parameters are sent directly to the actor devices".
//!
//! The learner publishes a new snapshot after every update; actor threads
//! grab the latest snapshot before each inference step ("switch to using the
//! latest parameters before each new inference step"). Snapshots are
//! `Arc`-shared, so publishing never blocks actors and actors never copy.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

#[derive(Debug)]
pub struct ParamSnapshot {
    pub version: u64,
    pub params: Vec<f32>,
}

pub struct ParamStore {
    current: RwLock<Arc<ParamSnapshot>>,
    version: AtomicU64,
}

impl ParamStore {
    pub fn new(initial: Vec<f32>) -> Self {
        Self {
            current: RwLock::new(Arc::new(ParamSnapshot { version: 0, params: initial })),
            version: AtomicU64::new(0),
        }
    }

    /// Latest snapshot (cheap: one RwLock read + Arc clone).
    pub fn latest(&self) -> Arc<ParamSnapshot> {
        self.current.read().unwrap().clone()
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Publish new parameters; returns the new version.
    pub fn publish(&self, params: Vec<f32>) -> u64 {
        let v = self.version.fetch_add(1, Ordering::AcqRel) + 1;
        let snap = Arc::new(ParamSnapshot { version: v, params });
        *self.current.write().unwrap() = snap;
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versions_are_monotonic() {
        let store = ParamStore::new(vec![0.0; 4]);
        assert_eq!(store.latest().version, 0);
        let v1 = store.publish(vec![1.0; 4]);
        let v2 = store.publish(vec![2.0; 4]);
        assert_eq!((v1, v2), (1, 2));
        assert_eq!(store.latest().version, 2);
        assert_eq!(store.latest().params[0], 2.0);
    }

    #[test]
    fn old_snapshots_stay_valid() {
        let store = ParamStore::new(vec![0.0]);
        let old = store.latest();
        store.publish(vec![9.0]);
        assert_eq!(old.params[0], 0.0); // actor holding the old Arc is fine
        assert_eq!(store.latest().params[0], 9.0);
    }

    #[test]
    fn concurrent_readers_see_some_version() {
        let store = Arc::new(ParamStore::new(vec![0.0]));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = store.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    let snap = s.latest();
                    // params value must always equal its version
                    assert_eq!(snap.params[0] as u64, snap.version);
                }
            }));
        }
        for i in 1..=100u64 {
            store.publish(vec![i as f32]);
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
