//! Versioned parameter store — the host-side stand-in for "updated
//! parameters are sent directly to the actor devices".
//!
//! The learner publishes a new snapshot after every update; actor threads
//! grab the latest snapshot before each inference step ("switch to using the
//! latest parameters before each new inference step"). Snapshots are
//! `Arc`-shared — and the parameter buffer itself is a second `Arc`, so a
//! snapshot can be handed to a device core as a zero-copy
//! `HostTensor::f32_shared` upload (DESIGN.md §11): publishing never blocks
//! actors, and actors never copy.
//!
//! Version assignment happens *under the write lock*. Assigning with a
//! lock-free `fetch_add` first (the pre-fix code) let two concurrent
//! publishers install snapshots out of order: publisher A draws version 1,
//! publisher B draws 2 and installs first, then A overwrites — `latest()`
//! ends up behind `version()` forever and actors keep reading the stale
//! params as if they were fresh.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct ParamSnapshot {
    pub version: u64,
    /// `Arc`-shared so device uploads reference the snapshot directly.
    pub params: Arc<Vec<f32>>,
}

pub struct ParamStore {
    current: RwLock<Arc<ParamSnapshot>>,
    /// Last published version. Updated under the write lock (after the
    /// snapshot is installed), read lock-free: `version()` may briefly lag
    /// `latest().version` during a publish, but can never run ahead of it.
    version: AtomicU64,
    /// Publish signal for [`Self::wait_newer`] subscribers (the wire
    /// publisher thread, DESIGN.md §15): a mirror of the installed version
    /// guarded by a plain mutex so it can pair with a condvar. Updated
    /// *after* the snapshot is installed, so a woken waiter always finds
    /// the new snapshot via `latest_if_newer`.
    signal: Mutex<u64>,
    published: Condvar,
}

impl ParamStore {
    pub fn new(initial: Vec<f32>) -> Self {
        Self::with_version(initial, 0)
    }

    /// Like [`Self::new`], but the initial snapshot carries a checkpointed
    /// version instead of 0. A restored run must resume the version
    /// sequence where the original left off — actors pace themselves on
    /// `version()`, so restarting it at 0 would desynchronise the lockstep
    /// restore path (DESIGN.md §13).
    pub fn with_version(initial: Vec<f32>, version: u64) -> Self {
        Self {
            current: RwLock::new(Arc::new(ParamSnapshot {
                version,
                params: Arc::new(initial),
            })),
            version: AtomicU64::new(version),
            signal: Mutex::new(version),
            published: Condvar::new(),
        }
    }

    /// Latest snapshot (cheap: one RwLock read + Arc clone).
    pub fn latest(&self) -> Arc<ParamSnapshot> {
        self.current.read().unwrap().clone()
    }

    /// Latest snapshot only if a version other than `seen` has been
    /// published — the hot-loop refresh (actor + serve inference loops):
    /// the common no-new-params case is one lock-free atomic load, with no
    /// read lock taken and no `Arc` clone made. `u64::MAX` is the
    /// "nothing cached yet" sentinel (no published version can equal it,
    /// so the first call always fetches, including the initial version 0).
    ///
    /// `version()` may briefly lag `latest().version` during a publish
    /// (see the field doc), so the atomic is a conservative gate: when it
    /// fires, the installed snapshot is re-checked under the read lock and
    /// a same-version snapshot is still `None`.
    pub fn latest_if_newer(&self, seen: u64) -> Option<Arc<ParamSnapshot>> {
        if self.version.load(Ordering::Acquire) == seen {
            return None;
        }
        let snap = self.latest();
        if snap.version == seen {
            return None;
        }
        Some(snap)
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Publish new parameters; returns the new version. Concurrent
    /// publishers serialize on the write lock, so versions are assigned and
    /// installed in the same order and `latest().version` is monotonic.
    pub fn publish(&self, params: Vec<f32>) -> u64 {
        self.publish_shared(Arc::new(params))
    }

    /// Publish an already-`Arc`'d buffer without copying it.
    pub fn publish_shared(&self, params: Arc<Vec<f32>>) -> u64 {
        let mut g = self.current.write().unwrap();
        let v = self.version.load(Ordering::Relaxed) + 1;
        *g = Arc::new(ParamSnapshot { version: v, params });
        self.version.store(v, Ordering::Release);
        drop(g);
        self.notify(v);
        v
    }

    /// Install a snapshot that already carries its version — the wire
    /// subscriber path (DESIGN.md §15): an actor pod's replica store adopts
    /// the versions the learner pod assigned, rather than drawing its own.
    /// Stale or duplicate deliveries are ignored (returns `false`), so
    /// out-of-order frames can never move the store backwards and
    /// `latest().version` stays monotonic.
    pub fn install(&self, params: Vec<f32>, version: u64) -> bool {
        let mut g = self.current.write().unwrap();
        if version <= g.version {
            return false;
        }
        *g = Arc::new(ParamSnapshot { version, params: Arc::new(params) });
        self.version.store(version, Ordering::Release);
        drop(g);
        self.notify(version);
        true
    }

    fn notify(&self, version: u64) {
        let mut s = self.signal.lock().unwrap();
        // publish_shared and install serialize on the write lock, but the
        // signal mutex is taken after dropping it — keep the mirror
        // monotonic if two notifiers race here.
        if version > *s {
            *s = version;
        }
        self.published.notify_all();
    }

    /// Block until a version newer than `seen` is published, or `timeout`
    /// elapses (`None`). The pub/sub primitive under the wire publisher:
    /// `wait_newer` + broadcast on the learner pod is exactly
    /// `latest_if_newer` with the polling replaced by a condvar.
    pub fn wait_newer(&self, seen: u64, timeout: Duration) -> Option<Arc<ParamSnapshot>> {
        let deadline = Instant::now() + timeout;
        let mut s = self.signal.lock().unwrap();
        loop {
            if *s > seen {
                drop(s);
                // the mirror only advances after installation, so this
                // always observes a snapshot newer than `seen`
                return self.latest_if_newer(seen);
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return None;
            }
            let (g, res) = self.published.wait_timeout(s, left).unwrap();
            s = g;
            if res.timed_out() && *s <= seen {
                return None;
            }
        }
    }
}

/// Epoch-aware subscriber registry for the wire Params publisher
/// (DESIGN.md §16). The publisher thread broadcasts each new snapshot to
/// exactly the pods registered here; eviction retires an entry, so a dead
/// pod stops receiving Params frames the moment its membership ends rather
/// than when its socket finally errors. Each entry remembers the membership
/// epoch it joined at, purely as a diagnostic anchor — retirement is by pod
/// index, which the `Membership` registry never reuses.
#[derive(Default)]
pub struct SubscriberSet {
    inner: Mutex<BTreeMap<usize, u64>>,
}

impl SubscriberSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a pod at its admission epoch. Re-registering an index
    /// (which `Membership` never hands out twice) just updates the epoch.
    pub fn register(&self, pod: usize, epoch: u64) {
        self.inner.lock().unwrap().insert(pod, epoch);
    }

    /// Retire a pod; returns whether it was registered. Idempotent, like
    /// `Membership::depart`.
    pub fn retire(&self, pod: usize) -> bool {
        self.inner.lock().unwrap().remove(&pod).is_some()
    }

    pub fn contains(&self, pod: usize) -> bool {
        self.inner.lock().unwrap().contains_key(&pod)
    }

    /// Snapshot of the active pod indices, in index order. A snapshot (not
    /// a held lock) so the publisher never sends frames under the registry
    /// lock.
    pub fn active(&self) -> Vec<usize> {
        self.inner.lock().unwrap().keys().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subscriber_set_registers_and_retires_by_pod_index() {
        let subs = SubscriberSet::new();
        assert!(subs.is_empty());
        subs.register(0, 1);
        subs.register(2, 3);
        assert_eq!(subs.active(), vec![0, 2]);
        assert!(subs.contains(2));
        assert!(subs.retire(2));
        assert!(!subs.retire(2), "retirement is idempotent");
        assert!(!subs.contains(2));
        assert_eq!(subs.active(), vec![0]);
        assert_eq!(subs.len(), 1);
    }

    #[test]
    fn versions_are_monotonic() {
        let store = ParamStore::new(vec![0.0; 4]);
        assert_eq!(store.latest().version, 0);
        let v1 = store.publish(vec![1.0; 4]);
        let v2 = store.publish(vec![2.0; 4]);
        assert_eq!((v1, v2), (1, 2));
        assert_eq!(store.latest().version, 2);
        assert_eq!(store.latest().params[0], 2.0);
    }

    #[test]
    fn old_snapshots_stay_valid() {
        let store = ParamStore::new(vec![0.0]);
        let old = store.latest();
        store.publish(vec![9.0]);
        assert_eq!(old.params[0], 0.0); // actor holding the old Arc is fine
        assert_eq!(store.latest().params[0], 9.0);
    }

    #[test]
    fn concurrent_readers_see_some_version() {
        let store = Arc::new(ParamStore::new(vec![0.0]));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = store.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    let snap = s.latest();
                    // params value must always equal its version
                    assert_eq!(snap.params[0] as u64, snap.version);
                }
            }));
        }
        for i in 1..=100u64 {
            store.publish(vec![i as f32]);
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn concurrent_publishers_keep_latest_monotonic() {
        // Regression (ISSUE 4): version used to be drawn with fetch_add
        // *before* taking the write lock, so two racing publishers could
        // install out of order and leave latest() permanently behind
        // version(). Hammer the store from several publishers while a
        // reader asserts latest().version never goes backwards.
        use std::sync::atomic::AtomicBool;

        const PUBLISHERS: usize = 4;
        const EACH: u64 = 400;

        let store = Arc::new(ParamStore::new(vec![0.0]));
        let stop = Arc::new(AtomicBool::new(false));

        let watcher = {
            let s = store.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut last = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let snap = s.latest();
                    assert!(
                        snap.version >= last,
                        "latest() went backwards: {} after {}",
                        snap.version,
                        last
                    );
                    // version() may lag by at most the publish in flight,
                    // but never runs ahead of an installed snapshot forever
                    assert!(s.version() + 1 >= snap.version);
                    last = snap.version;
                }
            })
        };

        let mut pubs = Vec::new();
        for p in 0..PUBLISHERS {
            let s = store.clone();
            pubs.push(std::thread::spawn(move || {
                for i in 0..EACH {
                    s.publish(vec![(p as u64 * EACH + i) as f32]);
                }
            }));
        }
        for p in pubs {
            p.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        watcher.join().unwrap();

        // Quiescent: every publish got a distinct, in-order version, and
        // the installed snapshot is the one that drew the final version.
        assert_eq!(store.version(), PUBLISHERS as u64 * EACH);
        assert_eq!(store.latest().version, PUBLISHERS as u64 * EACH);
    }

    #[test]
    fn with_version_resumes_the_sequence() {
        let store = ParamStore::new(vec![1.0; 4]);
        store.publish(vec![2.0; 4]);
        store.publish(vec![3.0; 4]);
        // rebuild "from checkpoint": same params, same version
        let restored = ParamStore::with_version(store.latest().params.to_vec(), store.version());
        assert_eq!(restored.version(), 2);
        assert_eq!(restored.latest().version, 2);
        assert_eq!(restored.latest().params[0], 3.0);
        assert_eq!(restored.publish(vec![4.0; 4]), 3);
    }

    #[test]
    fn install_adopts_wire_versions_and_ignores_stale_ones() {
        let store = ParamStore::new(vec![0.0]);
        assert!(store.install(vec![5.0], 5));
        assert_eq!(store.latest().version, 5);
        assert_eq!(store.latest().params[0], 5.0);
        // duplicate and out-of-order deliveries cannot move it backwards
        assert!(!store.install(vec![3.0], 3));
        assert!(!store.install(vec![5.5], 5));
        assert_eq!(store.latest().params[0], 5.0);
        // the next local publish continues from the adopted version
        assert_eq!(store.publish(vec![6.0]), 6);
        // and latest_if_newer sees installs like any publish
        assert!(store.install(vec![9.0], 9));
        assert_eq!(store.latest_if_newer(6).unwrap().version, 9);
        assert!(store.latest_if_newer(9).is_none());
    }

    #[test]
    fn wait_newer_wakes_on_publish_and_times_out_when_idle() {
        let store = Arc::new(ParamStore::new(vec![0.0]));
        // idle: no publish -> None after the timeout
        assert!(store.wait_newer(0, Duration::from_millis(10)).is_none());
        // already newer: returns without blocking
        store.publish(vec![1.0]);
        let snap = store.wait_newer(0, Duration::from_secs(5)).unwrap();
        assert_eq!(snap.version, 1);
        // blocked waiter is woken by a concurrent publish
        let waiter = {
            let s = store.clone();
            std::thread::spawn(move || s.wait_newer(1, Duration::from_secs(5)))
        };
        std::thread::sleep(Duration::from_millis(20));
        store.install(vec![7.0], 7);
        let snap = waiter.join().unwrap().expect("waiter should see the install");
        assert_eq!(snap.version, 7);
        assert_eq!(snap.params[0], 7.0);
    }

    #[test]
    fn publish_shared_does_not_copy() {
        let store = ParamStore::new(vec![0.0]);
        let buf = Arc::new(vec![4.0, 5.0]);
        let ptr = buf.as_ptr();
        store.publish_shared(buf);
        let snap = store.latest();
        assert!(std::ptr::eq(snap.params.as_ptr(), ptr));
        assert_eq!(snap.version, 1);
    }
}
