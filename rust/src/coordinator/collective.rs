//! Simulated collectives: the paper's `psum`/`pmean` over learner cores and
//! replicas, performed by the coordinator between the `grad` and `apply`
//! programs (DESIGN.md §4 "the psum seam").
//!
//! Two pieces:
//! * [`all_reduce_mean`] — deterministic in-place tree reduction over the
//!   gradient buffers a single learner thread collected from its cores.
//! * [`GradientBus`] — the cross-replica collective: R learner threads post
//!   their replica-mean gradients, the last to arrive computes the global
//!   mean (in fixed replica order => deterministic), everyone picks it up.

use std::sync::{Condvar, Mutex};

use anyhow::{bail, Result};

/// Deterministic pairwise-tree mean over `n` equal-length buffers, in place:
/// on return, `bufs[0]` holds the mean. Tree order is fixed by index, so the
/// result is bit-stable regardless of which core finished first.
pub fn all_reduce_mean(bufs: &mut [Vec<f32>]) -> Result<()> {
    let n = bufs.len();
    if n == 0 {
        bail!("all_reduce over zero buffers");
    }
    let len = bufs[0].len();
    if bufs.iter().any(|b| b.len() != len) {
        bail!("all_reduce over unequal buffer lengths");
    }
    // pairwise tree: stride doubling
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (a, b) = bufs.split_at_mut(i + stride);
            let dst = &mut a[i];
            let src = &b[0];
            for k in 0..len {
                dst[k] += src[k];
            }
            i += stride * 2;
        }
        stride *= 2;
    }
    let inv = 1.0 / n as f32;
    for v in bufs[0].iter_mut() {
        *v *= inv;
    }
    Ok(())
}

/// Cross-replica gradient all-reduce with barrier semantics.
///
/// Each of `n` participants calls `all_reduce(id, grads)` once per round;
/// the call blocks until every participant of the round has posted, then all
/// return the same global mean. Rounds are generation-counted, so repeated
/// use is safe. `shutdown()` unblocks everyone with an error.
pub struct GradientBus {
    n: usize,
    state: Mutex<BusState>,
    cv: Condvar,
}

struct BusState {
    generation: u64,
    posted: Vec<Option<Vec<f32>>>,
    result: Option<Vec<f32>>,
    collected: usize,
    shutdown: bool,
}

impl GradientBus {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Self {
            n,
            state: Mutex::new(BusState {
                generation: 0,
                posted: vec![None; n],
                result: None,
                collected: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn participants(&self) -> usize {
        self.n
    }

    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    /// Post `grads` for `id` and wait for the round's global mean.
    pub fn all_reduce(&self, id: usize, grads: Vec<f32>) -> Result<Vec<f32>> {
        if id >= self.n {
            bail!("participant {id} out of range {}", self.n);
        }
        if self.n == 1 {
            return Ok(grads); // fast path: single replica
        }
        let mut g = self.state.lock().unwrap();
        // A fast replica can lap the round: it re-enters the next
        // `all_reduce` while slower participants are still collecting the
        // current result. Hold it here until the round fully drains
        // (`result` is cleared once `collected == n`) — otherwise its
        // wait below would see `result.is_some()` with `generation` still
        // unbumped, skip the wait, and return the *previous* round's mean.
        while g.result.is_some() && !g.shutdown {
            g = self.cv.wait(g).unwrap();
        }
        if g.shutdown {
            bail!("gradient bus shut down");
        }
        if g.posted[id].is_some() {
            bail!("participant {id} posted twice in one round");
        }
        let my_gen = g.generation;
        g.posted[id] = Some(grads);

        let all_posted = g.posted.iter().all(Option::is_some);
        if all_posted {
            // last one in computes the mean, in fixed id order
            let mut bufs: Vec<Vec<f32>> =
                g.posted.iter_mut().map(|o| o.take().unwrap()).collect();
            all_reduce_mean(&mut bufs)?;
            g.result = Some(bufs.swap_remove(0));
            g.collected = 0;
            self.cv.notify_all();
        } else {
            while g.generation == my_gen && g.result.is_none() && !g.shutdown {
                g = self.cv.wait(g).unwrap();
            }
        }
        if g.shutdown {
            bail!("gradient bus shut down");
        }
        let result = g
            .result
            .as_ref()
            .expect("round result missing")
            .clone();
        g.collected += 1;
        if g.collected == self.n {
            // round complete: reset for the next generation
            g.result = None;
            g.generation += 1;
            self.cv.notify_all();
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mean_of_three() {
        let mut bufs = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        all_reduce_mean(&mut bufs).unwrap();
        assert_eq!(bufs[0], vec![3.0, 4.0]);
    }

    #[test]
    fn single_buffer_identity() {
        let mut bufs = vec![vec![7.0, -1.0]];
        all_reduce_mean(&mut bufs).unwrap();
        assert_eq!(bufs[0], vec![7.0, -1.0]);
    }

    #[test]
    fn matches_sequential_sum() {
        // deterministic tree == plain left-to-right mean for these values
        for n in 1..9 {
            let mut bufs: Vec<Vec<f32>> =
                (0..n).map(|i| vec![i as f32, (i * i) as f32]).collect();
            let want0: f32 = (0..n).map(|i| i as f32).sum::<f32>() / n as f32;
            let want1: f32 = (0..n).map(|i| (i * i) as f32).sum::<f32>() / n as f32;
            all_reduce_mean(&mut bufs).unwrap();
            assert!((bufs[0][0] - want0).abs() < 1e-5);
            assert!((bufs[0][1] - want1).abs() < 1e-5);
        }
    }

    #[test]
    fn unequal_lengths_rejected() {
        let mut bufs = vec![vec![1.0], vec![1.0, 2.0]];
        assert!(all_reduce_mean(&mut bufs).is_err());
        let mut empty: Vec<Vec<f32>> = vec![];
        assert!(all_reduce_mean(&mut empty).is_err());
    }

    #[test]
    fn bus_single_participant_passthrough() {
        let bus = GradientBus::new(1);
        let out = bus.all_reduce(0, vec![1.0, 2.0]).unwrap();
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    fn bus_three_replicas_agree() {
        let bus = Arc::new(GradientBus::new(3));
        let mut handles = Vec::new();
        for id in 0..3 {
            let bus = bus.clone();
            handles.push(std::thread::spawn(move || {
                bus.all_reduce(id, vec![id as f32 * 3.0]).unwrap()
            }));
        }
        let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in &results {
            assert_eq!(r, &vec![3.0]); // mean of 0, 3, 6
        }
    }

    #[test]
    fn bus_multiple_rounds() {
        let bus = Arc::new(GradientBus::new(2));
        for round in 0..5 {
            let b1 = bus.clone();
            let t = std::thread::spawn(move || b1.all_reduce(1, vec![round as f32 + 1.0]).unwrap());
            let r0 = bus.all_reduce(0, vec![round as f32]).unwrap();
            let r1 = t.join().unwrap();
            assert_eq!(r0, r1);
            assert!((r0[0] - (round as f32 + 0.5)).abs() < 1e-6);
        }
    }

    #[test]
    fn bus_lapping_replicas_get_fresh_round_means() {
        // Regression for the round-lapping race: two fast participants and
        // one slow one. Whichever fast participant posts last computes the
        // mean and immediately re-enters the next round — before the other
        // two have collected. It must block at the entry gate until the
        // round drains, not skip the wait on the still-set `result` and
        // walk off with the previous round's mean.
        const ROUNDS: usize = 100;
        let bus = Arc::new(GradientBus::new(3));
        let mut handles = Vec::new();
        for id in 0..3 {
            let bus = bus.clone();
            handles.push(std::thread::spawn(move || {
                let mut out = Vec::with_capacity(ROUNDS);
                for r in 0..ROUNDS {
                    if id == 2 {
                        // the slow replica: arrives (and so collects) late
                        std::thread::sleep(std::time::Duration::from_micros(300));
                    }
                    let v = (r * 3 + id) as f32;
                    out.push(bus.all_reduce(id, vec![v]).unwrap()[0]);
                }
                out
            }));
        }
        let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (id, res) in results.iter().enumerate() {
            for (r, got) in res.iter().enumerate() {
                let want = (r * 3 + 1) as f32; // mean of 3r, 3r+1, 3r+2
                assert_eq!(
                    *got, want,
                    "participant {id} got a stale mean in round {r}: {got} != {want}"
                );
            }
        }
    }

    #[test]
    fn bus_shutdown_unblocks() {
        let bus = Arc::new(GradientBus::new(2));
        let b = bus.clone();
        let t = std::thread::spawn(move || b.all_reduce(0, vec![1.0]));
        std::thread::sleep(std::time::Duration::from_millis(20));
        bus.shutdown();
        assert!(t.join().unwrap().is_err());
    }
}
