//! Simulated collectives: the paper's `psum`/`pmean` over learner cores and
//! replicas, performed by the coordinator between the `grad` and `apply`
//! programs (DESIGN.md §4 "the psum seam") and by the threaded Anakin driver
//! between outer iterations (DESIGN.md §10).
//!
//! Two pieces:
//! * [`all_reduce_mean`] — deterministic in-place tree reduction over the
//!   buffers a single thread collected from its cores.
//! * [`TensorBus`] — the cross-thread collective: N participants run a
//!   sequence of *rounds*, each round either an all-reduce (everyone posts,
//!   the last to arrive computes the global mean in fixed id order =>
//!   deterministic) or a broadcast (one root posts, everyone receives).
//!   Sebulba's learners all-reduce gradients on it ([`GradientBus`] is the
//!   historical alias); the threaded Anakin driver all-reduces params +
//!   optimiser state in Bundled mode and grads in Psum mode, then
//!   broadcasts the applied params back (DESIGN.md §10).

use std::sync::{Condvar, Mutex, MutexGuard};

use anyhow::{bail, Result};

/// Deterministic pairwise-tree mean over `n` equal-length buffers, in place:
/// on return, `bufs[0]` holds the mean. Tree order is fixed by index, so the
/// result is bit-stable regardless of which core finished first.
pub fn all_reduce_mean(bufs: &mut [Vec<f32>]) -> Result<()> {
    let n = bufs.len();
    if n == 0 {
        bail!("all_reduce over zero buffers");
    }
    let len = bufs[0].len();
    if bufs.iter().any(|b| b.len() != len) {
        bail!("all_reduce over unequal buffer lengths");
    }
    // pairwise tree: stride doubling
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (a, b) = bufs.split_at_mut(i + stride);
            let dst = &mut a[i];
            let src = &b[0];
            for k in 0..len {
                dst[k] += src[k];
            }
            i += stride * 2;
        }
        stride *= 2;
    }
    let inv = 1.0 / n as f32;
    for v in bufs[0].iter_mut() {
        *v *= inv;
    }
    Ok(())
}

/// What a [`TensorBus`] round does with the posted buffers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RoundOp {
    /// Everyone posts; the last to arrive computes the tree mean in fixed
    /// participant order (deterministic regardless of arrival order).
    Reduce,
    /// Exactly one participant (the root) posts; everyone receives a copy.
    Broadcast,
}

/// Cross-thread tensor collective with barrier semantics.
///
/// Each of `n` participants calls [`TensorBus::all_reduce`] or
/// [`TensorBus::broadcast`] once per round; the call blocks until every
/// participant of the round has posted, then all return the same buffer.
/// All participants of a round must call the *same* operation — the rounds
/// form one totally-ordered schedule, exactly like collectives on a real
/// pod. Rounds are generation-counted, so repeated use is safe; a fast
/// participant that laps the round is held at the entry gate until the
/// round fully drains. `shutdown()` unblocks everyone with an error, and a
/// protocol violation (mismatched ops, two roots, a double post) poisons
/// the bus so no sibling is left parked forever.
pub struct TensorBus {
    n: usize,
    state: Mutex<BusState>,
    cv: Condvar,
}

/// Historical name: Sebulba's learners all-reduce gradients on the bus.
pub type GradientBus = TensorBus;

struct BusState {
    generation: u64,
    /// The round's op, fixed by the first poster, cleared when it drains.
    op: Option<RoundOp>,
    posted: Vec<bool>,
    payloads: Vec<Option<Vec<f32>>>,
    arrived: usize,
    result: Option<Vec<f32>>,
    collected: usize,
    shutdown: bool,
}

impl TensorBus {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Self {
            n,
            state: Mutex::new(BusState {
                generation: 0,
                op: None,
                posted: vec![false; n],
                payloads: (0..n).map(|_| None).collect(),
                arrived: 0,
                result: None,
                collected: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn participants(&self) -> usize {
        self.n
    }

    /// Shut the bus down: every parked participant wakes with an error and
    /// every later entrant bails at the gate. Flag and notify happen under
    /// one held guard, exactly like [`Self::poison`] — the pre-fix code
    /// dropped the lock between the two, leaving a window where a
    /// concurrent `all_reduce` could enter its round against a bus that
    /// was already going down.
    pub fn shutdown(&self) {
        let mut g = self.state.lock().unwrap();
        self.poison(&mut g);
    }

    /// Poison under the lock: a protocol violation must not leave siblings
    /// parked in a round that can no longer complete.
    fn poison(&self, g: &mut MutexGuard<'_, BusState>) {
        g.shutdown = true;
        self.cv.notify_all();
    }

    /// Post `buf` for `id` and wait for the round's global mean.
    pub fn all_reduce(&self, id: usize, buf: Vec<f32>) -> Result<Vec<f32>> {
        self.round(id, Some(buf), RoundOp::Reduce)
    }

    /// Join a broadcast round: the root passes `Some(buf)`, everyone else
    /// `None`; all participants return a copy of the root's buffer.
    pub fn broadcast(&self, id: usize, payload: Option<Vec<f32>>) -> Result<Vec<f32>> {
        self.round(id, payload, RoundOp::Broadcast)
    }

    fn round(&self, id: usize, payload: Option<Vec<f32>>, op: RoundOp) -> Result<Vec<f32>> {
        if id >= self.n {
            bail!("participant {id} out of range {}", self.n);
        }
        if self.n == 1 {
            // Fast path: single participant, every op is the identity —
            // but round entry is still gated on the shutdown flag under
            // the round lock. The pre-fix code skipped the lock entirely,
            // so a single-replica learner racing `shutdown()` would keep
            // reducing on a bus that was already down instead of
            // observing it (the shutdown discipline every n >= 2
            // participant gets at the entry gate below).
            if self.state.lock().unwrap().shutdown {
                bail!("tensor bus shut down");
            }
            return match payload {
                Some(buf) => Ok(buf),
                None => bail!("broadcast round had no root"),
            };
        }
        let mut g = self.state.lock().unwrap();
        // A fast participant can lap the round: it re-enters the next
        // round while slower participants are still collecting the current
        // result. Hold it here until the round fully drains (`result` is
        // cleared once `collected == n`) — otherwise its wait below would
        // see `result.is_some()` with `generation` still unbumped, skip the
        // wait, and return the *previous* round's buffer.
        while g.result.is_some() && !g.shutdown {
            g = self.cv.wait(g).unwrap();
        }
        if g.shutdown {
            bail!("tensor bus shut down");
        }
        match g.op {
            None => g.op = Some(op),
            Some(cur) if cur == op => {}
            Some(cur) => {
                self.poison(&mut g);
                bail!("collective protocol violation: round is {cur:?}, participant {id} called {op:?}");
            }
        }
        if g.posted[id] {
            self.poison(&mut g);
            bail!("participant {id} posted twice in one round");
        }
        if payload.is_some() {
            if op == RoundOp::Broadcast && g.payloads.iter().any(Option::is_some) {
                self.poison(&mut g);
                bail!("two roots in one broadcast round");
            }
            g.payloads[id] = payload;
        } else if op == RoundOp::Reduce {
            self.poison(&mut g);
            bail!("reduce round requires a payload");
        }
        g.posted[id] = true;
        g.arrived += 1;
        let my_gen = g.generation;

        if g.arrived == self.n {
            // last one in computes the round's result
            let result = match op {
                RoundOp::Reduce => {
                    // fixed id order => deterministic tree
                    let mut bufs: Vec<Vec<f32>> =
                        g.payloads.iter_mut().map(|o| o.take().unwrap()).collect();
                    match all_reduce_mean(&mut bufs) {
                        Ok(()) => bufs.swap_remove(0),
                        Err(e) => {
                            self.poison(&mut g);
                            return Err(e);
                        }
                    }
                }
                RoundOp::Broadcast => {
                    let root = g.payloads.iter_mut().find_map(Option::take);
                    match root {
                        Some(buf) => buf,
                        None => {
                            self.poison(&mut g);
                            bail!("broadcast round had no root");
                        }
                    }
                }
            };
            g.result = Some(result);
            g.collected = 0;
            self.cv.notify_all();
        } else {
            while g.generation == my_gen && g.result.is_none() && !g.shutdown {
                g = self.cv.wait(g).unwrap();
            }
        }
        if g.shutdown {
            bail!("tensor bus shut down");
        }
        let result = g
            .result
            .as_ref()
            .expect("round result missing")
            .clone();
        g.collected += 1;
        if g.collected == self.n {
            // round complete: reset for the next generation
            g.result = None;
            g.op = None;
            for p in g.posted.iter_mut() {
                *p = false;
            }
            g.arrived = 0;
            g.generation += 1;
            self.cv.notify_all();
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mean_of_three() {
        let mut bufs = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        all_reduce_mean(&mut bufs).unwrap();
        assert_eq!(bufs[0], vec![3.0, 4.0]);
    }

    #[test]
    fn single_buffer_identity() {
        let mut bufs = vec![vec![7.0, -1.0]];
        all_reduce_mean(&mut bufs).unwrap();
        assert_eq!(bufs[0], vec![7.0, -1.0]);
    }

    #[test]
    fn matches_sequential_sum() {
        // deterministic tree == plain left-to-right mean for these values
        for n in 1..9 {
            let mut bufs: Vec<Vec<f32>> =
                (0..n).map(|i| vec![i as f32, (i * i) as f32]).collect();
            let want0: f32 = (0..n).map(|i| i as f32).sum::<f32>() / n as f32;
            let want1: f32 = (0..n).map(|i| (i * i) as f32).sum::<f32>() / n as f32;
            all_reduce_mean(&mut bufs).unwrap();
            assert!((bufs[0][0] - want0).abs() < 1e-5);
            assert!((bufs[0][1] - want1).abs() < 1e-5);
        }
    }

    #[test]
    fn unequal_lengths_rejected() {
        let mut bufs = vec![vec![1.0], vec![1.0, 2.0]];
        assert!(all_reduce_mean(&mut bufs).is_err());
        let mut empty: Vec<Vec<f32>> = vec![];
        assert!(all_reduce_mean(&mut empty).is_err());
    }

    #[test]
    fn bus_single_participant_passthrough() {
        let bus = GradientBus::new(1);
        let out = bus.all_reduce(0, vec![1.0, 2.0]).unwrap();
        assert_eq!(out, vec![1.0, 2.0]);
        let out = bus.broadcast(0, Some(vec![3.0])).unwrap();
        assert_eq!(out, vec![3.0]);
        assert!(bus.broadcast(0, None).is_err());
    }

    #[test]
    fn bus_three_replicas_agree() {
        let bus = Arc::new(GradientBus::new(3));
        let mut handles = Vec::new();
        for id in 0..3 {
            let bus = bus.clone();
            handles.push(std::thread::spawn(move || {
                bus.all_reduce(id, vec![id as f32 * 3.0]).unwrap()
            }));
        }
        let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in &results {
            assert_eq!(r, &vec![3.0]); // mean of 0, 3, 6
        }
    }

    #[test]
    fn bus_multiple_rounds() {
        let bus = Arc::new(GradientBus::new(2));
        for round in 0..5 {
            let b1 = bus.clone();
            let t = std::thread::spawn(move || b1.all_reduce(1, vec![round as f32 + 1.0]).unwrap());
            let r0 = bus.all_reduce(0, vec![round as f32]).unwrap();
            let r1 = t.join().unwrap();
            assert_eq!(r0, r1);
            assert!((r0[0] - (round as f32 + 0.5)).abs() < 1e-6);
        }
    }

    #[test]
    fn bus_broadcast_delivers_root_buffer() {
        let bus = Arc::new(TensorBus::new(3));
        let mut handles = Vec::new();
        for id in 0..3 {
            let bus = bus.clone();
            handles.push(std::thread::spawn(move || {
                let payload = if id == 1 { Some(vec![4.0, 5.0]) } else { None };
                bus.broadcast(id, payload).unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![4.0, 5.0]);
        }
    }

    #[test]
    fn bus_lapping_replicas_get_fresh_round_means() {
        // Regression for the round-lapping race: two fast participants and
        // one slow one. Whichever fast participant posts last computes the
        // mean and immediately re-enters the next round — before the other
        // two have collected. It must block at the entry gate until the
        // round drains, not skip the wait on the still-set `result` and
        // walk off with the previous round's mean.
        const ROUNDS: usize = 100;
        let bus = Arc::new(GradientBus::new(3));
        let mut handles = Vec::new();
        for id in 0..3 {
            let bus = bus.clone();
            handles.push(std::thread::spawn(move || {
                let mut out = Vec::with_capacity(ROUNDS);
                for r in 0..ROUNDS {
                    if id == 2 {
                        // the slow replica: arrives (and so collects) late
                        std::thread::sleep(std::time::Duration::from_micros(300));
                    }
                    let v = (r * 3 + id) as f32;
                    out.push(bus.all_reduce(id, vec![v]).unwrap()[0]);
                }
                out
            }));
        }
        let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (id, res) in results.iter().enumerate() {
            for (r, got) in res.iter().enumerate() {
                let want = (r * 3 + 1) as f32; // mean of 3r, 3r+1, 3r+2
                assert_eq!(
                    *got, want,
                    "participant {id} got a stale mean in round {r}: {got} != {want}"
                );
            }
        }
    }

    #[test]
    fn bus_lapping_replicas_mixed_reduce_broadcast_rounds() {
        // The TensorBus twin of the lapping regression, over the threaded
        // Anakin Psum schedule: reduce, then two broadcasts, per outer
        // round. A fast participant must never slip its broadcast post into
        // a round whose reduce hasn't drained (or vice versa) — the op
        // check would poison the bus and the values would go stale.
        const ROUNDS: usize = 50;
        let bus = Arc::new(TensorBus::new(3));
        let mut handles = Vec::new();
        for id in 0..3 {
            let bus = bus.clone();
            handles.push(std::thread::spawn(move || {
                let mut out = Vec::with_capacity(ROUNDS);
                for r in 0..ROUNDS {
                    if id == 2 {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    let mean = bus.all_reduce(id, vec![(r * 3 + id) as f32]).unwrap()[0];
                    let root = |v: f32| if id == 0 { Some(vec![v]) } else { None };
                    let p = bus.broadcast(id, root(mean + 100.0)).unwrap()[0];
                    let o = bus.broadcast(id, root(mean + 200.0)).unwrap()[0];
                    out.push((mean, p, o));
                }
                out
            }));
        }
        let results: Vec<Vec<(f32, f32, f32)>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (id, res) in results.iter().enumerate() {
            for (r, &(mean, p, o)) in res.iter().enumerate() {
                let want = (r * 3 + 1) as f32;
                assert_eq!(mean, want, "participant {id} round {r}: stale mean");
                assert_eq!(p, want + 100.0, "participant {id} round {r}: stale broadcast");
                assert_eq!(o, want + 200.0, "participant {id} round {r}: stale broadcast");
            }
        }
    }

    #[test]
    fn bus_mismatched_ops_poison_instead_of_hanging() {
        let bus = Arc::new(TensorBus::new(2));
        let b = bus.clone();
        let t = std::thread::spawn(move || b.all_reduce(0, vec![1.0]));
        // give the reducer time to open the round
        std::thread::sleep(std::time::Duration::from_millis(20));
        let r1 = bus.broadcast(1, Some(vec![2.0]));
        assert!(r1.is_err(), "mismatched op must error");
        // the sibling must be unblocked by the poison, not left parked
        assert!(t.join().unwrap().is_err());
    }

    #[test]
    fn bus_two_broadcast_roots_rejected() {
        let bus = Arc::new(TensorBus::new(2));
        let b = bus.clone();
        let t = std::thread::spawn(move || b.broadcast(0, Some(vec![1.0])));
        std::thread::sleep(std::time::Duration::from_millis(20));
        let r1 = bus.broadcast(1, Some(vec![2.0]));
        assert!(r1.is_err(), "second root must error");
        assert!(t.join().unwrap().is_err());
    }

    #[test]
    fn bus_shutdown_unblocks() {
        let bus = Arc::new(GradientBus::new(2));
        let b = bus.clone();
        let t = std::thread::spawn(move || b.all_reduce(0, vec![1.0]));
        std::thread::sleep(std::time::Duration::from_millis(20));
        bus.shutdown();
        assert!(t.join().unwrap().is_err());
    }

    #[test]
    fn bus_shutdown_races_round_entry_without_stranding_anyone() {
        // Regression (ISSUE 8): `shutdown()` used to set the flag and drop
        // the round lock *before* notifying, racing participants entering
        // their round — and the n == 1 fast path never looked at the flag
        // at all. Hammer both: participants loop rounds while shutdown
        // lands at a random point; every call must return (a valid mean or
        // the shutdown error), nobody may be left parked, and nothing may
        // succeed once a sibling has observed the shutdown error and the
        // round after it drained.
        for trial in 0..20 {
            let bus = Arc::new(GradientBus::new(2));
            let mut handles = Vec::new();
            for id in 0..2 {
                let bus = bus.clone();
                handles.push(std::thread::spawn(move || {
                    let mut completed = 0u64;
                    loop {
                        match bus.all_reduce(id, vec![completed as f32]) {
                            Ok(_) => completed += 1,
                            Err(_) => return completed,
                        }
                    }
                }));
            }
            // land the shutdown at a varying point in the round schedule
            std::thread::sleep(std::time::Duration::from_micros(50 * trial));
            bus.shutdown();
            let counts: Vec<u64> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            // both sides ran the same totally-ordered round schedule, so
            // their completed-round counts differ by at most the one round
            // in flight when the shutdown landed
            assert!(
                counts[0].abs_diff(counts[1]) <= 1,
                "trial {trial}: round counts diverged: {counts:?}"
            );
            // and a late entrant on the drained bus observes the shutdown
            assert!(bus.all_reduce(0, vec![0.0]).is_err());
        }
    }

    #[test]
    fn bus_single_participant_observes_shutdown() {
        // The n == 1 fast path is gated on the shutdown flag too: a
        // single-replica learner must stop at its next collective instead
        // of reducing forever on a bus its pod already tore down.
        let bus = GradientBus::new(1);
        assert!(bus.all_reduce(0, vec![1.0]).is_ok());
        bus.shutdown();
        assert!(bus.all_reduce(0, vec![1.0]).is_err());
        assert!(bus.broadcast(0, Some(vec![1.0])).is_err());
    }
}
