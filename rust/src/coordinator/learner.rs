//! The learner thread: pipelined grad rounds on every learner core,
//! collective, apply.
//!
//! One learner thread per replica (the paper: "a single learner thread on
//! host then takes the handle to the data (already sharded across the
//! appropriate learner cores), and executes the same update function on all
//! the TPU cores dedicated to learning"). Per update round:
//!
//! 1. launch the grad program on all learner cores concurrently
//!    (`execute_cached_async`, parameters device-resident), one shard each;
//! 2. all-reduce the gradients (deterministic tree mean) — within the
//!    replica, then across replicas on the [`GradientBus`];
//! 3. run the apply program once, publish the new parameters.
//!
//! Rounds are *software-pipelined* to depth `LearnerConfig::pipeline`
//! (`SebulbaConfig::learner_pipeline`, DESIGN.md §9): while round k runs
//! the host-side collective and the apply program, round k+1's grad
//! programs are already in flight on the learner cores against the
//! pre-apply parameter snapshot, and the next bundle is prefetched from the
//! queue with `pop_timeout` so starvation stays observable in
//! `pop_block_seconds`. Depth 1 degenerates to the serial
//! pop→grad→reduce→apply schedule, bit-for-bit (pinned by
//! `rust/tests/learner_pipeline.rs`); each extra level costs one update of
//! gradient staleness.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::checkpoint::{
    Checkpoint, CheckpointSpec, MetaSection, StoreSection, ACTOR_SECTION, META_SECTION,
    STORE_SECTION,
};
use crate::experiment::{Arch, Topology};
use crate::runtime::tensor::HostTensor;
use crate::runtime::DeviceHandle;
use crate::testkit::FaultPlan;

use super::actor::{ShardBundle, SnapshotSlot};
use super::collective::{all_reduce_mean, GradientBus};
use super::param_store::{ParamSnapshot, ParamStore};
use super::queue::BoundedQueue;
use super::stats::RunStats;
use super::trajectory::TrajShard;

/// How long a launch polls the queue for the next bundle while rounds are
/// still in flight: long enough to piggyback on a push that is about to
/// land, short enough that a finished round never stalls behind data.
const PREFETCH_POLL: Duration = Duration::from_millis(1);

pub struct LearnerConfig {
    pub replica_id: usize,
    pub grad_program: String,
    pub apply_program: String,
    /// Shards per update round (= learner cores).
    pub shards_per_round: usize,
    pub total_updates: u64,
    /// Grad/apply rounds kept in flight (1 = serial, bit-for-bit; 2 =
    /// double-buffered). See `SebulbaConfig::learner_pipeline`.
    pub pipeline: usize,
    /// Checkpoint duties, when this replica writes them (DESIGN.md §13).
    pub checkpoint: Option<LearnerCheckpoint>,
    /// Scheduled faults (resilience tests only; None on production paths).
    pub fault: Option<FaultPlan>,
    /// Updates already retired by the run this one restored from. The loop
    /// counts on from here, so `total_updates` stays an absolute budget.
    pub start_round: u64,
}

/// Checkpoint duties delegated to the learner thread (DESIGN.md §13). The
/// learner is the sole writer: after publishing update `r` it pairs its own
/// state (params, optimiser, version) with the [`ActorSection`] the actor
/// deposited for window `r` — the deposit-before-push protocol keys the
/// slot by window count, and lockstep pacing makes window `r` and update
/// `r` the same boundary — then saves atomically.
pub struct LearnerCheckpoint {
    pub spec: CheckpointSpec,
    /// The actor's deposit slot; the save takes the entry keyed by the
    /// retired-round count.
    pub slot: SnapshotSlot,
    /// Workload identity stamped into every checkpoint; `rounds_done` is
    /// overwritten with the retired count at save time.
    pub meta: MetaSection,
    pub arch: Arch,
    pub topology: Topology,
}

/// Build and atomically save a checkpoint right after retiring round
/// `retired`. The learner is the only publisher, so `store.latest()` here
/// is exactly the params this round published — it cannot move under us.
fn write_checkpoint(
    cfg: &LearnerConfig,
    ck: &LearnerCheckpoint,
    retired: u64,
    opt_state: &[f32],
    h: &LearnerHandles,
) -> Result<()> {
    let snap = h.store.latest();
    let actor = ck
        .slot
        .lock()
        .unwrap()
        .remove(&retired)
        .with_context(|| format!("actor deposited no snapshot for window {retired}"))?;
    let mut c = Checkpoint::new(ck.arch, &ck.topology);
    let mut meta = ck.meta.clone();
    meta.rounds_done = retired;
    c.insert(META_SECTION, meta.encode());
    c.insert(
        STORE_SECTION,
        StoreSection {
            params: snap.params.as_ref().clone(),
            opt: opt_state.to_vec(),
            version: snap.version,
        }
        .encode(),
    );
    c.insert(ACTOR_SECTION, actor.encode());
    c.save(&ck.spec.path)
        .with_context(|| format!("saving checkpoint to {}", ck.spec.path.display()))?;
    // Injected fault: cut the file after a good save, so the next restore
    // must surface a typed error instead of loading a partial state.
    if let Some(len) = cfg.fault.as_ref().and_then(|f| f.truncate_checkpoint_to) {
        let f = std::fs::OpenOptions::new()
            .write(true)
            .open(&ck.spec.path)
            .context("truncate-checkpoint fault")?;
        f.set_len(len).context("truncate-checkpoint fault")?;
    }
    Ok(())
}

pub struct LearnerHandles {
    pub cores: Vec<DeviceHandle>,
    pub store: Arc<ParamStore>,
    pub queue: Arc<BoundedQueue<ShardBundle>>,
    pub stats: Arc<RunStats>,
    pub bus: Arc<GradientBus>,
}

/// One grad round in flight on the learner cores.
struct InFlightRound {
    /// Per-core receivers for the grad programs, in core order.
    waits: Vec<mpsc::Receiver<Result<Vec<HostTensor>>>>,
    /// Parameter snapshot the grads are computed against — the staleness
    /// reference for this round. The apply chains from the latest params,
    /// not this snapshot (at depth ≥ 2 the two differ by an update).
    snap: Arc<ParamSnapshot>,
    /// Version of the parameters that generated the round's shards.
    data_version: u64,
    issued: Instant,
}

/// Launch one grad round: take `cores.len()` shards off `pending`, refresh
/// each core's device-resident parameter slot if it holds a stale version
/// (rounds launched in the same fill window share a snapshot and skip the
/// upload; steady-state retires publish between launches, so then it costs
/// the same as passing params inline), and fire the grad programs async.
fn launch_round(
    cfg: &LearnerConfig,
    h: &LearnerHandles,
    pending: &mut VecDeque<TrajShard>,
    param_slot: &str,
    core_versions: &mut [u64],
) -> Result<InFlightRound> {
    let snap = h.store.latest();
    let data_version = pending
        .front()
        .expect("caller ensured a full round of shards")
        .param_version();
    let issued = Instant::now();
    let mut waits = Vec::with_capacity(h.cores.len());
    for (i, core) in h.cores.iter().enumerate() {
        let shard = pending.pop_front().expect("caller ensured a full round of shards");
        if core_versions[i] != snap.version {
            core.cache(
                param_slot,
                HostTensor::f32_shared(vec![snap.params.len()], snap.params.clone(), 0)?,
            )?;
            core_versions[i] = snap.version;
        }
        // Shards are arena views and the param upload references the
        // snapshot's Arc'd buffer: the grad inputs reach the device-core
        // thread without a single host-side copy — pixel trajectories are
        // tens of MB (§Perf L3-2, DESIGN.md §11). Params come from the
        // device cache slot (input 0).
        let inputs = shard.to_tensors()?;
        waits.push(core.execute_cached_async(
            &cfg.grad_program,
            inputs,
            vec![(0, param_slot.to_string())],
        )?);
    }
    Ok(InFlightRound { waits, snap, data_version, issued })
}

/// Run the learner loop to `total_updates` on the calling thread.
/// Returns the final (params, opt_state).
pub fn learner_main(
    cfg: &LearnerConfig,
    h: &LearnerHandles,
    mut opt_state: Vec<f32>,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let l = h.cores.len();
    if l == 0 {
        bail!("no learner cores");
    }
    if cfg.shards_per_round != l {
        bail!("shards_per_round {} != learner cores {}", cfg.shards_per_round, l);
    }
    if cfg.pipeline == 0 {
        bail!("learner pipeline depth must be >= 1");
    }

    // Device-resident parameter cache, one slot name shared by this
    // replica's learner cores; uploaded only when a core's version is stale.
    let param_slot = format!("lparams#{}", cfg.replica_id);
    let mut core_versions: Vec<u64> = vec![u64::MAX; l];

    // Overlap accounting, mirroring the actor side (DESIGN.md §9).
    let t_loop = Instant::now();
    let mut grad_busy = Duration::ZERO;
    let mut collective_busy = Duration::ZERO;
    let mut apply_busy = Duration::ZERO;
    let mut pop_blocked = Duration::ZERO;

    let mut pending: VecDeque<TrajShard> = VecDeque::new();
    let mut in_flight: VecDeque<InFlightRound> = VecDeque::new();
    // A restored run continues the original count: `total_updates` is an
    // absolute budget, not "N more" (DESIGN.md §13).
    let mut launched = cfg.start_round;
    let mut retired = cfg.start_round;
    let mut queue_done = false;

    while retired < cfg.total_updates {
        // Injected fault: die at the start of round `retired`, exactly as a
        // crashed learner process would (before any of the round's effects).
        if let Some(f) = &cfg.fault {
            if f.should_kill(cfg.replica_id, retired) {
                bail!(
                    "injected fault: learner replica {} killed at round {}",
                    cfg.replica_id,
                    retired
                );
            }
        }
        // ---- fill: launch grad rounds while the pipeline has slots -------
        while !queue_done && launched < cfg.total_updates && in_flight.len() < cfg.pipeline {
            while pending.len() < l && !queue_done {
                let t_pop = Instant::now();
                let popped = if in_flight.is_empty() {
                    // Nothing to retire: block until data (or shutdown).
                    h.queue.pop().map(Some)
                } else {
                    // Rounds in flight: poll briefly — prefetch a bundle if
                    // one is there, otherwise go retire instead of stalling.
                    h.queue.pop_timeout(PREFETCH_POLL)
                };
                pop_blocked += t_pop.elapsed();
                match popped {
                    Ok(Some(bundle)) => {
                        if bundle.len() % l != 0 {
                            bail!(
                                "bundle of {} shards not divisible by {} cores",
                                bundle.len(),
                                l
                            );
                        }
                        pending.extend(bundle);
                    }
                    Ok(None) => break, // prefetch poll expired: retire first
                    Err(_) => queue_done = true, // shutdown: drain finished
                }
            }
            if pending.len() < l {
                break;
            }
            let round = launch_round(cfg, h, &mut pending, &param_slot, &mut core_versions)?;
            in_flight.push_back(round);
            launched += 1;
        }

        // ---- retire the oldest round: grads → collective → apply ---------
        let Some(round) = in_flight.pop_front() else {
            if queue_done {
                break; // queue drained mid-run: no more updates possible
            }
            continue;
        };

        // 1) harvest the round's gradients (buffers moved, not copied)
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(l);
        let mut loss = 0.0f32;
        for rx in round.waits {
            let mut outs = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("learner core died"))?
                .context("grad program")?;
            loss += outs[1].as_f32()?[0];
            grads.push(outs.swap_remove(0).into_f32()?);
        }
        loss /= l as f32;
        // Issue → harvest: at depth ≥ 2 this span includes device queueing
        // behind the previous round, which is exactly the hidden work the
        // overlap metric counts.
        let grad_span = round.issued.elapsed();
        grad_busy += grad_span;
        h.stats.grad_latency.record(grad_span);

        // 2) collective: within replica, then across replicas
        let t_coll = Instant::now();
        all_reduce_mean(&mut grads)?;
        let global = h.bus.all_reduce(cfg.replica_id, std::mem::take(&mut grads[0]))?;
        collective_busy += t_coll.elapsed();

        // 3) apply once, publish. The apply chains from the *latest*
        //    published params: at depth ≥ 2 the round's grad snapshot is an
        //    apply behind, and chaining from it would silently drop the
        //    in-between update — only the gradient is allowed to be stale
        //    (DESIGN.md §9). At depth 1 `latest()` is the round's snapshot,
        //    bit-for-bit. The measured span includes core-0 queueing behind
        //    the next round's grad at depth ≥ 2 (span caveats in §9).
        let t_apply = Instant::now();
        let current = h.store.latest();
        let apply_inputs = vec![
            HostTensor::f32_shared(vec![current.params.len()], current.params.clone(), 0)?,
            HostTensor::f32(vec![opt_state.len()], std::mem::take(&mut opt_state))?,
            HostTensor::f32(vec![global.len()], global)?,
        ];
        let mut outs = h.cores[0]
            .execute(&cfg.apply_program, apply_inputs)
            .context("apply program")?;
        opt_state = outs.swap_remove(1).into_f32()?;
        let new_params = outs.swap_remove(0).into_f32()?;
        apply_busy += t_apply.elapsed();
        h.stats.apply_latency.record(t_apply.elapsed());

        h.store.publish(new_params);
        // Staleness against the snapshot this round actually grad-ed on —
        // not the store version at bundle-pop time, which understates
        // rounds 2..n of a micro-batched bundle (each publish in between
        // ages the data) and every pipelined round.
        h.stats
            .record_update(round.snap.version.saturating_sub(round.data_version), loss);
        retired += 1;

        if let Some(ck) = &cfg.checkpoint {
            if ck.spec.due(retired) {
                write_checkpoint(cfg, ck, retired, &opt_state, h)
                    .with_context(|| format!("checkpoint after round {retired}"))?;
            }
        }
    }

    h.stats.record_learner_overlap(
        grad_busy,
        collective_busy,
        apply_busy,
        t_loop.elapsed().saturating_sub(pop_blocked),
    );

    let final_params = h.store.latest().params.as_ref().clone();
    Ok((final_params, opt_state))
}
