//! The learner thread: grad on every learner core, collective, apply.
//!
//! One learner thread per replica (the paper: "a single learner thread on
//! host then takes the handle to the data (already sharded across the
//! appropriate learner cores), and executes the same update function on all
//! the TPU cores dedicated to learning"). Per bundle round:
//!
//! 1. launch the grad program on all learner cores concurrently
//!    (`execute_async`), one shard each;
//! 2. all-reduce the gradients (deterministic tree mean) — within the
//!    replica, then across replicas on the [`GradientBus`];
//! 3. run the apply program once, publish the new parameters.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::HostTensor;
use crate::runtime::DeviceHandle;

use super::actor::ShardBundle;
use super::collective::{all_reduce_mean, GradientBus};
use super::param_store::ParamStore;
use super::queue::BoundedQueue;
use super::stats::RunStats;

pub struct LearnerConfig {
    pub replica_id: usize,
    pub grad_program: String,
    pub apply_program: String,
    /// Shards per update round (= learner cores).
    pub shards_per_round: usize,
    pub total_updates: u64,
}

pub struct LearnerHandles {
    pub cores: Vec<DeviceHandle>,
    pub store: Arc<ParamStore>,
    pub queue: Arc<BoundedQueue<ShardBundle>>,
    pub stats: Arc<RunStats>,
    pub bus: Arc<GradientBus>,
}

/// Run the learner loop to `total_updates` on the calling thread.
/// Returns the final (params, opt_state).
pub fn learner_main(
    cfg: &LearnerConfig,
    h: &LearnerHandles,
    mut opt_state: Vec<f32>,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let l = h.cores.len();
    if l == 0 {
        bail!("no learner cores");
    }
    if cfg.shards_per_round != l {
        bail!("shards_per_round {} != learner cores {}", cfg.shards_per_round, l);
    }

    let mut updates = 0u64;
    'outer: while updates < cfg.total_updates {
        let bundle = match h.queue.pop() {
            Ok(b) => b,
            Err(_) => break, // shutdown: drain finished
        };
        if bundle.len() % l != 0 {
            bail!("bundle of {} shards not divisible by {} cores", bundle.len(), l);
        }
        let staleness = h
            .store
            .version()
            .saturating_sub(bundle[0].param_version);

        // micro-batch rounds: bundle = rounds x cores shards
        let rounds = bundle.len() / l;
        let mut shards = bundle.into_iter();
        for _round in 0..rounds {
            let snap = h.store.latest();
            let params =
                HostTensor::f32(vec![snap.params.len()], snap.params.clone())?;

            // 1) grad on all learner cores concurrently (shards moved, not
            //    copied — pixel trajectories are tens of MB; §Perf L3-2)
            let t0 = Instant::now();
            let mut waits = Vec::with_capacity(l);
            for core in h.cores.iter() {
                let shard = shards.next().expect("bundle size checked above");
                let mut inputs = vec![params.clone()];
                inputs.extend(shard.into_tensors()?);
                waits.push(core.execute_async(&cfg.grad_program, inputs)?);
            }
            let mut grads: Vec<Vec<f32>> = Vec::with_capacity(l);
            let mut loss = 0.0f32;
            for rx in waits {
                let mut outs = rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("learner core died"))?
                    .context("grad program")?;
                loss += outs[1].as_f32()?[0];
                // take ownership — no gradient-buffer copy (§Perf L3-2)
                grads.push(outs.swap_remove(0).into_f32()?);
            }
            loss /= l as f32;
            h.stats.grad_latency.record(t0.elapsed());

            // 2) collective: within replica, then across replicas
            all_reduce_mean(&mut grads)?;
            let global = h.bus.all_reduce(cfg.replica_id, std::mem::take(&mut grads[0]))?;

            // 3) apply once, publish
            let t1 = Instant::now();
            let apply_inputs = vec![
                params.clone(),
                HostTensor::f32(vec![opt_state.len()], std::mem::take(&mut opt_state))?,
                HostTensor::f32(vec![global.len()], global)?,
            ];
            let mut outs = h.cores[0]
                .execute(&cfg.apply_program, apply_inputs)
                .context("apply program")?;
            opt_state = outs.swap_remove(1).into_f32()?;
            let new_params = outs.swap_remove(0).into_f32()?;
            h.stats.apply_latency.record(t1.elapsed());

            h.store.publish(new_params);
            h.stats.record_update(staleness, loss);
            updates += 1;
            if updates >= cfg.total_updates {
                break 'outer;
            }
        }
    }

    let final_params = h.store.latest().params.clone();
    Ok((final_params, opt_state))
}
