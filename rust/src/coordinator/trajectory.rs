//! Trajectories: fixed-geometry experience windows, arena-backed.
//!
//! The paper's actors "place the Python reference to this tensor data onto a
//! queue" — a reference, not a copy. [`TrajectoryBuilder`] therefore writes
//! every step straight into one `Arc`-shared [`TrajArena`] per window, laid
//! out *shard-major*: the arena is partitioned into `num_shards` contiguous
//! blocks (one per learner slot), each block time-major with the exact
//! layout the exported grad programs expect (`obs [T+1, bs, obs...]`,
//! `actions/rewards/discounts [T, bs]`, `behaviour_logits [T, bs, A]`).
//! Sharding is then pure pointer arithmetic — [`TrajShard`] is an arena
//! handle plus a column range, and `TrajShard::to_tensors` yields
//! `Arc`-backed [`HostTensor`] views — so a window travels
//! actor -> queue -> learner -> device with zero host-side copies
//! (DESIGN.md §11).
//!
//! [`Trajectory`] remains as the *materialized* full-window form: the
//! canonical time-major layout used by tests, the copying-path oracle and
//! diagnostics.

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::runtime::tensor::HostTensor;

/// A materialized trajectory window in canonical time-major layout
/// (`obs [T+1, B, obs...]`, row-major flat `Vec`s). Production code moves
/// [`TrajShard`] views instead; this form is the reference currency for
/// tests and the copying oracle.
#[derive(Clone, Debug)]
pub struct Trajectory {
    pub t_len: usize,
    pub batch: usize,
    pub obs_shape: Vec<usize>,
    pub num_actions: usize,
    /// `[T+1, B, obs...]`
    pub obs: Vec<f32>,
    /// `[T, B]`
    pub actions: Vec<i32>,
    /// `[T, B]`
    pub rewards: Vec<f32>,
    /// `[T, B]` — 0 at episode boundaries, else the discount factor.
    pub discounts: Vec<f32>,
    /// `[T, B, A]` — logits of the policy that acted (for V-trace), or MCTS
    /// visit distributions (for MuZero, where they are the policy targets).
    pub behaviour_logits: Vec<f32>,
    /// Version of the parameters that generated this data (staleness stats).
    pub param_version: u64,
    /// Which actor thread produced it.
    pub actor_id: usize,
}

impl Trajectory {
    pub fn obs_numel(&self) -> usize {
        self.obs_shape.iter().product()
    }

    /// Total environment frames represented (T * B).
    pub fn frames(&self) -> usize {
        self.t_len * self.batch
    }

    /// Package as grad-program inputs (after the params tensor), consuming
    /// the trajectory — zero buffer copies (§Perf L3-2). Pixel trajectories
    /// are tens of MB, so the copy this avoids is material.
    pub fn into_tensors(self) -> Result<Vec<HostTensor>> {
        let d = self.obs_numel();
        let mut obs_shape = vec![self.t_len + 1, self.batch];
        obs_shape.extend_from_slice(&self.obs_shape);
        debug_assert_eq!(self.obs.len(), (self.t_len + 1) * self.batch * d);
        Ok(vec![
            HostTensor::f32(obs_shape, self.obs)?,
            HostTensor::i32(vec![self.t_len, self.batch], self.actions)?,
            HostTensor::f32(vec![self.t_len, self.batch], self.rewards)?,
            HostTensor::f32(vec![self.t_len, self.batch], self.discounts)?,
            HostTensor::f32(
                vec![self.t_len, self.batch, self.num_actions],
                self.behaviour_logits,
            )?,
        ])
    }

    /// Package as grad-program inputs (after the params tensor).
    pub fn to_tensors(&self) -> Result<Vec<HostTensor>> {
        self.clone().into_tensors()
    }

    /// Mean reward per frame (diagnostics).
    pub fn mean_reward(&self) -> f32 {
        if self.rewards.is_empty() {
            return 0.0;
        }
        self.rewards.iter().sum::<f32>() / self.rewards.len() as f32
    }

    /// Number of episode boundaries in the window.
    pub fn episodes_ended(&self) -> usize {
        self.discounts.iter().filter(|&&d| d == 0.0).count()
    }

    /// Copy one shard-shaped column block (time-major, `bs` envs wide,
    /// geometry inferred from the slices) into this window at column
    /// offset `col0`. The single decoder of the shard/arena block layout:
    /// both `TrajArena::to_trajectory` and `sharder::unshard` go through
    /// here, so the production layout can never drift from the oracle's.
    pub(crate) fn fill_block(
        &mut self,
        col0: usize,
        obs: &[f32],
        actions: &[i32],
        rewards: &[f32],
        discounts: &[f32],
        behaviour_logits: &[f32],
    ) {
        let t = self.t_len;
        let d = self.obs_numel();
        let a = self.num_actions;
        let total_b = self.batch;
        let bs = actions.len() / t.max(1);
        debug_assert_eq!(obs.len(), (t + 1) * bs * d);
        debug_assert_eq!(behaviour_logits.len(), t * bs * a);
        debug_assert!(col0 + bs <= total_b);
        for ti in 0..=t {
            let src = ti * bs * d;
            let dst = ti * total_b * d + col0 * d;
            self.obs[dst..dst + bs * d].copy_from_slice(&obs[src..src + bs * d]);
        }
        for ti in 0..t {
            let src = ti * bs;
            let dst = ti * total_b + col0;
            self.actions[dst..dst + bs].copy_from_slice(&actions[src..src + bs]);
            self.rewards[dst..dst + bs].copy_from_slice(&rewards[src..src + bs]);
            self.discounts[dst..dst + bs].copy_from_slice(&discounts[src..src + bs]);
            let lsrc = ti * bs * a;
            let ldst = ti * total_b * a + col0 * a;
            self.behaviour_logits[ldst..ldst + bs * a]
                .copy_from_slice(&behaviour_logits[lsrc..lsrc + bs * a]);
        }
    }
}

/// One window of experience in a shard-major arena: `num_shards` contiguous
/// per-learner-slot blocks, each block time-major. Columns are `Arc`-shared
/// so shard views ([`TrajShard`]) and device uploads reference the same
/// buffers the builder filled — the window is written exactly once.
#[derive(Debug)]
pub struct TrajArena {
    pub t_len: usize,
    /// Total environments in the window (all shards together).
    pub batch: usize,
    pub obs_shape: Vec<usize>,
    pub num_actions: usize,
    /// Contiguous blocks the arena is partitioned into (learner slots).
    pub num_shards: usize,
    /// Version of the parameters that generated this data.
    pub param_version: u64,
    /// Which actor thread produced it.
    pub actor_id: usize,
    /// `[S][T+1, bs, obs...]` — shard blocks, each time-major.
    pub obs: Arc<Vec<f32>>,
    /// `[S][T, bs]`
    pub actions: Arc<Vec<i32>>,
    /// `[S][T, bs]`
    pub rewards: Arc<Vec<f32>>,
    /// `[S][T, bs]`
    pub discounts: Arc<Vec<f32>>,
    /// `[S][T, bs, A]`
    pub behaviour_logits: Arc<Vec<f32>>,
}

impl TrajArena {
    pub fn obs_numel(&self) -> usize {
        self.obs_shape.iter().product()
    }

    /// Environments per shard block.
    pub fn shard_batch(&self) -> usize {
        self.batch / self.num_shards
    }

    /// Total environment frames represented (T * B).
    pub fn frames(&self) -> usize {
        self.t_len * self.batch
    }

    /// Elements in one shard's obs block: `(T+1) * bs * obs_numel`.
    pub fn obs_block(&self) -> usize {
        (self.t_len + 1) * self.shard_batch() * self.obs_numel()
    }

    /// Elements in one shard's actions/rewards/discounts block: `T * bs`.
    pub fn scalar_block(&self) -> usize {
        self.t_len * self.shard_batch()
    }

    /// Elements in one shard's logits block: `T * bs * A`.
    pub fn logit_block(&self) -> usize {
        self.scalar_block() * self.num_actions
    }

    /// Build an arena from already-laid-out shard-major columns (tests,
    /// the copying oracle). With `num_shards = 1` the expected layout is
    /// plain time-major — identical to [`Trajectory`]'s.
    #[allow(clippy::too_many_arguments)]
    pub fn from_columns(
        t_len: usize,
        batch: usize,
        obs_shape: &[usize],
        num_actions: usize,
        num_shards: usize,
        obs: Vec<f32>,
        actions: Vec<i32>,
        rewards: Vec<f32>,
        discounts: Vec<f32>,
        behaviour_logits: Vec<f32>,
        param_version: u64,
        actor_id: usize,
    ) -> Result<Arc<Self>> {
        ensure!(num_shards >= 1, "num_shards must be >= 1");
        ensure!(
            batch % num_shards == 0,
            "batch {batch} not divisible into {num_shards} shards"
        );
        let d: usize = obs_shape.iter().product();
        ensure!(obs.len() == (t_len + 1) * batch * d, "obs column size mismatch");
        ensure!(actions.len() == t_len * batch, "actions column size mismatch");
        ensure!(rewards.len() == t_len * batch, "rewards column size mismatch");
        ensure!(discounts.len() == t_len * batch, "discounts column size mismatch");
        ensure!(
            behaviour_logits.len() == t_len * batch * num_actions,
            "logits column size mismatch"
        );
        Ok(Arc::new(Self {
            t_len,
            batch,
            obs_shape: obs_shape.to_vec(),
            num_actions,
            num_shards,
            param_version,
            actor_id,
            obs: Arc::new(obs),
            actions: Arc::new(actions),
            rewards: Arc::new(rewards),
            discounts: Arc::new(discounts),
            behaviour_logits: Arc::new(behaviour_logits),
        }))
    }

    /// Materialize the full window in canonical time-major layout
    /// (inverse of the shard-major interleave; tests / diagnostics only).
    /// Decodes through `Trajectory::fill_block` — the same block decoder
    /// `sharder::unshard` uses.
    pub fn to_trajectory(&self) -> Trajectory {
        let t = self.t_len;
        let bs = self.shard_batch();
        let d = self.obs_numel();
        let a = self.num_actions;
        let total_b = self.batch;
        let mut out = Trajectory {
            t_len: t,
            batch: total_b,
            obs_shape: self.obs_shape.clone(),
            num_actions: a,
            obs: vec![0.0; (t + 1) * total_b * d],
            actions: vec![0; t * total_b],
            rewards: vec![0.0; t * total_b],
            discounts: vec![0.0; t * total_b],
            behaviour_logits: vec![0.0; t * total_b * a],
            param_version: self.param_version,
            actor_id: self.actor_id,
        };
        for s in 0..self.num_shards {
            let (ob, sb_, lb) = (self.obs_block(), self.scalar_block(), self.logit_block());
            out.fill_block(
                s * bs,
                &self.obs[s * ob..(s + 1) * ob],
                &self.actions[s * sb_..(s + 1) * sb_],
                &self.rewards[s * sb_..(s + 1) * sb_],
                &self.discounts[s * sb_..(s + 1) * sb_],
                &self.behaviour_logits[s * lb..(s + 1) * lb],
            );
        }
        out
    }

    /// Mean reward per frame (diagnostics; layout-independent).
    pub fn mean_reward(&self) -> f32 {
        if self.rewards.is_empty() {
            return 0.0;
        }
        self.rewards.iter().sum::<f32>() / self.rewards.len() as f32
    }

    /// Number of episode boundaries in the window (layout-independent).
    pub fn episodes_ended(&self) -> usize {
        self.discounts.iter().filter(|&&d| d == 0.0).count()
    }
}

/// A lightweight view of one shard of a window: an arena handle plus the
/// column range `[index * bs, (index + 1) * bs)`. Cloning or queueing a
/// shard clones an `Arc`; the experience data is never copied.
#[derive(Clone, Debug)]
pub struct TrajShard {
    arena: Arc<TrajArena>,
    index: usize,
}

impl TrajShard {
    pub fn new(arena: Arc<TrajArena>, index: usize) -> Self {
        assert!(index < arena.num_shards, "shard index {index} out of range");
        Self { arena, index }
    }

    pub fn arena(&self) -> &Arc<TrajArena> {
        &self.arena
    }

    pub fn index(&self) -> usize {
        self.index
    }

    pub fn t_len(&self) -> usize {
        self.arena.t_len
    }

    /// Environments in this shard.
    pub fn batch(&self) -> usize {
        self.arena.shard_batch()
    }

    pub fn obs_numel(&self) -> usize {
        self.arena.obs_numel()
    }

    pub fn num_actions(&self) -> usize {
        self.arena.num_actions
    }

    pub fn param_version(&self) -> u64 {
        self.arena.param_version
    }

    pub fn actor_id(&self) -> usize {
        self.arena.actor_id
    }

    /// Environment frames in this shard (T * bs).
    pub fn frames(&self) -> usize {
        self.arena.t_len * self.batch()
    }

    /// `[T+1, bs, obs...]` — this shard's slice of the arena.
    pub fn obs(&self) -> &[f32] {
        let b = self.arena.obs_block();
        &self.arena.obs[self.index * b..(self.index + 1) * b]
    }

    /// `[T, bs]`
    pub fn actions(&self) -> &[i32] {
        let b = self.arena.scalar_block();
        &self.arena.actions[self.index * b..(self.index + 1) * b]
    }

    /// `[T, bs]`
    pub fn rewards(&self) -> &[f32] {
        let b = self.arena.scalar_block();
        &self.arena.rewards[self.index * b..(self.index + 1) * b]
    }

    /// `[T, bs]`
    pub fn discounts(&self) -> &[f32] {
        let b = self.arena.scalar_block();
        &self.arena.discounts[self.index * b..(self.index + 1) * b]
    }

    /// `[T, bs, A]`
    pub fn behaviour_logits(&self) -> &[f32] {
        let b = self.arena.logit_block();
        &self.arena.behaviour_logits[self.index * b..(self.index + 1) * b]
    }

    /// Package as grad-program inputs (after the params tensor): five
    /// `Arc`-backed tensor views into the arena — no data is copied on the
    /// host; the only copy left is the host->device transfer itself.
    pub fn to_tensors(&self) -> Result<Vec<HostTensor>> {
        let a = &self.arena;
        let bs = a.shard_batch();
        let mut obs_shape = vec![a.t_len + 1, bs];
        obs_shape.extend_from_slice(&a.obs_shape);
        Ok(vec![
            HostTensor::f32_shared(obs_shape, a.obs.clone(), self.index * a.obs_block())?,
            HostTensor::i32_shared(
                vec![a.t_len, bs],
                a.actions.clone(),
                self.index * a.scalar_block(),
            )?,
            HostTensor::f32_shared(
                vec![a.t_len, bs],
                a.rewards.clone(),
                self.index * a.scalar_block(),
            )?,
            HostTensor::f32_shared(
                vec![a.t_len, bs],
                a.discounts.clone(),
                self.index * a.scalar_block(),
            )?,
            HostTensor::f32_shared(
                vec![a.t_len, bs, a.num_actions],
                a.behaviour_logits.clone(),
                self.index * a.logit_block(),
            )?,
        ])
    }

    /// Materialize this shard alone as a [`Trajectory`] (tests, oracle).
    pub fn to_trajectory(&self) -> Trajectory {
        Trajectory {
            t_len: self.t_len(),
            batch: self.batch(),
            obs_shape: self.arena.obs_shape.clone(),
            num_actions: self.num_actions(),
            obs: self.obs().to_vec(),
            actions: self.actions().to_vec(),
            rewards: self.rewards().to_vec(),
            discounts: self.discounts().to_vec(),
            behaviour_logits: self.behaviour_logits().to_vec(),
            param_version: self.param_version(),
            actor_id: self.actor_id(),
        }
    }
}

/// Copy one batch-wide row (`src`, per-env width `w`) into its shard-major
/// position for time index `t`: shard `s` has `rows` rows of `bs * w`.
fn scatter_row<T: Copy>(
    dst: &mut [T],
    src: &[T],
    t: usize,
    rows: usize,
    bs: usize,
    w: usize,
    num_shards: usize,
) {
    let row_w = bs * w;
    let block = rows * row_w;
    for s in 0..num_shards {
        let d0 = s * block + t * row_w;
        let s0 = s * row_w;
        dst[d0..d0 + row_w].copy_from_slice(&src[s0..s0 + row_w]);
    }
}

/// Accumulates one window, step by step, on the actor thread — writing
/// directly into the (future) arena's shard-major buffers, so `finish`
/// hands out an `Arc<TrajArena>` without relayout or copy.
pub struct TrajectoryBuilder {
    t_len: usize,
    batch: usize,
    obs_shape: Vec<usize>,
    num_actions: usize,
    num_shards: usize,
    steps_pushed: usize,
    obs: Vec<f32>,
    actions: Vec<i32>,
    rewards: Vec<f32>,
    discounts: Vec<f32>,
    behaviour_logits: Vec<f32>,
}

impl TrajectoryBuilder {
    pub fn new(
        t_len: usize,
        batch: usize,
        obs_shape: &[usize],
        num_actions: usize,
        num_shards: usize,
    ) -> Self {
        assert!(num_shards >= 1, "num_shards must be >= 1");
        assert!(
            batch % num_shards == 0,
            "batch {batch} not divisible into {num_shards} shards"
        );
        let d: usize = obs_shape.iter().product();
        Self {
            t_len,
            batch,
            obs_shape: obs_shape.to_vec(),
            num_actions,
            num_shards,
            steps_pushed: 0,
            obs: vec![0.0; (t_len + 1) * batch * d],
            actions: vec![0; t_len * batch],
            rewards: vec![0.0; t_len * batch],
            discounts: vec![0.0; t_len * batch],
            behaviour_logits: vec![0.0; t_len * batch * num_actions],
        }
    }

    pub fn is_full(&self) -> bool {
        self.steps_pushed == self.t_len
    }

    pub fn steps(&self) -> usize {
        self.steps_pushed
    }

    fn obs_numel(&self) -> usize {
        self.obs_shape.iter().product()
    }

    /// Push one step: the observation the policy saw, the actions/logits it
    /// chose, and the env's reward/discount response.
    pub fn push_step(
        &mut self,
        obs: &[f32],
        actions: &[i32],
        behaviour_logits: &[f32],
        rewards: &[f32],
        discounts: &[f32],
    ) -> Result<()> {
        let d = self.obs_numel();
        if self.is_full() {
            bail!("trajectory already has {} steps", self.t_len);
        }
        if obs.len() != self.batch * d
            || actions.len() != self.batch
            || behaviour_logits.len() != self.batch * self.num_actions
            || rewards.len() != self.batch
            || discounts.len() != self.batch
        {
            bail!("push_step: size mismatch");
        }
        let (t, bs, n) = (self.steps_pushed, self.batch / self.num_shards, self.num_shards);
        scatter_row(&mut self.obs, obs, t, self.t_len + 1, bs, d, n);
        scatter_row(&mut self.actions, actions, t, self.t_len, bs, 1, n);
        scatter_row(&mut self.rewards, rewards, t, self.t_len, bs, 1, n);
        scatter_row(&mut self.discounts, discounts, t, self.t_len, bs, 1, n);
        scatter_row(
            &mut self.behaviour_logits,
            behaviour_logits,
            t,
            self.t_len,
            bs,
            self.num_actions,
            n,
        );
        self.steps_pushed += 1;
        Ok(())
    }

    /// Finish with the bootstrap observation (the T+1'th), producing the
    /// `Arc`-shared arena and resetting the builder for the next window.
    /// The filled buffers are *moved* into the arena — no copy.
    pub fn finish(
        &mut self,
        final_obs: &[f32],
        param_version: u64,
        actor_id: usize,
    ) -> Result<Arc<TrajArena>> {
        let d = self.obs_numel();
        if !self.is_full() {
            bail!("trajectory has {}/{} steps", self.steps_pushed, self.t_len);
        }
        if final_obs.len() != self.batch * d {
            bail!("finish: obs size mismatch");
        }
        let (bs, n) = (self.batch / self.num_shards, self.num_shards);
        scatter_row(&mut self.obs, final_obs, self.t_len, self.t_len + 1, bs, d, n);
        self.steps_pushed = 0;
        let obs = std::mem::replace(&mut self.obs, vec![0.0; (self.t_len + 1) * self.batch * d]);
        let actions = std::mem::replace(&mut self.actions, vec![0; self.t_len * self.batch]);
        let rewards = std::mem::replace(&mut self.rewards, vec![0.0; self.t_len * self.batch]);
        let discounts = std::mem::replace(&mut self.discounts, vec![0.0; self.t_len * self.batch]);
        let behaviour_logits = std::mem::replace(
            &mut self.behaviour_logits,
            vec![0.0; self.t_len * self.batch * self.num_actions],
        );
        Ok(Arc::new(TrajArena {
            t_len: self.t_len,
            batch: self.batch,
            obs_shape: self.obs_shape.clone(),
            num_actions: self.num_actions,
            num_shards: self.num_shards,
            param_version,
            actor_id,
            obs: Arc::new(obs),
            actions: Arc::new(actions),
            rewards: Arc::new(rewards),
            discounts: Arc::new(discounts),
            behaviour_logits: Arc::new(behaviour_logits),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_n(b: &mut TrajectoryBuilder, n: usize, batch: usize, d: usize, a: usize) {
        for t in 0..n {
            let obs = vec![t as f32; batch * d];
            let actions = vec![t as i32; batch];
            let logits = vec![0.1; batch * a];
            let rewards = vec![1.0; batch];
            let discounts = vec![0.99; batch];
            b.push_step(&obs, &actions, &logits, &rewards, &discounts).unwrap();
        }
    }

    #[test]
    fn builder_produces_correct_layout() {
        let (t, bsz, d, a) = (3, 2, 4, 3);
        let mut b = TrajectoryBuilder::new(t, bsz, &[d], a, 1);
        push_n(&mut b, 3, bsz, d, a);
        assert!(b.is_full());
        let arena = b.finish(&vec![9.0; bsz * d], 7, 1).unwrap();
        let traj = arena.to_trajectory();
        assert_eq!(traj.obs.len(), (t + 1) * bsz * d);
        assert_eq!(traj.actions.len(), t * bsz);
        assert_eq!(traj.behaviour_logits.len(), t * bsz * a);
        assert_eq!(traj.param_version, 7);
        assert_eq!(traj.actor_id, 1);
        // time-major: step 1's obs sit in the second B*d block
        assert_eq!(traj.obs[bsz * d], 1.0);
        assert_eq!(traj.obs[t * bsz * d], 9.0); // bootstrap obs last
        assert_eq!(traj.frames(), 6);
        assert_eq!(arena.frames(), 6);
        // single-shard arena: columns ARE the canonical layout
        assert_eq!(arena.obs.as_slice(), traj.obs.as_slice());
    }

    #[test]
    fn sharded_builder_matches_single_shard_canonical_layout() {
        // The shard-major scatter must be a pure re-layout: materializing
        // the full window is independent of num_shards.
        let (t, bsz, d, a) = (3, 6, 2, 3);
        let mut data_rng = crate::util::rng::Xoshiro256::new(5);
        let mut steps = Vec::new();
        for _ in 0..t {
            steps.push((
                (0..bsz * d).map(|_| data_rng.next_f32()).collect::<Vec<f32>>(),
                (0..bsz).map(|_| data_rng.next_below(a as u32) as i32).collect::<Vec<i32>>(),
                (0..bsz * a).map(|_| data_rng.next_f32()).collect::<Vec<f32>>(),
                (0..bsz).map(|_| data_rng.next_f32()).collect::<Vec<f32>>(),
                (0..bsz).map(|_| 0.99f32).collect::<Vec<f32>>(),
            ));
        }
        let final_obs: Vec<f32> = (0..bsz * d).map(|_| data_rng.next_f32()).collect();

        let mut canonical = None;
        for n in [1usize, 2, 3, 6] {
            let mut b = TrajectoryBuilder::new(t, bsz, &[d], a, n);
            for (obs, act, log, rew, disc) in &steps {
                b.push_step(obs, act, log, rew, disc).unwrap();
            }
            let traj = b.finish(&final_obs, 0, 0).unwrap().to_trajectory();
            match &canonical {
                None => canonical = Some(traj),
                Some(c) => {
                    assert_eq!(c.obs, traj.obs, "num_shards={n}: obs relayout diverged");
                    assert_eq!(c.actions, traj.actions, "num_shards={n}");
                    assert_eq!(c.rewards, traj.rewards, "num_shards={n}");
                    assert_eq!(c.discounts, traj.discounts, "num_shards={n}");
                    assert_eq!(c.behaviour_logits, traj.behaviour_logits, "num_shards={n}");
                }
            }
        }
    }

    #[test]
    fn shard_views_alias_the_arena() {
        let (t, bsz, d, a) = (2, 4, 3, 2);
        let mut b = TrajectoryBuilder::new(t, bsz, &[d], a, 2);
        push_n(&mut b, 2, bsz, d, a);
        let arena = b.finish(&vec![0.5; bsz * d], 3, 0).unwrap();
        let s0 = TrajShard::new(arena.clone(), 0);
        let s1 = TrajShard::new(arena.clone(), 1);
        // both views point into the same Arc'd columns
        assert!(Arc::ptr_eq(s0.arena(), s1.arena()));
        assert!(Arc::ptr_eq(&s0.arena().obs, &arena.obs));
        // slices tile the columns without overlap
        assert!(std::ptr::eq(s0.obs().as_ptr(), arena.obs.as_ptr()));
        assert!(std::ptr::eq(s1.obs().as_ptr(), arena.obs[arena.obs_block()..].as_ptr()));
        assert_eq!(s0.param_version(), 3);
        assert_eq!(s0.frames() + s1.frames(), arena.frames());
    }

    #[test]
    fn shard_tensors_are_shared_views() {
        let (t, bsz, d, a) = (2, 4, 3, 2);
        let mut b = TrajectoryBuilder::new(t, bsz, &[d], a, 2);
        push_n(&mut b, 2, bsz, d, a);
        let arena = b.finish(&vec![0.5; bsz * d], 0, 0).unwrap();
        let s1 = TrajShard::new(arena.clone(), 1);
        let tensors = s1.to_tensors().unwrap();
        assert_eq!(tensors[0].shape, vec![t + 1, 2, d]);
        assert_eq!(tensors[1].shape, vec![t, 2]);
        assert_eq!(tensors[4].shape, vec![t, 2, a]);
        for tensor in &tensors {
            assert!(tensor.is_shared(), "shard tensor materialized a copy");
        }
        // the obs tensor view aliases the arena's second block
        assert!(std::ptr::eq(
            tensors[0].as_f32().unwrap().as_ptr(),
            arena.obs[arena.obs_block()..].as_ptr()
        ));
    }

    #[test]
    fn builder_resets_after_finish() {
        let mut b = TrajectoryBuilder::new(2, 1, &[2], 2, 1);
        push_n(&mut b, 2, 1, 2, 2);
        let _ = b.finish(&[0.0, 0.0], 0, 0).unwrap();
        assert_eq!(b.steps(), 0);
        push_n(&mut b, 2, 1, 2, 2);
        let t2 = b.finish(&[0.0, 0.0], 1, 0).unwrap();
        assert_eq!(t2.obs.len(), 3 * 2);
        assert_eq!(t2.param_version, 1);
    }

    #[test]
    fn overfull_and_underfull_rejected() {
        let mut b = TrajectoryBuilder::new(1, 1, &[1], 2, 1);
        assert!(b.finish(&[0.0], 0, 0).is_err()); // underfull
        push_n(&mut b, 1, 1, 1, 2);
        let obs = [0.0];
        let act = [0];
        let log = [0.0, 0.0];
        let r = [0.0];
        let disc = [0.0];
        assert!(b.push_step(&obs, &act, &log, &r, &disc).is_err()); // overfull
    }

    #[test]
    fn size_mismatch_rejected() {
        let mut b = TrajectoryBuilder::new(2, 2, &[3], 2, 1);
        let bad_obs = vec![0.0; 5];
        assert!(b
            .push_step(&bad_obs, &[0, 0], &[0.0; 4], &[0.0; 2], &[0.0; 2])
            .is_err());
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn indivisible_shard_geometry_panics_at_construction() {
        let _ = TrajectoryBuilder::new(2, 5, &[1], 2, 2);
    }

    #[test]
    fn to_tensors_shapes() {
        let mut b = TrajectoryBuilder::new(2, 3, &[4, 4, 1], 5, 1);
        for _ in 0..2 {
            b.push_step(
                &vec![0.0; 3 * 16],
                &[0, 1, 2],
                &vec![0.0; 15],
                &[0.0; 3],
                &[0.9; 3],
            )
            .unwrap();
        }
        let arena = b.finish(&vec![0.0; 48], 0, 0).unwrap();
        let tensors = arena.to_trajectory().to_tensors().unwrap();
        assert_eq!(tensors[0].shape, vec![3, 3, 4, 4, 1]);
        assert_eq!(tensors[1].shape, vec![2, 3]);
        assert_eq!(tensors[4].shape, vec![2, 3, 5]);
        // the shard view of a single-shard arena has the same shapes + data
        let view = TrajShard::new(arena, 0).to_tensors().unwrap();
        assert_eq!(view, tensors);
    }

    #[test]
    fn episode_stats() {
        let mut b = TrajectoryBuilder::new(2, 2, &[1], 2, 2);
        b.push_step(&[0.0, 0.0], &[0, 0], &[0.0; 4], &[1.0, 0.0], &[0.99, 0.0]).unwrap();
        b.push_step(&[0.0, 0.0], &[0, 0], &[0.0; 4], &[0.0, 3.0], &[0.0, 0.99]).unwrap();
        let arena = b.finish(&[0.0, 0.0], 0, 0).unwrap();
        assert_eq!(arena.episodes_ended(), 2);
        assert!((arena.mean_reward() - 1.0).abs() < 1e-6);
        let t = arena.to_trajectory();
        assert_eq!(t.episodes_ended(), 2);
        assert!((t.mean_reward() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn from_columns_validates_geometry() {
        let ok = TrajArena::from_columns(
            1,
            2,
            &[1],
            2,
            1,
            vec![0.0; 4],
            vec![0; 2],
            vec![0.0; 2],
            vec![0.0; 2],
            vec![0.0; 4],
            0,
            0,
        );
        assert!(ok.is_ok());
        let bad = TrajArena::from_columns(
            1,
            2,
            &[1],
            2,
            1,
            vec![0.0; 3], // wrong obs length
            vec![0; 2],
            vec![0.0; 2],
            vec![0.0; 2],
            vec![0.0; 4],
            0,
            0,
        );
        assert!(bad.is_err());
    }
}
