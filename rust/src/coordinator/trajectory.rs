//! Trajectories: fixed-geometry, time-major experience buffers.
//!
//! Layouts match the exported grad programs exactly:
//! `obs [T+1, B, obs...]`, `actions/rewards/discounts [T, B]`,
//! `behaviour_logits [T, B, A]` — all flat row-major `Vec`s, so shipping a
//! trajectory to a learner core is a single buffer per field.

use anyhow::{bail, Result};

use crate::runtime::tensor::HostTensor;

#[derive(Clone, Debug)]
pub struct Trajectory {
    pub t_len: usize,
    pub batch: usize,
    pub obs_shape: Vec<usize>,
    pub num_actions: usize,
    /// `[T+1, B, obs...]`
    pub obs: Vec<f32>,
    /// `[T, B]`
    pub actions: Vec<i32>,
    /// `[T, B]`
    pub rewards: Vec<f32>,
    /// `[T, B]` — 0 at episode boundaries, else the discount factor.
    pub discounts: Vec<f32>,
    /// `[T, B, A]` — logits of the policy that acted (for V-trace), or MCTS
    /// visit distributions (for MuZero, where they are the policy targets).
    pub behaviour_logits: Vec<f32>,
    /// Version of the parameters that generated this data (staleness stats).
    pub param_version: u64,
    /// Which actor thread produced it.
    pub actor_id: usize,
}

impl Trajectory {
    pub fn obs_numel(&self) -> usize {
        self.obs_shape.iter().product()
    }

    /// Total environment frames represented (T * B).
    pub fn frames(&self) -> usize {
        self.t_len * self.batch
    }

    /// Package as grad-program inputs (after the params tensor), consuming
    /// the trajectory — zero buffer copies (§Perf L3-2). Pixel trajectories
    /// are tens of MB, so the copy this avoids is material.
    pub fn into_tensors(self) -> Result<Vec<HostTensor>> {
        let d = self.obs_numel();
        let mut obs_shape = vec![self.t_len + 1, self.batch];
        obs_shape.extend_from_slice(&self.obs_shape);
        debug_assert_eq!(self.obs.len(), (self.t_len + 1) * self.batch * d);
        Ok(vec![
            HostTensor::f32(obs_shape, self.obs)?,
            HostTensor::i32(vec![self.t_len, self.batch], self.actions)?,
            HostTensor::f32(vec![self.t_len, self.batch], self.rewards)?,
            HostTensor::f32(vec![self.t_len, self.batch], self.discounts)?,
            HostTensor::f32(
                vec![self.t_len, self.batch, self.num_actions],
                self.behaviour_logits,
            )?,
        ])
    }

    /// Package as grad-program inputs (after the params tensor).
    pub fn to_tensors(&self) -> Result<Vec<HostTensor>> {
        let d = self.obs_numel();
        let mut obs_shape = vec![self.t_len + 1, self.batch];
        obs_shape.extend_from_slice(&self.obs_shape);
        Ok(vec![
            HostTensor::f32(obs_shape, self.obs.clone())?,
            HostTensor::i32(vec![self.t_len, self.batch], self.actions.clone())?,
            HostTensor::f32(vec![self.t_len, self.batch], self.rewards.clone())?,
            HostTensor::f32(vec![self.t_len, self.batch], self.discounts.clone())?,
            HostTensor::f32(
                vec![self.t_len, self.batch, self.num_actions],
                self.behaviour_logits.clone(),
            )?,
        ])
        .and_then(|v: Vec<HostTensor>| {
            debug_assert_eq!(v[0].len(), (self.t_len + 1) * self.batch * d);
            Ok(v)
        })
    }

    /// Mean reward per frame (diagnostics).
    pub fn mean_reward(&self) -> f32 {
        if self.rewards.is_empty() {
            return 0.0;
        }
        self.rewards.iter().sum::<f32>() / self.rewards.len() as f32
    }

    /// Number of episode boundaries in the window.
    pub fn episodes_ended(&self) -> usize {
        self.discounts.iter().filter(|&&d| d == 0.0).count()
    }
}

/// Accumulates one trajectory, step by step, on the actor thread.
pub struct TrajectoryBuilder {
    t_len: usize,
    batch: usize,
    obs_shape: Vec<usize>,
    num_actions: usize,
    steps_pushed: usize,
    traj: Trajectory,
}

impl TrajectoryBuilder {
    pub fn new(t_len: usize, batch: usize, obs_shape: &[usize], num_actions: usize) -> Self {
        let d: usize = obs_shape.iter().product();
        Self {
            t_len,
            batch,
            obs_shape: obs_shape.to_vec(),
            num_actions,
            steps_pushed: 0,
            traj: Trajectory {
                t_len,
                batch,
                obs_shape: obs_shape.to_vec(),
                num_actions,
                obs: Vec::with_capacity((t_len + 1) * batch * d),
                actions: Vec::with_capacity(t_len * batch),
                rewards: Vec::with_capacity(t_len * batch),
                discounts: Vec::with_capacity(t_len * batch),
                behaviour_logits: Vec::with_capacity(t_len * batch * num_actions),
                param_version: 0,
                actor_id: 0,
            },
        }
    }

    pub fn is_full(&self) -> bool {
        self.steps_pushed == self.t_len
    }

    pub fn steps(&self) -> usize {
        self.steps_pushed
    }

    /// Push one step: the observation the policy saw, the actions/logits it
    /// chose, and the env's reward/discount response.
    pub fn push_step(
        &mut self,
        obs: &[f32],
        actions: &[i32],
        behaviour_logits: &[f32],
        rewards: &[f32],
        discounts: &[f32],
    ) -> Result<()> {
        let d = self.traj.obs_numel();
        if self.is_full() {
            bail!("trajectory already has {} steps", self.t_len);
        }
        if obs.len() != self.batch * d
            || actions.len() != self.batch
            || behaviour_logits.len() != self.batch * self.num_actions
            || rewards.len() != self.batch
            || discounts.len() != self.batch
        {
            bail!("push_step: size mismatch");
        }
        self.traj.obs.extend_from_slice(obs);
        self.traj.actions.extend_from_slice(actions);
        self.traj.behaviour_logits.extend_from_slice(behaviour_logits);
        self.traj.rewards.extend_from_slice(rewards);
        self.traj.discounts.extend_from_slice(discounts);
        self.steps_pushed += 1;
        Ok(())
    }

    /// Finish with the bootstrap observation (the T+1'th), producing the
    /// trajectory and resetting the builder for the next window.
    pub fn finish(&mut self, final_obs: &[f32], param_version: u64, actor_id: usize) -> Result<Trajectory> {
        let d = self.traj.obs_numel();
        if !self.is_full() {
            bail!("trajectory has {}/{} steps", self.steps_pushed, self.t_len);
        }
        if final_obs.len() != self.batch * d {
            bail!("finish: obs size mismatch");
        }
        self.traj.obs.extend_from_slice(final_obs);
        self.traj.param_version = param_version;
        self.traj.actor_id = actor_id;
        self.steps_pushed = 0;
        let fresh = TrajectoryBuilder::new(self.t_len, self.batch, &self.obs_shape, self.num_actions);
        Ok(std::mem::replace(&mut self.traj, fresh.traj))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_n(b: &mut TrajectoryBuilder, n: usize, batch: usize, d: usize, a: usize) {
        for t in 0..n {
            let obs = vec![t as f32; batch * d];
            let actions = vec![t as i32; batch];
            let logits = vec![0.1; batch * a];
            let rewards = vec![1.0; batch];
            let discounts = vec![0.99; batch];
            b.push_step(&obs, &actions, &logits, &rewards, &discounts).unwrap();
        }
    }

    #[test]
    fn builder_produces_correct_layout() {
        let (t, bsz, d, a) = (3, 2, 4, 3);
        let mut b = TrajectoryBuilder::new(t, bsz, &[d], a);
        push_n(&mut b, 3, bsz, d, a);
        assert!(b.is_full());
        let traj = b.finish(&vec![9.0; bsz * d], 7, 1).unwrap();
        assert_eq!(traj.obs.len(), (t + 1) * bsz * d);
        assert_eq!(traj.actions.len(), t * bsz);
        assert_eq!(traj.behaviour_logits.len(), t * bsz * a);
        assert_eq!(traj.param_version, 7);
        assert_eq!(traj.actor_id, 1);
        // time-major: step 1's obs sit in the second B*d block
        assert_eq!(traj.obs[bsz * d], 1.0);
        assert_eq!(traj.obs[t * bsz * d], 9.0); // bootstrap obs last
        assert_eq!(traj.frames(), 6);
    }

    #[test]
    fn builder_resets_after_finish() {
        let mut b = TrajectoryBuilder::new(2, 1, &[2], 2);
        push_n(&mut b, 2, 1, 2, 2);
        let _ = b.finish(&[0.0, 0.0], 0, 0).unwrap();
        assert_eq!(b.steps(), 0);
        push_n(&mut b, 2, 1, 2, 2);
        let t2 = b.finish(&[0.0, 0.0], 1, 0).unwrap();
        assert_eq!(t2.obs.len(), 3 * 2);
    }

    #[test]
    fn overfull_and_underfull_rejected() {
        let mut b = TrajectoryBuilder::new(1, 1, &[1], 2);
        assert!(b.finish(&[0.0], 0, 0).is_err()); // underfull
        push_n(&mut b, 1, 1, 1, 2);
        let obs = [0.0];
        let act = [0];
        let log = [0.0, 0.0];
        let r = [0.0];
        let disc = [0.0];
        assert!(b.push_step(&obs, &act, &log, &r, &disc).is_err()); // overfull
    }

    #[test]
    fn size_mismatch_rejected() {
        let mut b = TrajectoryBuilder::new(2, 2, &[3], 2);
        let bad_obs = vec![0.0; 5];
        assert!(b
            .push_step(&bad_obs, &[0, 0], &[0.0; 4], &[0.0; 2], &[0.0; 2])
            .is_err());
    }

    #[test]
    fn to_tensors_shapes() {
        let mut b = TrajectoryBuilder::new(2, 3, &[4, 4, 1], 5);
        for _ in 0..2 {
            b.push_step(
                &vec![0.0; 3 * 16],
                &[0, 1, 2],
                &vec![0.0; 15],
                &[0.0; 3],
                &[0.9; 3],
            )
            .unwrap();
        }
        let traj = b.finish(&vec![0.0; 48], 0, 0).unwrap();
        let tensors = traj.to_tensors().unwrap();
        assert_eq!(tensors[0].shape, vec![3, 3, 4, 4, 1]);
        assert_eq!(tensors[1].shape, vec![2, 3]);
        assert_eq!(tensors[4].shape, vec![2, 3, 5]);
    }

    #[test]
    fn episode_stats() {
        let mut b = TrajectoryBuilder::new(2, 2, &[1], 2);
        b.push_step(&[0.0, 0.0], &[0, 0], &[0.0; 4], &[1.0, 0.0], &[0.99, 0.0]).unwrap();
        b.push_step(&[0.0, 0.0], &[0, 0], &[0.0; 4], &[0.0, 3.0], &[0.0, 0.99]).unwrap();
        let t = b.finish(&[0.0, 0.0], 0, 0).unwrap();
        assert_eq!(t.episodes_ended(), 2);
        assert!((t.mean_reward() - 1.0).abs() < 1e-6);
    }
}
