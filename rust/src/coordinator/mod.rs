//! Sebulba: the decomposed actor/learner coordination architecture.
//!
//! The 8 cores of each simulated host are split into `A` actor cores and
//! `8 - A` learner cores (paper Fig. 1c / Fig. 3). Actor threads (≥1 per
//! actor core) step batched host-side environments and run batched inference
//! on their core, double-buffered over `pipeline_stages` sub-batches so env
//! stepping hides behind device time (DESIGN.md §2); completed windows live
//! in `Arc`-shared shard-major arenas, sharded along the batch dimension
//! into zero-copy views and queued to the learners (DESIGN.md §11); the
//! learner thread runs the grad program on every learner core, all-reduces
//! the gradients (the paper's `psum`), applies the update, and publishes
//! fresh parameters to the actor threads through the parameter store. The
//! learner rounds are themselves software-pipelined over
//! `learner_pipeline` slots so the collective and apply retire under the
//! next round's grads (DESIGN.md §9).

pub mod actor;
pub mod collective;
pub mod config;
pub mod learner;
pub mod param_store;
pub mod queue;
pub mod sebulba;
pub mod sharder;
pub mod stats;
pub mod trajectory;

pub use config::SebulbaConfig;
pub use sebulba::Sebulba;
