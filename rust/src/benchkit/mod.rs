//! Benchmark harness (the vendored crate set has no criterion).
//!
//! `Bench` runs named cases with warmup + repeats, reports mean/p50/p95 and
//! a domain metric (e.g. frames/s), prints a markdown table matching the
//! paper's figures, and dumps JSON to `bench_results/` so EXPERIMENTS.md can
//! cite exact numbers.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::math::{mean, percentile};

#[derive(Clone, Debug)]
pub struct CaseResult {
    pub name: String,
    /// Wall-clock seconds per repeat.
    pub times: Vec<f64>,
    /// Domain metric per repeat (e.g. frames/sec), if the case reports one.
    pub metrics: Vec<f64>,
    pub metric_name: String,
}

impl CaseResult {
    pub fn mean_time(&self) -> f64 {
        mean(&self.times)
    }

    pub fn mean_metric(&self) -> f64 {
        mean(&self.metrics)
    }

    pub fn p50_time(&self) -> f64 {
        percentile(&self.times, 50.0)
    }

    pub fn p95_time(&self) -> f64 {
        percentile(&self.times, 95.0)
    }
}

pub struct Bench {
    pub title: String,
    pub warmup: usize,
    pub repeats: usize,
    pub results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        let fast = std::env::var("PODRACER_BENCH_FAST").is_ok();
        Self {
            title: title.to_string(),
            warmup: if fast { 0 } else { 1 },
            repeats: if fast { 1 } else { 3 },
            results: Vec::new(),
        }
    }

    /// Run `f` warmup+repeats times. `f` returns the domain metric
    /// (`metric_name`, e.g. "fps") for the repeat.
    pub fn case<F>(&mut self, name: &str, metric_name: &str, mut f: F)
    where
        F: FnMut() -> f64,
    {
        for _ in 0..self.warmup {
            let _ = f();
        }
        let mut times = Vec::with_capacity(self.repeats);
        let mut metrics = Vec::with_capacity(self.repeats);
        for _ in 0..self.repeats {
            let t0 = Instant::now();
            let m = f();
            times.push(t0.elapsed().as_secs_f64());
            metrics.push(m);
        }
        let r = CaseResult {
            name: name.to_string(),
            times,
            metrics,
            metric_name: metric_name.to_string(),
        };
        eprintln!(
            "  [{}] {}: {:.3}s mean, {} = {:.1}",
            self.title,
            r.name,
            r.mean_time(),
            r.metric_name,
            r.mean_metric()
        );
        self.results.push(r);
    }

    /// Markdown table of all cases (the figure/table the bench regenerates).
    pub fn table(&self) -> String {
        let metric = self
            .results
            .first()
            .map(|r| r.metric_name.clone())
            .unwrap_or_else(|| "metric".into());
        let mut out = format!(
            "\n## {}\n\n| case | mean time (s) | p50 (s) | p95 (s) | {} |\n|---|---|---|---|---|\n",
            self.title, metric
        );
        for r in &self.results {
            out.push_str(&format!(
                "| {} | {:.4} | {:.4} | {:.4} | {:.1} |\n",
                r.name,
                r.mean_time(),
                r.p50_time(),
                r.p95_time(),
                r.mean_metric()
            ));
        }
        out
    }

    /// Write JSON results under `bench_results/<slug>.json`.
    pub fn dump_json(&self) -> std::io::Result<std::path::PathBuf> {
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let dir = std::path::Path::new("bench_results");
        std::fs::create_dir_all(dir)?;
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(&r.name)),
                    ("times", Json::arr_f64(&r.times)),
                    ("metrics", Json::arr_f64(&r.metrics)),
                    ("metric_name", Json::str(&r.metric_name)),
                ])
            })
            .collect();
        let j = Json::obj(vec![
            ("title", Json::str(&self.title)),
            ("warmup", Json::num(self.warmup as f64)),
            ("repeats", Json::num(self.repeats as f64)),
            ("cases", Json::Arr(cases)),
        ]);
        let path = dir.join(format!("{slug}.json"));
        std::fs::write(&path, j.to_string())?;
        Ok(path)
    }

    /// Print the table and dump JSON; call at the end of each bench binary.
    pub fn finish(&self) {
        println!("{}", self.table());
        match self.dump_json() {
            Ok(p) => eprintln!("  results -> {}", p.display()),
            Err(e) => eprintln!("  (could not write bench_results: {e})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_records_repeats() {
        std::env::set_var("PODRACER_BENCH_FAST", "1");
        let mut b = Bench::new("unit test bench");
        let mut calls = 0;
        b.case("one", "ops", || {
            calls += 1;
            42.0
        });
        assert_eq!(b.results.len(), 1);
        assert!(calls >= 1);
        assert_eq!(b.results[0].mean_metric(), 42.0);
        std::env::remove_var("PODRACER_BENCH_FAST");
    }

    #[test]
    fn table_contains_cases() {
        std::env::set_var("PODRACER_BENCH_FAST", "1");
        let mut b = Bench::new("tbl");
        b.case("fast_case", "fps", || 100.0);
        let t = b.table();
        assert!(t.contains("fast_case"));
        assert!(t.contains("fps"));
        std::env::remove_var("PODRACER_BENCH_FAST");
    }
}
