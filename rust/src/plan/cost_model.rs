//! The versioned, serializable per-stage cost model the planner consumes.
//!
//! A [`CostModel`] maps `(arch, env, batch)` to measured per-stage costs
//! ([`StageCosts`]): core-seconds per frame for env stepping, actor
//! inference and learner grads, and seconds per update for the collective
//! and the apply. Entries are populated by folding the per-stage seconds
//! every [`Report`] already carries (`fold`), so any run — a calibration
//! run, a bench, a production job — can teach the model.
//!
//! The on-disk format follows the checkpoint discipline (DESIGN.md §13):
//! versioned, CRC-checked, and fail-closed. The CRC is computed over the
//! *canonical* serialization of the entries (the in-house writer prints
//! sorted keys, no whitespace), so any truncation or byte flip is a typed
//! [`CostModelError`] — corruption never panics and never silently loads.

use std::collections::BTreeMap;
use std::path::Path;

use crate::checkpoint::format::crc32;
use crate::experiment::{Arch, Detail, Report, Topology};
use crate::util::json::Json;

/// On-disk format version; bump on any incompatible layout change.
pub const COST_MODEL_VERSION: u64 = 1;

/// Typed load/store failures. `Io` is the filesystem layer; everything else
/// means the bytes were read but rejected before any entry was trusted.
#[derive(Debug, thiserror::Error)]
pub enum CostModelError {
    #[error("cost model io: {0}")]
    Io(#[from] std::io::Error),
    /// Not parseable as JSON at all (covers every truncation).
    #[error("cost model parse: {0}")]
    Parse(String),
    #[error("cost model format version {found} unsupported (expected {expected})")]
    UnsupportedVersion { found: u64, expected: u64 },
    /// Parsed, but the structure or a field value is wrong.
    #[error("cost model corrupt: {0}")]
    Corrupt(String),
    #[error("cost model crc mismatch: stored {stored:#010x}, computed {computed:#010x}")]
    CrcMismatch { stored: u32, computed: u32 },
}

/// Measured per-stage costs for one `(arch, env, batch)` cell.
///
/// Frame-denominated fields are *core*-seconds per frame (summed device
/// time over the threads that produced the frames, divided by the frames),
/// so a candidate's rate per core is `1 / cost` regardless of how many
/// cores the calibration run used. Update-denominated fields are wall
/// seconds per learner update.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageCosts {
    /// Host env stepping, core-seconds per frame.
    pub env_step_s: f64,
    /// Actor inference (MCTS-inclusive for MuZero), core-seconds per frame.
    pub actor_infer_s: f64,
    /// Learner grads, core-seconds per frame (round wall × learner cores).
    pub learner_grad_s: f64,
    /// Gradient collective, seconds per update.
    pub learner_collective_s: f64,
    /// Optimizer apply, seconds per update.
    pub learner_apply_s: f64,
    /// Runs folded into this cell (weighted-mean denominator).
    pub samples: u64,
}

impl StageCosts {
    /// Merge one observation in as a sample-weighted running mean.
    fn observe(&mut self, obs: &StageCosts) {
        let n = self.samples as f64;
        let m = obs.samples.max(1) as f64;
        let mix = |old: f64, new: f64| (old * n + new * m) / (n + m);
        self.env_step_s = mix(self.env_step_s, obs.env_step_s);
        self.actor_infer_s = mix(self.actor_infer_s, obs.actor_infer_s);
        self.learner_grad_s = mix(self.learner_grad_s, obs.learner_grad_s);
        self.learner_collective_s = mix(self.learner_collective_s, obs.learner_collective_s);
        self.learner_apply_s = mix(self.learner_apply_s, obs.learner_apply_s);
        self.samples += obs.samples.max(1);
    }

    fn finite_nonneg(&self) -> bool {
        [
            self.env_step_s,
            self.actor_infer_s,
            self.learner_grad_s,
            self.learner_collective_s,
            self.learner_apply_s,
        ]
        .iter()
        .all(|s| s.is_finite() && *s >= 0.0)
    }
}

/// The model: `(arch, env, batch)` → [`StageCosts`]. BTreeMap keys give the
/// canonical (sorted) serialization order for free.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostModel {
    entries: BTreeMap<(String, String, usize), StageCosts>,
}

impl CostModel {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Iterate `(arch, env, batch, costs)` in canonical order.
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, usize, &StageCosts)> {
        self.entries
            .iter()
            .map(|((a, e, b), c)| (a.as_str(), e.as_str(), *b, c))
    }

    /// Merge one measured observation into the `(arch, env, batch)` cell.
    pub fn insert(&mut self, arch: Arch, env: &str, batch: usize, costs: StageCosts) {
        self.entries
            .entry((arch.as_str().to_string(), env.to_string(), batch))
            .or_default()
            .observe(&costs);
    }

    /// Fold the per-stage seconds a finished [`Report`] carries into the
    /// model. `topo` must be the topology the run actually used (the grad
    /// round wall is scaled by its learner cores back to core-seconds);
    /// `batch` keys the cell (the actor batch for Sebulba/MuZero, 1 for
    /// Anakin's per-core loop). Empty runs (zero frames) fold to nothing.
    pub fn fold(&mut self, report: &Report, env: &str, batch: usize, topo: &Topology) {
        let frames = report.steps as f64;
        let updates = report.updates as f64;
        if frames <= 0.0 {
            return;
        }
        let costs = match &report.detail {
            Detail::Anakin(d) => StageCosts {
                env_step_s: d.replica_host_seconds / frames,
                actor_infer_s: d.replica_device_seconds / frames,
                learner_grad_s: 0.0,
                learner_collective_s: if updates > 0.0 {
                    d.replica_collective_seconds / updates
                } else {
                    0.0
                },
                learner_apply_s: 0.0,
                samples: 1,
            },
            Detail::ActorLearner(d) => {
                // MuZero actors are search-bound and report their device
                // time as busy seconds rather than per-call infer latency;
                // fall back so the cell still captures the actor cost.
                let infer = if d.actor_infer_seconds > 0.0 {
                    d.actor_infer_seconds
                } else {
                    d.actor_busy_seconds
                };
                StageCosts {
                    env_step_s: d.actor_env_step_seconds / frames,
                    actor_infer_s: infer / frames,
                    learner_grad_s: d.learner_grad_seconds * topo.learner_cores as f64 / frames,
                    learner_collective_s: if updates > 0.0 {
                        d.learner_collective_seconds / updates
                    } else {
                        0.0
                    },
                    learner_apply_s: if updates > 0.0 {
                        d.learner_apply_seconds / updates
                    } else {
                        0.0
                    },
                    samples: 1,
                }
            }
        };
        self.insert(report.arch, env, batch, costs);
    }

    /// Look up the cell for `(arch, env)` nearest to `batch`: an exact hit,
    /// else the smallest batch distance, ties to the smaller batch (so the
    /// fallback is deterministic). Returns the batch actually matched.
    pub fn lookup(&self, arch: Arch, env: &str, batch: usize) -> Option<(usize, &StageCosts)> {
        let mut best: Option<(usize, &StageCosts)> = None;
        for ((a, e, b), c) in &self.entries {
            if a != arch.as_str() || e != env {
                continue;
            }
            let dist = b.abs_diff(batch);
            let better = match best {
                None => true,
                Some((cur, _)) => {
                    let cur_dist = cur.abs_diff(batch);
                    dist < cur_dist || (dist == cur_dist && *b < cur)
                }
            };
            if better {
                best = Some((*b, c));
            }
        }
        best
    }

    // -- serialization ------------------------------------------------------

    fn entry_json(arch: &str, env: &str, batch: usize, c: &StageCosts) -> Json {
        Json::obj(vec![
            ("arch", Json::str(arch)),
            ("env", Json::str(env)),
            ("batch", Json::num(batch as f64)),
            ("env_step_s", Json::num(c.env_step_s)),
            ("actor_infer_s", Json::num(c.actor_infer_s)),
            ("learner_grad_s", Json::num(c.learner_grad_s)),
            ("learner_collective_s", Json::num(c.learner_collective_s)),
            ("learner_apply_s", Json::num(c.learner_apply_s)),
            ("samples", Json::num(c.samples as f64)),
        ])
    }

    fn entries_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|((a, e, b), c)| Self::entry_json(a, e, *b, c))
                .collect(),
        )
    }

    /// Canonical serialized form: entries in key order, CRC over the
    /// canonical entries array, version stamp.
    pub fn to_bytes(&self) -> Vec<u8> {
        let entries = self.entries_json();
        let crc = crc32(entries.to_string().as_bytes());
        Json::obj(vec![
            ("format_version", Json::num(COST_MODEL_VERSION as f64)),
            ("crc32", Json::num(crc as f64)),
            ("entries", entries),
        ])
        .to_string()
        .into_bytes()
    }

    /// Strict load: parse → version gate → field-by-field validation → CRC
    /// over the re-canonicalized entries. Every failure is a typed
    /// [`CostModelError`]; nothing partial ever escapes.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, CostModelError> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| CostModelError::Parse(format!("not utf-8: {e}")))?;
        let doc = Json::parse(text).map_err(|e| CostModelError::Parse(e.to_string()))?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| CostModelError::Corrupt("top level is not an object".into()))?;
        for key in obj.keys() {
            if !matches!(key.as_str(), "format_version" | "crc32" | "entries") {
                return Err(CostModelError::Corrupt(format!("unknown top-level key {key:?}")));
            }
        }
        let version = read_u64(&doc, "format_version")?;
        if version != COST_MODEL_VERSION {
            return Err(CostModelError::UnsupportedVersion {
                found: version,
                expected: COST_MODEL_VERSION,
            });
        }
        let stored = read_u64(&doc, "crc32")?;
        let stored = u32::try_from(stored)
            .map_err(|_| CostModelError::Corrupt(format!("crc32 {stored} out of range")))?;
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| CostModelError::Corrupt("entries is not an array".into()))?;

        let mut model = CostModel::new();
        for (i, entry) in entries.iter().enumerate() {
            let (key, costs) = parse_entry(entry)
                .map_err(|msg| CostModelError::Corrupt(format!("entry {i}: {msg}")))?;
            if model.entries.insert(key.clone(), costs).is_some() {
                return Err(CostModelError::Corrupt(format!(
                    "duplicate entry for ({}, {}, {})",
                    key.0, key.1, key.2
                )));
            }
        }
        let computed = crc32(model.entries_json().to_string().as_bytes());
        if computed != stored {
            return Err(CostModelError::CrcMismatch { stored, computed });
        }
        Ok(model)
    }

    pub fn save(&self, path: &Path) -> Result<(), CostModelError> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self, CostModelError> {
        Self::from_bytes(&std::fs::read(path)?)
    }
}

fn read_u64(doc: &Json, key: &str) -> Result<u64, CostModelError> {
    let n = doc
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| CostModelError::Corrupt(format!("missing numeric key {key:?}")))?;
    if n.fract() != 0.0 || n < 0.0 || n > u64::MAX as f64 {
        return Err(CostModelError::Corrupt(format!("{key} is not a non-negative integer: {n}")));
    }
    Ok(n as u64)
}

fn parse_entry(entry: &Json) -> Result<((String, String, usize), StageCosts), String> {
    const KEYS: [&str; 9] = [
        "arch",
        "env",
        "batch",
        "env_step_s",
        "actor_infer_s",
        "learner_grad_s",
        "learner_collective_s",
        "learner_apply_s",
        "samples",
    ];
    let obj = entry.as_obj().ok_or("not an object")?;
    for key in obj.keys() {
        if !KEYS.contains(&key.as_str()) {
            return Err(format!("unknown key {key:?}"));
        }
    }
    let str_field = |key: &str| -> Result<String, String> {
        entry
            .get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("missing string key {key:?}"))
    };
    let num_field = |key: &str| -> Result<f64, String> {
        entry
            .get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("missing numeric key {key:?}"))
    };
    let int_field = |key: &str| -> Result<u64, String> {
        let n = num_field(key)?;
        if n.fract() != 0.0 || n < 0.0 || n > u64::MAX as f64 {
            return Err(format!("{key} is not a non-negative integer: {n}"));
        }
        Ok(n as u64)
    };

    let arch = str_field("arch")?;
    if !Arch::ALL.iter().any(|a| a.as_str() == arch) {
        return Err(format!("unknown arch {arch:?}"));
    }
    let env = str_field("env")?;
    let batch = int_field("batch")?;
    if batch == 0 {
        return Err("batch must be >= 1".into());
    }
    let costs = StageCosts {
        env_step_s: num_field("env_step_s")?,
        actor_infer_s: num_field("actor_infer_s")?,
        learner_grad_s: num_field("learner_grad_s")?,
        learner_collective_s: num_field("learner_collective_s")?,
        learner_apply_s: num_field("learner_apply_s")?,
        samples: int_field("samples")?,
    };
    if !costs.finite_nonneg() {
        return Err("stage seconds must be finite and non-negative".into());
    }
    Ok(((arch, env, batch as usize), costs))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_model() -> CostModel {
        let mut m = CostModel::new();
        m.insert(
            Arch::Sebulba,
            "catch",
            16,
            StageCosts {
                env_step_s: 1e-5,
                actor_infer_s: 2e-5,
                learner_grad_s: 3e-5,
                learner_collective_s: 4e-4,
                learner_apply_s: 5e-4,
                samples: 1,
            },
        );
        m.insert(
            Arch::Anakin,
            "catch",
            1,
            StageCosts { actor_infer_s: 1e-4, env_step_s: 2e-5, samples: 1, ..Default::default() },
        );
        m
    }

    #[test]
    fn roundtrip() {
        let m = sample_model();
        let loaded = CostModel::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(loaded, m);
    }

    #[test]
    fn observe_is_weighted_mean() {
        let mut m = CostModel::new();
        let obs = |infer: f64| StageCosts { actor_infer_s: infer, samples: 1, ..Default::default() };
        m.insert(Arch::Sebulba, "catch", 16, obs(1.0));
        m.insert(Arch::Sebulba, "catch", 16, obs(3.0));
        let (_, c) = m.lookup(Arch::Sebulba, "catch", 16).unwrap();
        assert_eq!(c.actor_infer_s, 2.0);
        assert_eq!(c.samples, 2);
    }

    #[test]
    fn lookup_nearest_batch_ties_to_smaller() {
        let mut m = CostModel::new();
        let c = StageCosts { samples: 1, ..Default::default() };
        m.insert(Arch::Sebulba, "catch", 8, c);
        m.insert(Arch::Sebulba, "catch", 32, c);
        assert_eq!(m.lookup(Arch::Sebulba, "catch", 8).unwrap().0, 8);
        assert_eq!(m.lookup(Arch::Sebulba, "catch", 30).unwrap().0, 32);
        // equidistant from 8 and 32: the smaller batch wins, deterministically
        assert_eq!(m.lookup(Arch::Sebulba, "catch", 20).unwrap().0, 8);
        assert!(m.lookup(Arch::Sebulba, "atari_like", 8).is_none());
        assert!(m.lookup(Arch::MuZero, "catch", 8).is_none());
    }

    #[test]
    fn version_gate_is_typed() {
        let text = String::from_utf8(sample_model().to_bytes()).unwrap();
        let bumped = text.replace("\"format_version\":1", "\"format_version\":99");
        match CostModel::from_bytes(bumped.as_bytes()) {
            Err(CostModelError::UnsupportedVersion { found: 99, expected: 1 }) => {}
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
    }

    #[test]
    fn truncation_is_typed_parse_error() {
        let bytes = sample_model().to_bytes();
        for cut in [0, 1, bytes.len() / 2, bytes.len() - 1] {
            match CostModel::from_bytes(&bytes[..cut]) {
                Err(CostModelError::Parse(_)) => {}
                other => panic!("truncation at {cut} should be Parse, got {other:?}"),
            }
        }
    }

    #[test]
    fn value_flip_is_crc_mismatch() {
        let text = String::from_utf8(sample_model().to_bytes()).unwrap();
        // Flip one digit inside a stored stage cost: still valid JSON, still
        // a valid schema — only the CRC can catch it.
        let flipped = text.replace("\"samples\":1", "\"samples\":7");
        match CostModel::from_bytes(flipped.as_bytes()) {
            Err(CostModelError::CrcMismatch { .. }) => {}
            other => panic!("expected CrcMismatch, got {other:?}"),
        }
    }

    #[test]
    fn unknown_key_is_corrupt() {
        let text = String::from_utf8(sample_model().to_bytes()).unwrap();
        let renamed = text.replace("\"env_step_s\"", "\"env_stop_s\"");
        match CostModel::from_bytes(renamed.as_bytes()) {
            Err(CostModelError::Corrupt(_)) => {}
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn save_load_file() {
        let dir = std::env::temp_dir().join(format!("podracer_cm_{}", std::process::id()));
        let path = dir.join("cost_model.json");
        let m = sample_model();
        m.save(&path).unwrap();
        assert_eq!(CostModel::load(&path).unwrap(), m);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
