//! `podracer plan` — the planner's CLI surface (DESIGN.md §17).
//!
//! Prints the ranked candidate table for an `(arch, agent, env, pod)`
//! request. `--calibrate` bootstraps the cost model with one short real
//! run on a conservative topology; `--measure` re-runs the top-ranked
//! candidates for real and reports where the predicted best actually
//! landed (`measured-rank=1/k` means the prediction was spot on —
//! `scripts/plan_smoke.sh` gates on top-2).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::experiment::{Arch, EnvKind, Experiment, Report, Topology};
use crate::runtime::Manifest;
use crate::util::cli::Args;

use super::{topology_label, CostModel, PlanRequest, Planner};

/// Every flag `podracer plan` accepts; anything else is a hard error.
pub const PLAN_FLAGS: &[&str] = &[
    "arch",
    "agent",
    "env",
    "pod-cores",
    "batch",
    "unroll",
    "micro-batches",
    "cost-model",
    "calibrate",
    "measure",
    "top",
    "report-json",
];

/// Learner updates (or Anakin outer iterations) per calibration run —
/// enough to average past first-touch jitter, short enough for CI.
const CALIBRATE_UPDATES: u64 = 3;
/// Updates per `--measure` run.
const MEASURE_UPDATES: u64 = 3;
/// How many top-ranked candidates `--measure` actually runs.
const MEASURE_CANDIDATES: usize = 3;

/// The `podracer plan` entrypoint.
pub fn run(args: &Args) -> Result<()> {
    args.check_known("plan", PLAN_FLAGS)?;
    let arch: Arch = args.get_str("arch", "sebulba").parse()?;
    let env: EnvKind = args.get_str("env", "catch").parse()?;
    let pod_cores = args.get_usize("pod-cores", 4)?;
    if arch == Arch::Anakin {
        for knob in ["batch", "unroll", "micro-batches"] {
            if args.has(knob) {
                bail!("--{knob} does not apply to the anakin architecture");
            }
        }
    }
    let mut req = PlanRequest::new(arch, pod_cores);
    req.env = env.as_str().to_string();
    req.agent = args.get_str("agent", &default_agent(arch, env));
    req.actor_batch = args.get_usize("batch", req.actor_batch)?;
    req.unroll = args.get_usize("unroll", req.unroll)?;
    req.micro_batches = args.get_usize("micro-batches", req.micro_batches)?;

    let model_path = args
        .flags
        .get("cost-model")
        .map(PathBuf::from)
        .unwrap_or_else(|| crate::artifacts_dir().join("cost_model.json"));
    let calibrate = args.get_bool("calibrate", false)?;
    let measure = args.get_bool("measure", false)?;
    let top = args.get_usize("top", 8)?;
    if top == 0 {
        bail!("--top expects a positive candidate count");
    }

    let mut model = if model_path.exists() {
        CostModel::load(&model_path)
            .with_context(|| format!("loading cost model {}", model_path.display()))?
    } else if calibrate {
        CostModel::new()
    } else {
        bail!(
            "no cost model at {} — bootstrap one with `podracer plan --calibrate` \
             or `make bench-smoke`",
            model_path.display()
        );
    };

    if calibrate {
        // A model-free planner still carries the full feasibility oracle
        // (manifest program gate + topology validation) — exactly what
        // picking a bootstrap topology needs.
        let probe = planner_with_manifest(CostModel::new());
        let topo = calibration_topology(&probe, &req)?;
        println!("calibrate: {} ({CALIBRATE_UPDATES} updates)", topology_label(&topo));
        let report = run_once(&req, env, &topo, CALIBRATE_UPDATES)?;
        model.fold(&report, &req.env, probe.cell_batch(&req), &topo);
        model.save(&model_path)
            .with_context(|| format!("writing cost model {}", model_path.display()))?;
        println!("calibrated: {} ({} cells)", model_path.display(), model.len());
    }

    let planner = planner_with_manifest(model);
    let mut plan = planner.plan(&req)?;
    plan.candidates.truncate(top);

    if measure {
        let k = plan.candidates.len().min(MEASURE_CANDIDATES);
        for i in 0..k {
            let topo = plan.candidates[i].topology.clone();
            let report = run_once(&req, env, &topo, MEASURE_UPDATES)
                .with_context(|| format!("measuring {}", topology_label(&topo)))?;
            plan.candidates[i].measured_fps = Some(report.throughput);
        }
        let best = plan.candidates[0].measured_fps.unwrap_or(0.0);
        let rank = 1
            + plan.candidates[..k]
                .iter()
                .filter(|c| c.measured_fps.unwrap_or(0.0) > best)
                .count();
        println!("measure: predicted-best measured-rank={rank}/{k}");
    }

    print!("{}", plan.table());
    println!("best: {}", topology_label(&plan.best().topology));

    if let Some(path) = args.flags.get("report-json") {
        if path.is_empty() || path == "true" {
            bail!("--report-json expects a file path");
        }
        std::fs::write(path, format!("{}\n", plan.to_json()))
            .with_context(|| format!("writing {path}"))?;
    }
    Ok(())
}

/// The shipped agent tag for `(arch, env)` — mirrors the training CLI's
/// defaults, extended across the env matrix.
fn default_agent(arch: Arch, env: EnvKind) -> String {
    match arch {
        // Anakin's env is baked into the agent program; only the shipped
        // fused agents are reachable by default.
        Arch::Anakin => match env {
            EnvKind::Gridworld => "anakin_grid".to_string(),
            _ => "anakin_catch".to_string(),
        },
        Arch::Sebulba => format!("seb_{}", short_env(env)),
        Arch::MuZero => format!("mz_{}", short_env(env)),
    }
}

/// The env's short tag inside agent names (`seb_atari`, `mz_grid`, ...).
fn short_env(env: EnvKind) -> &'static str {
    match env {
        EnvKind::Catch => "catch",
        EnvKind::Gridworld => "grid",
        EnvKind::Cartpole => "cartpole",
        EnvKind::Chain => "chain",
        EnvKind::AtariLike => "atari",
    }
}

fn planner_with_manifest(model: CostModel) -> Planner {
    let mut p = Planner::new(model);
    if let Ok(m) = Manifest::load(&crate::artifacts_dir()) {
        p = p.with_manifest(m);
    }
    p
}

/// First feasible bootstrap topology from a fixed preference list of
/// modest splits — deterministic, and checked with the same oracle the
/// enumeration uses.
fn calibration_topology(planner: &Planner, req: &PlanRequest) -> Result<Topology> {
    let prefs: Vec<Topology> = match req.arch {
        // widest replica slice first: more parallel samples per second
        Arch::Anakin => (1..=req.pod_cores.min(4)).rev().map(Topology::anakin).collect(),
        Arch::Sebulba => [(1, 2, 1, 2, 1), (1, 1, 1, 2, 1), (1, 2, 1, 1, 1), (1, 1, 1, 1, 1)]
            .iter()
            .map(|&(a, l, t, s, lp)| Topology {
                actor_cores: a,
                learner_cores: l,
                threads_per_actor_core: t,
                pipeline_stages: s,
                learner_pipeline: lp,
                ..Topology::default()
            })
            .collect(),
        Arch::MuZero => [(1usize, 1usize), (1, 2)]
            .iter()
            .map(|&(a, l)| Topology {
                actor_cores: a,
                learner_cores: l,
                threads_per_actor_core: 1,
                pipeline_stages: 1,
                learner_pipeline: 1,
                ..Topology::default()
            })
            .collect(),
    };
    prefs.into_iter().find(|t| planner.is_feasible(req, t)).ok_or_else(|| {
        anyhow::anyhow!(
            "no feasible calibration topology for {} agent {:?} within {} cores \
             (try --batch matching a compiled inference geometry)",
            req.arch,
            req.agent,
            req.pod_cores
        )
    })
}

/// One short real run of the request's workload on `topo`.
fn run_once(req: &PlanRequest, env: EnvKind, topo: &Topology, updates: u64) -> Result<Report> {
    let mut b = Experiment::new(req.arch)
        .agent(&req.agent)
        .topology(topo.clone())
        .updates(updates)
        .seed(17);
    match req.arch {
        Arch::Anakin => {}
        Arch::Sebulba => {
            b = b
                .env(env)
                .actor_batch(req.actor_batch)
                .unroll(req.unroll)
                .micro_batches(req.micro_batches);
        }
        Arch::MuZero => b = b.env(env),
    }
    b.build()?.run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_agents_cover_the_matrix() {
        assert_eq!(default_agent(Arch::Sebulba, EnvKind::AtariLike), "seb_atari");
        assert_eq!(default_agent(Arch::Sebulba, EnvKind::Catch), "seb_catch");
        assert_eq!(default_agent(Arch::MuZero, EnvKind::Gridworld), "mz_grid");
        assert_eq!(default_agent(Arch::Anakin, EnvKind::Gridworld), "anakin_grid");
        assert_eq!(default_agent(Arch::Anakin, EnvKind::Catch), "anakin_catch");
    }

    #[test]
    fn calibration_topology_is_feasible_by_the_planner_oracle() {
        for arch in Arch::ALL {
            let planner = Planner::new(CostModel::new());
            let req = PlanRequest::new(arch, 4);
            let topo = calibration_topology(&planner, &req).unwrap();
            assert!(planner.is_feasible(&req, &topo));
            topo.validate_for_pod(4).unwrap();
        }
    }

    #[test]
    fn unknown_flags_and_anakin_batch_hard_error() {
        let args = Args::parse(["--bogus".to_string(), "1".to_string()]);
        assert!(run(&args).unwrap_err().to_string().contains("--bogus"));
        let args =
            Args::parse(["--arch".to_string(), "anakin".to_string(), "--batch".to_string(), "8".to_string()]);
        let err = run(&args).unwrap_err().to_string();
        assert!(err.contains("--batch") && err.contains("anakin"), "{err}");
    }
}
