//! Cost-model-driven topology planning (DESIGN.md §17).
//!
//! The repo measures per-stage costs everywhere (`RunStats`,
//! `table_cost_model`, every [`Report`](crate::experiment::Report)) and has
//! a typed [`Topology`] with a feasibility oracle (`validate_for_pod`) —
//! this module closes the loop. A [`CostModel`] stores measured per-stage
//! seconds/item keyed by `(arch, env, batch)`; the [`Planner`] enumerates
//! every feasible topology for a pod, predicts each candidate's
//! steady-state throughput as the bottleneck stage's rate under the
//! pipeline-overlap model (DESIGN.md §§1, 9), and returns the ranked
//! candidates. Surfaced three ways:
//!
//! * [`Topology::auto`] — library entrypoint: the argmax topology.
//! * `--topology auto` on the training subcommands (`experiment::from_args`).
//! * `podracer plan` — the ranked candidate table, with `--calibrate` to
//!   bootstrap a model from short runs and `--measure` to check the
//!   prediction against real runs ([`cli`]).
//!
//! ## The prediction model
//!
//! All costs are *core*-seconds per frame (or wall seconds per update for
//! the collective/apply), so rates compose linearly in cores:
//!
//! * **actor rate** = `actor_cores / actor_infer_s` when env stepping is
//!   hidden behind the device (threads > 1 or pipeline_stages > 1 — the
//!   split-batch overlap of DESIGN.md §1), else
//!   `actor_cores / (actor_infer_s + env_step_s)`.
//! * **learner rate**: one update consumes `stage_batch × unroll /
//!   micro_batches` frames; its grad round walls
//!   `learner_grad_s × frames / learner_cores`, and the
//!   collective+apply overhead overlaps the next round's grads when
//!   `learner_pipeline > 1` (DESIGN.md §9) — so the update wall is
//!   `max(grad, overhead)` pipelined, `grad + overhead` serial.
//! * **predicted throughput** = `min(actor rate, learner rate)`; the argmin
//!   is reported as the bottleneck stage.
//!
//! Anakin has a single fused stage: `cores / (device_s + host_s)`.

pub mod cli;
mod cost_model;

pub use cost_model::{CostModel, CostModelError, StageCosts, COST_MODEL_VERSION};

use anyhow::{bail, Result};

use crate::coordinator::Sebulba;
use crate::experiment::{Arch, EnvKind, Topology};
use crate::runtime::Manifest;
use crate::search::MuZero;

/// What to plan for: the workload half of the question. The topology half
/// is the planner's output.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub arch: Arch,
    /// Agent tag in the artifact manifest.
    pub agent: String,
    /// Cost-model cell label (an [`EnvKind::as_str`] name).
    pub env: String,
    /// Core budget the topology must fit (`validate_for_pod`'s bound).
    pub pod_cores: usize,
    /// Actor batch (Sebulba; MuZero reads its batch from the manifest,
    /// Anakin's per-core loop is keyed as batch 1).
    pub actor_batch: usize,
    pub unroll: usize,
    pub micro_batches: usize,
}

impl PlanRequest {
    /// Per-arch default workload, mirroring the CLI defaults.
    pub fn new(arch: Arch, pod_cores: usize) -> Self {
        let (agent, batch, unroll) = match arch {
            Arch::Anakin => ("anakin_catch", 1, 1),
            Arch::Sebulba => ("seb_catch", 32, 20),
            Arch::MuZero => ("mz_catch", 8, 16),
        };
        Self {
            arch,
            agent: agent.to_string(),
            env: EnvKind::Catch.as_str().to_string(),
            pod_cores,
            actor_batch: batch,
            unroll,
            micro_batches: 1,
        }
    }
}

/// One enumerated topology with its predicted throughput.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub topology: Topology,
    /// Predicted steady-state frames/sec (the bottleneck stage's rate).
    pub predicted_fps: f64,
    /// Which stage bounds the prediction ("actor" | "learner" | "replica").
    pub bottleneck: &'static str,
    /// Filled by `podracer plan --measure` (short real runs).
    pub measured_fps: Option<f64>,
}

/// The ranked plan: `candidates[0]` is the argmax prediction.
#[derive(Clone, Debug)]
pub struct Plan {
    pub arch: Arch,
    pub env: String,
    pub pod_cores: usize,
    /// The cost-model batch cell the prediction used (nearest match).
    pub model_batch: usize,
    /// Feasible candidates, best predicted first; never empty.
    pub candidates: Vec<Candidate>,
}

impl Plan {
    pub fn best(&self) -> &Candidate {
        &self.candidates[0]
    }

    /// The ranked table `podracer plan` prints.
    pub fn table(&self) -> String {
        let mut out = format!(
            "plan: {} env={} pod_cores={} (cost cell: batch {})\n\
             {:>4}  {:<28} {:>14}  {:<10} {:>12}\n",
            self.arch, self.env, self.pod_cores, self.model_batch,
            "rank", "topology", "predicted fps", "bottleneck", "measured fps",
        );
        for (i, c) in self.candidates.iter().enumerate() {
            let measured = match c.measured_fps {
                Some(fps) => format!("{fps:.1}"),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "{:>4}  {:<28} {:>14.1}  {:<10} {:>12}\n",
                i + 1,
                topology_label(&c.topology),
                c.predicted_fps,
                c.bottleneck,
                measured,
            ));
        }
        out
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("arch", Json::str(self.arch.as_str())),
            ("env", Json::str(&self.env)),
            ("pod_cores", Json::num(self.pod_cores as f64)),
            ("model_batch", Json::num(self.model_batch as f64)),
            (
                "candidates",
                Json::Arr(
                    self.candidates
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("topology", Json::str(&topology_label(&c.topology))),
                                ("actor_cores", Json::num(c.topology.actor_cores as f64)),
                                ("learner_cores", Json::num(c.topology.learner_cores as f64)),
                                (
                                    "threads",
                                    Json::num(c.topology.threads_per_actor_core as f64),
                                ),
                                (
                                    "pipeline_stages",
                                    Json::num(c.topology.pipeline_stages as f64),
                                ),
                                (
                                    "learner_pipeline",
                                    Json::num(c.topology.learner_pipeline as f64),
                                ),
                                ("predicted_fps", Json::num(c.predicted_fps)),
                                ("bottleneck", Json::str(c.bottleneck)),
                                (
                                    "measured_fps",
                                    match c.measured_fps {
                                        Some(fps) => Json::num(fps),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Compact one-line topology description for tables and logs.
pub fn topology_label(t: &Topology) -> String {
    if t.actor_cores == 0 {
        format!("anakin({}c)", t.learner_cores)
    } else {
        format!(
            "{}a+{}l t{} s{} lp{}",
            t.actor_cores,
            t.learner_cores,
            t.threads_per_actor_core,
            t.pipeline_stages,
            t.learner_pipeline
        )
    }
}

/// Enumerates feasible topologies and ranks them by predicted throughput.
pub struct Planner {
    model: CostModel,
    manifest: Option<Manifest>,
}

impl Planner {
    pub fn new(model: CostModel) -> Self {
        Self { model, manifest: None }
    }

    /// Gate candidates on AOT program availability: a topology whose
    /// inference/grad geometry has no compiled program is infeasible even
    /// if the shape validates.
    pub fn with_manifest(mut self, manifest: Manifest) -> Self {
        self.manifest = Some(manifest);
        self
    }

    /// Enumerate feasible topologies for the request, predict each one's
    /// throughput from the cost model, and return them ranked (ties break
    /// deterministically: fewer cores, then topology fingerprint).
    pub fn plan(&self, req: &PlanRequest) -> Result<Plan> {
        if req.pod_cores == 0 {
            bail!("pod_cores must be >= 1");
        }
        let Some((model_batch, costs)) = self.model.lookup(
            req.arch,
            &req.env,
            self.lookup_batch(req),
        ) else {
            bail!(
                "no cost-model entry for arch={} env={} — bootstrap one with \
                 `make bench-smoke` or `podracer plan --calibrate`",
                req.arch,
                req.env
            );
        };
        let costs = *costs;
        let mut candidates: Vec<Candidate> = match req.arch {
            Arch::Anakin => self.anakin_candidates(req, &costs),
            Arch::Sebulba => self.sebulba_candidates(req, &costs),
            Arch::MuZero => self.muzero_candidates(req, &costs),
        };
        if candidates.is_empty() {
            bail!(
                "no feasible {} topology for agent {:?} within {} cores",
                req.arch,
                req.agent,
                req.pod_cores
            );
        }
        candidates.sort_by(|a, b| {
            b.predicted_fps
                .partial_cmp(&a.predicted_fps)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.topology.total_cores().cmp(&b.topology.total_cores()))
                .then_with(|| a.topology.fingerprint().cmp(&b.topology.fingerprint()))
        });
        Ok(Plan {
            arch: req.arch,
            env: req.env.clone(),
            pod_cores: req.pod_cores,
            model_batch,
            candidates,
        })
    }

    /// The batch the cost cell is keyed by (public so `podracer plan
    /// --calibrate` folds its measurement into the same cell `plan` reads).
    pub fn cell_batch(&self, req: &PlanRequest) -> usize {
        self.lookup_batch(req)
    }

    /// The same feasibility oracle the enumeration applies, for one
    /// concrete topology — `--calibrate` probes its bootstrap candidates
    /// with this before any cost cell exists.
    pub fn is_feasible(&self, req: &PlanRequest, topo: &Topology) -> bool {
        match req.arch {
            Arch::Anakin => {
                let agent_ok = match &self.manifest {
                    None => true,
                    Some(m) => m.agent(&req.agent).is_ok(),
                };
                agent_ok
                    && topo.actor_cores == 0
                    && topo.validate_for_pod(req.pod_cores).is_ok()
            }
            Arch::Sebulba => self.sebulba_feasible(&self.sebulba_runner(req), topo, req.pod_cores),
            Arch::MuZero => {
                let (batch, unroll) =
                    self.muzero_geometry(&req.agent).unwrap_or((req.actor_batch, req.unroll));
                let runner = MuZero { agent: req.agent.clone(), ..MuZero::default() };
                topo.validate_for_pod(req.pod_cores).is_ok()
                    && MuZero::check_topology(topo).is_ok()
                    && runner.resolved(topo).validate().is_ok()
                    && self.muzero_programs_exist(&req.agent, batch, unroll, topo.learner_cores)
            }
        }
    }

    /// The batch the cost cell is keyed by: MuZero's batch comes from the
    /// manifest when available, Anakin's per-core loop is keyed as 1.
    fn lookup_batch(&self, req: &PlanRequest) -> usize {
        match req.arch {
            Arch::Anakin => 1,
            Arch::Sebulba => req.actor_batch,
            Arch::MuZero => self
                .muzero_geometry(&req.agent)
                .map(|(batch, _)| batch)
                .unwrap_or(req.actor_batch),
        }
    }

    fn anakin_candidates(&self, req: &PlanRequest, costs: &StageCosts) -> Vec<Candidate> {
        if let Some(m) = &self.manifest {
            if m.agent(&req.agent).is_err() {
                return Vec::new();
            }
        }
        (1..=req.pod_cores)
            .filter_map(|cores| {
                let topo = Topology::anakin(cores);
                topo.validate_for_pod(req.pod_cores).ok()?;
                let per_step = costs.actor_infer_s + costs.env_step_s;
                Some(Candidate {
                    topology: topo,
                    predicted_fps: rate(cores as f64, per_step),
                    bottleneck: "replica",
                    measured_fps: None,
                })
            })
            .collect()
    }

    /// The request's workload half as a [`Sebulba`] runner, for geometry
    /// validation (env-agnostic — the env only matters at run time).
    fn sebulba_runner(&self, req: &PlanRequest) -> Sebulba {
        Sebulba {
            agent: req.agent.clone(),
            env_kind: EnvKind::Catch, // geometry validation only; env-agnostic
            actor_batch: req.actor_batch,
            unroll: req.unroll,
            micro_batches: req.micro_batches,
            ..Sebulba::default()
        }
    }

    fn sebulba_candidates(&self, req: &PlanRequest, costs: &StageCosts) -> Vec<Candidate> {
        let runner = self.sebulba_runner(req);
        let mut out = Vec::new();
        for actor_cores in 1..req.pod_cores {
            for learner_cores in 1..=(req.pod_cores - actor_cores) {
                for threads in [1usize, 2] {
                    for stages in [1usize, 2] {
                        for lpipe in [1usize, 2] {
                            let topo = Topology {
                                actor_cores,
                                learner_cores,
                                threads_per_actor_core: threads,
                                pipeline_stages: stages,
                                learner_pipeline: lpipe,
                                ..Topology::default()
                            };
                            if !self.sebulba_feasible(&runner, &topo, req.pod_cores) {
                                continue;
                            }
                            let (fps, bottleneck) = predict_actor_learner(
                                costs,
                                &topo,
                                req.actor_batch,
                                req.unroll,
                                req.micro_batches,
                            );
                            out.push(Candidate {
                                topology: topo,
                                predicted_fps: fps,
                                bottleneck,
                                measured_fps: None,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    fn sebulba_feasible(&self, runner: &Sebulba, topo: &Topology, pod_cores: usize) -> bool {
        if topo.validate_for_pod(pod_cores).is_err() {
            return false;
        }
        let cfg = runner.resolved(topo);
        if cfg.validate().is_err() {
            return false;
        }
        match &self.manifest {
            None => true,
            Some(m) => [
                cfg.infer_program(),
                cfg.grad_program(),
                cfg.apply_program(),
                cfg.init_program(),
            ]
            .iter()
            .all(|p| m.programs.contains_key(p)),
        }
    }

    /// MuZero's `(batch, unroll)` come from the agent's manifest entry.
    fn muzero_geometry(&self, agent: &str) -> Option<(usize, usize)> {
        let meta = self.manifest.as_ref()?.agent(agent).ok()?;
        Some((meta.extra_usize("batch").ok()?, meta.extra_usize("unroll").ok()?))
    }

    fn muzero_candidates(&self, req: &PlanRequest, costs: &StageCosts) -> Vec<Candidate> {
        let geometry = self.muzero_geometry(&req.agent);
        let (batch, unroll) = geometry.unwrap_or((req.actor_batch, req.unroll));
        let runner = MuZero { agent: req.agent.clone(), ..MuZero::default() };
        let mut out = Vec::new();
        for actor_cores in 1..req.pod_cores {
            for learner_cores in 1..=(req.pod_cores - actor_cores) {
                for lpipe in [1usize, 2] {
                    let topo = Topology {
                        actor_cores,
                        learner_cores,
                        threads_per_actor_core: 1,
                        pipeline_stages: 1,
                        learner_pipeline: lpipe,
                        ..Topology::default()
                    };
                    if topo.validate_for_pod(req.pod_cores).is_err()
                        || MuZero::check_topology(&topo).is_err()
                        || runner.resolved(&topo).validate().is_err()
                        || !self.muzero_programs_exist(&req.agent, batch, unroll, learner_cores)
                    {
                        continue;
                    }
                    let (fps, bottleneck) = predict_actor_learner(costs, &topo, batch, unroll, 1);
                    out.push(Candidate {
                        topology: topo,
                        predicted_fps: fps,
                        bottleneck,
                        measured_fps: None,
                    });
                }
            }
        }
        out
    }

    fn muzero_programs_exist(
        &self,
        agent: &str,
        batch: usize,
        unroll: usize,
        learner_cores: usize,
    ) -> bool {
        let Some(m) = &self.manifest else {
            return true;
        };
        if batch % learner_cores != 0 {
            return false;
        }
        let shard = batch / learner_cores;
        [
            format!("{agent}_represent_b{batch}"),
            format!("{agent}_dynpred_b{batch}"),
            format!("{agent}_predict_b{batch}"),
            format!("{agent}_grad_t{unroll}_b{shard}"),
            format!("{agent}_apply"),
            format!("{agent}_init"),
        ]
        .iter()
        .all(|p| m.programs.contains_key(p))
    }
}

/// `cores / per_item_cost`, infinite when the model has no cost for the
/// stage (a zero cell never vetoes a candidate, it just can't rank it).
fn rate(cores: f64, per_item: f64) -> f64 {
    if per_item > 0.0 {
        cores / per_item
    } else {
        f64::INFINITY
    }
}

/// The decomposed actor/learner prediction (module docs; DESIGN.md §17).
fn predict_actor_learner(
    costs: &StageCosts,
    topo: &Topology,
    batch: usize,
    unroll: usize,
    micro_batches: usize,
) -> (f64, &'static str) {
    let env_hidden = topo.threads_per_actor_core > 1 || topo.pipeline_stages > 1;
    let actor_cost = if env_hidden {
        costs.actor_infer_s
    } else {
        costs.actor_infer_s + costs.env_step_s
    };
    let actor_rate = rate(topo.actor_cores as f64, actor_cost);

    let stage_batch = batch / topo.pipeline_stages.max(1);
    let frames_per_update = (stage_batch * unroll) as f64 / micro_batches.max(1) as f64;
    let grad_wall = costs.learner_grad_s * frames_per_update / topo.learner_cores as f64;
    let overhead = costs.learner_collective_s + costs.learner_apply_s;
    let update_wall =
        if topo.learner_pipeline > 1 { grad_wall.max(overhead) } else { grad_wall + overhead };
    let learner_rate =
        if update_wall > 0.0 { frames_per_update / update_wall } else { f64::INFINITY };

    if actor_rate <= learner_rate {
        (actor_rate, "actor")
    } else {
        (learner_rate, "learner")
    }
}

impl Topology {
    /// Pick the best topology for `(arch, agent, env)` within `pod_cores`
    /// from measured costs: enumerate with [`Planner::plan`] under the
    /// default workload knobs and return the argmax. The artifact manifest
    /// (when loadable) gates candidates on compiled-program availability.
    pub fn auto(
        arch: Arch,
        agent: &str,
        env: EnvKind,
        pod_cores: usize,
        model: &CostModel,
    ) -> Result<Topology> {
        let mut req = PlanRequest::new(arch, pod_cores);
        req.agent = agent.to_string();
        req.env = env.as_str().to_string();
        Self::auto_for(&req, model)
    }

    /// [`Self::auto`] with full control over the workload knobs — what
    /// `--topology auto` uses so the planned split matches the batch,
    /// unroll and micro-batch geometry the run will actually execute.
    pub fn auto_for(req: &PlanRequest, model: &CostModel) -> Result<Topology> {
        let mut planner = Planner::new(model.clone());
        if let Ok(m) = Manifest::load(&crate::artifacts_dir()) {
            planner = planner.with_manifest(m);
        }
        Ok(planner.plan(req)?.best().topology.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_with(arch: Arch, env: &str, batch: usize, costs: StageCosts) -> CostModel {
        let mut m = CostModel::new();
        m.insert(arch, env, batch, costs);
        m
    }

    fn seb_costs() -> StageCosts {
        StageCosts {
            env_step_s: 2e-5,
            actor_infer_s: 4e-5,
            learner_grad_s: 1e-5,
            learner_collective_s: 2e-4,
            learner_apply_s: 1e-4,
            samples: 1,
        }
    }

    #[test]
    fn plan_is_deterministic() {
        let model = model_with(Arch::Sebulba, "catch", 32, seb_costs());
        let planner = Planner::new(model);
        let req = PlanRequest::new(Arch::Sebulba, 4);
        let a = planner.plan(&req).unwrap();
        let b = planner.plan(&req).unwrap();
        let shape = |p: &Plan| {
            p.candidates
                .iter()
                .map(|c| (c.topology.fingerprint(), c.predicted_fps.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(shape(&a), shape(&b));
        assert!(!a.candidates.is_empty());
    }

    #[test]
    fn every_candidate_validates_for_pod() {
        for (arch, env, batch) in
            [(Arch::Sebulba, "catch", 32), (Arch::Anakin, "catch", 1), (Arch::MuZero, "catch", 8)]
        {
            let model = model_with(arch, env, batch, seb_costs());
            let planner = Planner::new(model);
            for pod_cores in [2usize, 4, 6] {
                let req = PlanRequest::new(arch, pod_cores);
                let plan = planner.plan(&req).unwrap();
                for c in &plan.candidates {
                    c.topology.validate_for_pod(pod_cores).unwrap_or_else(|e| {
                        panic!("{arch} candidate {} infeasible: {e}", topology_label(&c.topology))
                    });
                }
            }
        }
    }

    #[test]
    fn hidden_env_stepping_beats_serial_actor() {
        // With env cost comparable to infer cost, the planner must prefer a
        // topology that hides env stepping (threads or stages > 1).
        let costs = StageCosts {
            env_step_s: 4e-5,
            actor_infer_s: 4e-5,
            learner_grad_s: 1e-6,
            ..seb_costs()
        };
        let model = model_with(Arch::Sebulba, "catch", 32, costs);
        let plan = Planner::new(model).plan(&PlanRequest::new(Arch::Sebulba, 4)).unwrap();
        let best = &plan.best().topology;
        assert!(
            best.threads_per_actor_core > 1 || best.pipeline_stages > 1,
            "expected env-hiding topology, got {}",
            topology_label(best)
        );
    }

    #[test]
    fn learner_bound_request_gets_learner_cores() {
        // Make grads overwhelmingly expensive: the best split must give the
        // learner more cores than the actor side.
        let costs = StageCosts {
            env_step_s: 1e-7,
            actor_infer_s: 1e-7,
            learner_grad_s: 1e-3,
            learner_collective_s: 0.0,
            learner_apply_s: 0.0,
            samples: 1,
        };
        let model = model_with(Arch::Sebulba, "catch", 32, costs);
        let plan = Planner::new(model).plan(&PlanRequest::new(Arch::Sebulba, 6)).unwrap();
        let best = &plan.best().topology;
        assert!(
            best.learner_cores > best.actor_cores,
            "expected learner-heavy split, got {}",
            topology_label(best)
        );
        assert_eq!(plan.best().bottleneck, "learner");
    }

    #[test]
    fn missing_cell_is_a_hard_error() {
        let model = model_with(Arch::Sebulba, "catch", 32, seb_costs());
        let req = PlanRequest {
            env: "atari_like".to_string(),
            ..PlanRequest::new(Arch::Sebulba, 4)
        };
        let err = Planner::new(model).plan(&req).unwrap_err().to_string();
        assert!(err.contains("no cost-model entry"), "{err}");
    }

    #[test]
    fn anakin_prediction_scales_with_cores() {
        let costs = StageCosts {
            env_step_s: 5e-5,
            actor_infer_s: 5e-5,
            ..Default::default()
        };
        let model = model_with(Arch::Anakin, "catch", 1, costs);
        let plan = Planner::new(model).plan(&PlanRequest::new(Arch::Anakin, 4)).unwrap();
        // All-core replica wins and the prediction is cores / per-step cost.
        assert_eq!(plan.best().topology.learner_cores, 4);
        let expected = 4.0 / 1e-4;
        assert!((plan.best().predicted_fps - expected).abs() < 1e-6 * expected);
    }

    #[test]
    fn auto_returns_the_argmax() {
        let model = model_with(Arch::Sebulba, "catch", 32, seb_costs());
        let topo = Topology::auto(Arch::Sebulba, "seb_catch", EnvKind::Catch, 4, &model).unwrap();
        let plan = Planner::new(model).plan(&PlanRequest::new(Arch::Sebulba, 4)).unwrap();
        // `auto` loads the manifest when present, which can only prune the
        // candidate list — with the default geometry both agree here.
        assert!(topo.total_cores() <= 4);
        assert!(plan.candidates.iter().any(|c| c.topology == topo));
    }
}
