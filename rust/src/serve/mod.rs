//! Policy serving: the Sebulba actor's inference machinery pointed at live
//! client sessions instead of a training env pool (DESIGN.md §14).
//!
//! The actor already solves the hard serving problem — batching many
//! concurrent decision streams onto one inference core with split-batch
//! latency hiding. This module reuses that loop verbatim through the
//! [`BatchSource`](crate::coordinator::actor::BatchSource) seam:
//!
//! - [`session`]: the in-process, socket-shaped transport — `ServeClient`
//!   dials [`SessionHandle`]s, `step(obs)` is a blocking RPC, admission is
//!   bounded by a session backlog.
//! - [`source`]: [`SessionSource`], the serving `BatchSource` — continuous
//!   batching (sessions admitted into the next sub-batch), per-request
//!   latency into `RunStats::request_latency`, zero-drop hot parameter
//!   swaps.
//! - [`run`]: the `podracer serve` driver — synthetic session fleet,
//!   optional hot-swapper thread, [`ServeReport`] with p50/p99/rps.

mod run;
mod session;
mod source;

pub use run::{run, run_on, spawn_serve_loop, Serve, ServeConfig, ServeReport};
pub use session::{
    session_channel, ConnectError, ServeClient, ServeError, SessionEndpoint, SessionHandle,
    StepReply,
};
pub use source::SessionSource;
