//! `SessionSource`: the serving [`BatchSource`] — live client sessions in,
//! per-request actions out, through the same infer loop the training actor
//! uses (DESIGN.md §14).
//!
//! Continuous batching: sub-batch membership is re-decided every tick. At
//! each `advance` the source (1) retires closed sessions, freeing their
//! slots, (2) admits backlog sessions into the freed slots — a new session
//! joins the *next* sub-batch, it never waits for a "round" to end — and
//! (3) arms one pending request per bound session, copying its observation
//! into the slot's region of the batch. Slots with no request this tick
//! stay zeroed; their inference outputs are discarded at dispatch. When no
//! slot has work the source blocks (condvar, bounded waits so `stop` is
//! observed) instead of spinning the device on empty batches.
//!
//! Hot swaps need nothing special here: the loop refreshes the device-side
//! parameter cache between launches (`latest_if_newer`), so a publish
//! never touches a request already in flight — replies are always sent,
//! stamped with the version that actually computed them.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::actor::{BatchSource, OverlapAcc, SourceStatus};
use crate::coordinator::stats::RunStats;
use crate::util::rng::Xoshiro256;

use super::session::{PendingRequest, SessionCell, SessionEndpoint, Shared, StepReply};

/// A request taken from its session and bound into the current sub-batch,
/// awaiting the inference result for its slot.
struct ArmedRequest {
    enqueued: std::time::Instant,
    reply: std::sync::mpsc::Sender<StepReply>,
}

/// One sub-batch of session slots (the serving analogue of the actor's
/// env-pool `Stage`).
struct ServeStage {
    /// Flat `[slots * obs_dim]`, zero-padded where no request is armed.
    /// `Arc`-shared for the same zero-copy upload as the actor path.
    obs: Arc<Vec<f32>>,
    /// Sessions bound to each slot (continuous: rebound as sessions come
    /// and go).
    slots: Vec<Option<Arc<SessionCell>>>,
    /// The in-flight request per slot, taken at assembly, replied at
    /// dispatch.
    armed: Vec<Option<ArmedRequest>>,
}

pub struct SessionSource {
    shared: Arc<Shared>,
    stats: Arc<RunStats>,
    stop: Arc<std::sync::atomic::AtomicBool>,
    obs_dim: usize,
    num_actions: usize,
    stages: Vec<ServeStage>,
    /// Lifetime counters (reported by serve::run).
    admitted: u64,
    served: u64,
}

impl SessionSource {
    pub fn new(
        endpoint: SessionEndpoint,
        stats: Arc<RunStats>,
        stop: Arc<std::sync::atomic::AtomicBool>,
        slots: usize,
        pipeline_stages: usize,
        obs_dim: usize,
        num_actions: usize,
    ) -> Result<Self> {
        anyhow::ensure!(slots >= 1, "serve batch must have at least one slot");
        anyhow::ensure!(pipeline_stages >= 1, "pipeline_stages must be >= 1");
        anyhow::ensure!(
            endpoint.shared.obs_dim == obs_dim,
            "session channel carries {}-float observations, agent expects {}",
            endpoint.shared.obs_dim,
            obs_dim
        );
        let stages = (0..pipeline_stages)
            .map(|_| ServeStage {
                obs: Arc::new(vec![0.0; slots * obs_dim]),
                slots: (0..slots).map(|_| None).collect(),
                armed: (0..slots).map(|_| None).collect(),
            })
            .collect();
        Ok(Self {
            shared: endpoint.shared,
            stats,
            stop,
            obs_dim,
            num_actions,
            stages,
            admitted: 0,
            served: 0,
        })
    }

    /// Sessions ever bound to a batch slot.
    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    /// Requests replied to.
    pub fn served(&self) -> u64 {
        self.served
    }

    /// Ready sub-batch `s` for its next inference: retire, admit, arm (the
    /// module doc's three phases). Blocks until at least one slot has a
    /// request, or reports `Shutdown` when stopped / fully drained.
    fn assemble(&mut self, s: usize) -> Result<SourceStatus> {
        let d = self.obs_dim;
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return Ok(SourceStatus::Shutdown);
            }
            let mut inner = self.shared.inner.lock().unwrap();
            let stage = &mut self.stages[s];

            // 1) retire closed sessions, freeing their slots
            for (i, slot) in stage.slots.iter_mut().enumerate() {
                if slot.as_ref().is_some_and(|c| c.closed.load(Ordering::Acquire)) {
                    *slot = None;
                    Arc::make_mut(&mut stage.obs)[i * d..(i + 1) * d].fill(0.0);
                }
            }

            // 2) continuous batching: admit waiting sessions into free
            //    slots — membership of the next sub-batch, not a cohort
            for slot in stage.slots.iter_mut() {
                if slot.is_none() {
                    while let Some(cell) = inner.backlog.pop_front() {
                        if cell.closed.load(Ordering::Acquire) {
                            continue; // gave up while queued
                        }
                        *slot = Some(cell);
                        self.admitted += 1;
                        break;
                    }
                }
            }

            // 3) arm one pending request per bound session
            let mut armed_any = false;
            for (i, slot) in stage.slots.iter().enumerate() {
                if stage.armed[i].is_some() {
                    continue; // already armed (cannot happen post-dispatch, defensive)
                }
                if let Some(cell) = slot {
                    if let Some(req) = cell.request.lock().unwrap().take() {
                        Arc::make_mut(&mut stage.obs)[i * d..(i + 1) * d]
                            .copy_from_slice(&req.obs);
                        stage.armed[i] =
                            Some(ArmedRequest { enqueued: req.enqueued, reply: req.reply });
                        armed_any = true;
                    }
                }
            }
            if armed_any {
                return Ok(SourceStatus::Continue);
            }

            // 4) drained? every client handle gone and no live session
            //    anywhere — nothing can ever arrive again
            if self.shared.clients.load(Ordering::Acquire) == 0 && inner.live == 0 {
                return Ok(SourceStatus::Shutdown);
            }

            // 5) block for work; bounded so `stop` is still observed
            let (guard, _) = self
                .shared
                .readable
                .wait_timeout(inner, Duration::from_millis(5))
                .unwrap();
            drop(guard);
        }
    }
}

impl BatchSource for SessionSource {
    fn stages(&self) -> usize {
        self.stages.len()
    }

    fn prime(&mut self) -> Result<SourceStatus> {
        self.assemble(0)
    }

    fn obs(&mut self, s: usize) -> Arc<Vec<f32>> {
        self.stages[s].obs.clone()
    }

    /// Reply to every armed request with its slot's action, stamped with
    /// the version that computed it. Channel sends — never blocks. A
    /// publish between launches can't drop anything here: requests armed
    /// under the old version still get their reply (with the old stamp).
    fn dispatch(
        &mut self,
        s: usize,
        actions: Vec<i32>,
        logits: Vec<f32>,
        param_version: u64,
        _acc: &mut OverlapAcc,
    ) -> Result<()> {
        let a = self.num_actions;
        let stage = &mut self.stages[s];
        for (i, armed) in stage.armed.iter_mut().enumerate() {
            if let Some(req) = armed.take() {
                self.stats.request_latency.record(req.enqueued.elapsed());
                let reply = StepReply {
                    action: actions[i],
                    logits: logits[i * a..(i + 1) * a].to_vec(),
                    param_version,
                };
                let _ = req.reply.send(reply); // client hung up: its loss, not an error
                self.served += 1;
            }
        }
        Ok(())
    }

    fn advance(
        &mut self,
        s: usize,
        _rng: &Xoshiro256,
        _acc: &mut OverlapAcc,
    ) -> Result<SourceStatus> {
        self.assemble(s)
    }
}

impl Drop for SessionSource {
    /// Fail pending work fast instead of stranding blocked clients: mark
    /// the server gone, then drop every unanswered request (slot-bound and
    /// backlogged) so their reply channels disconnect and `step` errors.
    fn drop(&mut self) {
        self.shared.server_gone.store(true, Ordering::Release);
        let drain = |cell: &Arc<SessionCell>| {
            let _: Option<PendingRequest> = cell.request.lock().unwrap().take();
        };
        for stage in &mut self.stages {
            for armed in stage.armed.iter_mut() {
                let _: Option<ArmedRequest> = armed.take();
            }
            for cell in stage.slots.iter().flatten() {
                drain(cell);
            }
        }
        let inner = self.shared.inner.lock().unwrap();
        for cell in inner.backlog.iter() {
            drain(cell);
        }
        self.shared.readable.notify_all();
    }
}
