//! In-process session transport for the serving frontend.
//!
//! Socket-shaped, no network: a [`ServeClient`] is the dialer end
//! (`connect` → [`SessionHandle`]), the opaque [`SessionEndpoint`] is the
//! listener end the `SessionSource` drains. A session's `step` is a
//! blocking RPC — post one observation, wait for its [`StepReply`] — so
//! each session has at most one request in flight and the server can
//! assemble sub-batches by taking at most one request per bound slot.
//!
//! Admission control lives here: `connect` refuses with [`ConnectError::Busy`]
//! once the not-yet-admitted backlog reaches `queue_capacity`. Admitted or
//! queued, a session counts as `live` until its handle drops, which is what
//! lets the server distinguish "momentarily idle" from "drained" (every
//! client handle gone and no live session) and exit cleanly.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

/// One action computed for one request.
#[derive(Clone, Debug)]
pub struct StepReply {
    pub action: i32,
    /// Behaviour logits for this slot (`num_actions` floats).
    pub logits: Vec<f32>,
    /// Version of the parameters that computed the action — hot swaps are
    /// observable per reply, and per-session versions are monotonic.
    pub param_version: u64,
}

/// Why `connect` was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ConnectError {
    /// Admission backlog is full — retry later. Sessions already bound to
    /// batch slots don't count against this; only the waiting queue does.
    Busy { capacity: usize },
    /// The serving loop is gone.
    Shutdown,
}

impl fmt::Display for ConnectError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConnectError::Busy { capacity } => {
                write!(f, "session backlog full ({capacity} waiting) — retry later")
            }
            ConnectError::Shutdown => write!(f, "serving loop shut down"),
        }
    }
}

impl std::error::Error for ConnectError {}

/// Why a session RPC ([`SessionHandle::step`]) failed — typed, so callers
/// can branch on the cause instead of substring-matching a message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The request's observation length doesn't match the server's.
    BadRequest { got: usize, want: usize },
    /// The serving loop was already gone when the request was posted.
    Shutdown,
    /// The serving loop went away with this request in flight.
    Disconnected,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::BadRequest { got, want } => {
                write!(f, "request carries {got} floats, server expects {want}")
            }
            ServeError::Shutdown => write!(f, "serving loop shut down"),
            ServeError::Disconnected => {
                write!(f, "serving loop shut down with the request in flight")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// A request the client has posted and is blocked on.
pub(crate) struct PendingRequest {
    pub obs: Vec<f32>,
    /// Posting time — request latency is measured from here to dispatch.
    pub enqueued: Instant,
    pub reply: mpsc::Sender<StepReply>,
}

/// Server-side view of one connected session.
pub(crate) struct SessionCell {
    pub id: u64,
    /// At most one in-flight request (`step` is a blocking RPC).
    pub request: Mutex<Option<PendingRequest>>,
    pub closed: AtomicBool,
}

pub(crate) struct Inner {
    /// Sessions accepted but not yet bound to a batch slot (FIFO).
    pub backlog: VecDeque<Arc<SessionCell>>,
    /// Sessions connected and not yet closed (backlog + slot-bound).
    pub live: usize,
}

pub(crate) struct Shared {
    pub inner: Mutex<Inner>,
    /// Signalled on any state the server may be waiting for: a request
    /// posted, a session connected or closed, a client handle dropped.
    /// Always notified while holding `inner`, so the server's wait on
    /// `inner` cannot miss a wakeup.
    pub readable: Condvar,
    /// Bound on `Inner::backlog` (admission control).
    pub queue_capacity: usize,
    /// Expected observation length per request.
    pub obs_dim: usize,
    /// Live `ServeClient` clones; 0 with `live == 0` means drained.
    pub clients: AtomicUsize,
    /// Set when the `SessionSource` is dropped — late connects/steps fail
    /// fast instead of queueing into the void.
    pub server_gone: AtomicBool,
    pub next_id: AtomicU64,
    /// Connects refused with `Busy` (admission-control accounting).
    pub rejected: AtomicU64,
}

impl Shared {
    /// Notify under the lock (see `readable` doc).
    pub fn notify(&self) {
        let _guard = self.inner.lock().unwrap();
        self.readable.notify_all();
    }
}

/// Build a connected client/server pair: the client side dials sessions,
/// the endpoint feeds a `SessionSource`. `queue_capacity` bounds how many
/// sessions may wait for a batch slot; `obs_dim` is the per-request
/// observation length every `step` must carry.
pub fn session_channel(queue_capacity: usize, obs_dim: usize) -> (ServeClient, SessionEndpoint) {
    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner { backlog: VecDeque::new(), live: 0 }),
        readable: Condvar::new(),
        queue_capacity,
        obs_dim,
        clients: AtomicUsize::new(1),
        server_gone: AtomicBool::new(false),
        next_id: AtomicU64::new(0),
        rejected: AtomicU64::new(0),
    });
    (ServeClient { shared: shared.clone() }, SessionEndpoint { shared })
}

/// The server end of [`session_channel`] — opaque; hand it to
/// `SessionSource::new`.
pub struct SessionEndpoint {
    pub(crate) shared: Arc<Shared>,
}

/// Dialer handle. Clone freely (one per client thread); when every clone is
/// gone and every session is closed, the serving loop drains and exits.
pub struct ServeClient {
    shared: Arc<Shared>,
}

impl Clone for ServeClient {
    fn clone(&self) -> Self {
        self.shared.clients.fetch_add(1, Ordering::AcqRel);
        Self { shared: self.shared.clone() }
    }
}

impl Drop for ServeClient {
    fn drop(&mut self) {
        self.shared.clients.fetch_sub(1, Ordering::AcqRel);
        self.shared.notify();
    }
}

impl ServeClient {
    /// Open a session. Fails fast with [`ConnectError::Busy`] when the
    /// admission backlog is full — callers decide whether to retry.
    pub fn connect(&self) -> Result<SessionHandle, ConnectError> {
        if self.shared.server_gone.load(Ordering::Acquire) {
            return Err(ConnectError::Shutdown);
        }
        let mut inner = self.shared.inner.lock().unwrap();
        if inner.backlog.len() >= self.shared.queue_capacity {
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ConnectError::Busy { capacity: self.shared.queue_capacity });
        }
        let cell = Arc::new(SessionCell {
            id: self.shared.next_id.fetch_add(1, Ordering::Relaxed),
            request: Mutex::new(None),
            closed: AtomicBool::new(false),
        });
        inner.backlog.push_back(cell.clone());
        inner.live += 1;
        self.shared.readable.notify_all();
        drop(inner);
        Ok(SessionHandle { shared: self.shared.clone(), cell })
    }

    /// Connects refused so far (admission control).
    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }
}

/// One open session. Dropping it closes the session; an unanswered request
/// at that point is simply never replied to (the reply receiver is ours).
pub struct SessionHandle {
    shared: Arc<Shared>,
    cell: Arc<SessionCell>,
}

impl SessionHandle {
    pub fn id(&self) -> u64 {
        self.cell.id
    }

    /// Post an observation and block for the action — one request in
    /// flight per session by construction. Failures are typed
    /// [`ServeError`]s, never stringly.
    pub fn step(&mut self, obs: &[f32]) -> Result<StepReply, ServeError> {
        if obs.len() != self.shared.obs_dim {
            return Err(ServeError::BadRequest { got: obs.len(), want: self.shared.obs_dim });
        }
        if self.shared.server_gone.load(Ordering::Acquire) {
            return Err(ServeError::Shutdown);
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut slot = self.cell.request.lock().unwrap();
            debug_assert!(slot.is_none(), "blocking RPC: no request can be in flight");
            *slot = Some(PendingRequest { obs: obs.to_vec(), enqueued: Instant::now(), reply: tx });
        }
        self.shared.notify();
        rx.recv().map_err(|_| ServeError::Disconnected)
    }
}

impl Drop for SessionHandle {
    fn drop(&mut self) {
        self.cell.closed.store(true, Ordering::Release);
        let mut inner = self.shared.inner.lock().unwrap();
        inner.live -= 1;
        self.shared.readable.notify_all();
    }
}
