//! `podracer serve`: drive the serving frontend end-to-end on one pod.
//!
//! One actor core runs the generic infer loop over a [`SessionSource`];
//! `sessions` synthetic client threads each dial in (retrying while the
//! admission backlog is full), run a host-side environment, and post one
//! observation per step through the session RPC; an optional swapper
//! thread hot-publishes a fresh parameter version every `swap_every`
//! served requests. The [`ServeReport`] carries the request percentiles
//! (from `RunStats::request_latency`) and the admission accounting.
//!
//! Teardown is drain-shaped, not deadline-shaped: the runner drops its
//! client handle once the drivers hold theirs, and when every driver is
//! done (all handles dropped, no live session) the source reports
//! `Shutdown` and the loop exits — no request is ever abandoned mid-swap
//! or mid-drain.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::actor::{run_infer_loop, InferLoopConfig, OverlapAcc};
use crate::coordinator::param_store::ParamStore;
use crate::coordinator::stats::RunStats;
use crate::envs::{make_env, EnvKind};
use crate::experiment::{Topology, ONE_POD};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{DeviceHandle, Pod};
use crate::util::rng::Xoshiro256;

use super::session::{session_channel, ConnectError, SessionEndpoint};
use super::source::SessionSource;

/// The serving *workload* — the half of [`ServeConfig`] that isn't core
/// topology, mirroring the `runner()`/`topology()` split the training
/// configs have (`SebulbaConfig`, `MuZeroRunConfig`):
/// `cfg.runner().resolved(&cfg.topology())` reproduces `cfg` exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct Serve {
    pub agent: String,
    pub env: EnvKind,
    /// Session slots per sub-batch — must match a lowered infer batch.
    pub batch: usize,
    pub sessions: usize,
    pub steps: usize,
    pub swap_every: u64,
    pub seed: u64,
}

impl Default for Serve {
    fn default() -> Self {
        ServeConfig::default().runner()
    }
}

impl Serve {
    /// Combine this workload with the core-split half into the resolved
    /// config — the serving counterpart of `Sebulba::resolved`. Serving
    /// reads only the topology fields it has a meaning for: one actor
    /// core's `pipeline_stages` sub-batches and the `queue_capacity`
    /// admission backlog.
    pub fn resolved(&self, topo: &Topology) -> ServeConfig {
        ServeConfig {
            agent: self.agent.clone(),
            env: self.env,
            batch: self.batch,
            pipeline_stages: topo.pipeline_stages,
            queue: topo.queue_capacity,
            sessions: self.sessions,
            steps: self.steps,
            swap_every: self.swap_every,
            seed: self.seed,
        }
    }
}

/// Knobs for one serving run (CLI: `podracer serve`, flags in
/// `experiment::serve_from_args`).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Agent whose `_infer_b{batch}` / `_init` programs serve the policy.
    pub agent: String,
    /// Environment the synthetic client sessions run host-side.
    pub env: EnvKind,
    /// Session slots per sub-batch — must match a lowered infer batch.
    pub batch: usize,
    /// Sub-batches round-robining through the infer loop (>= 1).
    pub pipeline_stages: usize,
    /// Admission backlog bound: sessions waiting for a slot beyond this
    /// are refused with `Busy`.
    pub queue: usize,
    /// Synthetic client sessions to drive.
    pub sessions: usize,
    /// Requests each session posts before closing.
    pub steps: usize,
    /// Hot-publish a new parameter version every N served requests
    /// (0 = never swap).
    pub swap_every: u64,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            agent: "seb_catch".into(),
            env: EnvKind::Catch,
            batch: 8,
            pipeline_stages: 1,
            queue: 8,
            sessions: 8,
            steps: 40,
            swap_every: 100,
            seed: 7,
        }
    }
}

impl ServeConfig {
    pub fn infer_program(&self) -> String {
        format!("{}_infer_b{}", self.agent, self.batch)
    }

    /// The workload half of this config; see [`Serve::resolved`].
    pub fn runner(&self) -> Serve {
        Serve {
            agent: self.agent.clone(),
            env: self.env,
            batch: self.batch,
            sessions: self.sessions,
            steps: self.steps,
            swap_every: self.swap_every,
            seed: self.seed,
        }
    }

    /// The core-split half, as the experiment API's typed [`Topology`].
    /// Serving runs one actor core and no learner; the depths serving has
    /// no use for collapse to 1. `runner().resolved(&topology())`
    /// reproduces `self` exactly.
    pub fn topology(&self) -> Topology {
        Topology {
            actor_cores: 1,
            learner_cores: 0,
            replicas: 1,
            threads_per_actor_core: 1,
            pipeline_stages: self.pipeline_stages,
            learner_pipeline: 1,
            env_workers: 1,
            queue_capacity: self.queue,
            pods: ONE_POD,
        }
    }

    /// Hard errors for values no run could mean (flag-level misuse is
    /// caught earlier by `serve_from_args`).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.batch >= 1, "--batch must be >= 1");
        anyhow::ensure!(self.pipeline_stages >= 1, "--pipeline-stages must be >= 1");
        anyhow::ensure!(self.queue >= 1, "--queue must be >= 1");
        anyhow::ensure!(self.sessions >= 1, "--sessions must be >= 1");
        anyhow::ensure!(self.steps >= 1, "--steps must be >= 1");
        Ok(())
    }
}

/// What a serving run measured.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Sessions requested / sessions that completed every step.
    pub sessions: u64,
    pub completed: u64,
    /// Sessions the source ever bound to a batch slot.
    pub admitted: u64,
    /// Requests replied to (zero-drop invariant: `sessions * steps` on a
    /// clean run).
    pub requests: u64,
    /// Connect attempts refused `Busy` (drivers retry, so these are
    /// retries, not lost sessions).
    pub rejected_retries: u64,
    pub elapsed_seconds: f64,
    pub rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// Parameter versions hot-published during the run.
    pub swaps: u64,
}

impl ServeReport {
    pub fn summary(&self, agent: &str) -> String {
        format!(
            "serve[{agent}] sessions={}/{} requests={} rps={:.0} p50_ms={:.2} p99_ms={:.2} mean_ms={:.2} swaps={} rejected_retries={}",
            self.completed,
            self.sessions,
            self.requests,
            self.rps,
            self.p50_ms,
            self.p99_ms,
            self.mean_ms,
            self.swaps,
            self.rejected_retries,
        )
    }

    /// Machine-readable form (`--report-json`) — stable field names.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("sessions", Json::num(self.sessions as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("admitted", Json::num(self.admitted as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("rejected_retries", Json::num(self.rejected_retries as f64)),
            ("elapsed_seconds", Json::num(self.elapsed_seconds)),
            ("rps", Json::num(self.rps)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("swaps", Json::num(self.swaps as f64)),
        ])
    }
}

/// Spawn the serving loop on `core`: builds the [`SessionSource`] over
/// `endpoint` and runs the generic infer loop until stopped or drained.
/// Returns `(sessions_admitted, requests_served)`. Public so tests can
/// wire their own store/clients around the loop (hot-swap oracle).
#[allow(clippy::too_many_arguments)]
pub fn spawn_serve_loop(
    core: DeviceHandle,
    infer_program: String,
    endpoint: SessionEndpoint,
    slots: usize,
    pipeline_stages: usize,
    obs_shape: Vec<usize>,
    num_actions: usize,
    store: Arc<ParamStore>,
    stats: Arc<RunStats>,
    stop: Arc<AtomicBool>,
    seed: u64,
) -> std::thread::JoinHandle<Result<(u64, u64)>> {
    std::thread::Builder::new()
        .name("serve-loop".into())
        .spawn(move || {
            let d: usize = obs_shape.iter().product();
            let mut source = SessionSource::new(
                endpoint,
                stats.clone(),
                stop.clone(),
                slots,
                pipeline_stages,
                d,
                num_actions,
            )?;
            let mut batch_shape = vec![slots];
            batch_shape.extend_from_slice(&obs_shape);
            let cfg = InferLoopConfig { actor_id: 0, infer_program, batch_shape };
            let mut rng = Xoshiro256::from_stream(seed, 0);
            let mut acc = OverlapAcc::default();
            run_infer_loop(&cfg, &core, &store, &stats, &stop, &mut rng, &mut source, &mut acc)?;
            Ok((source.admitted(), source.served()))
        })
        .expect("spawn serve loop thread")
}

/// Run a full serving session on a fresh single-core pod.
pub fn run(artifacts: &std::path::Path, cfg: &ServeConfig) -> Result<ServeReport> {
    let mut pod = Pod::new(artifacts, 1).context("building serve pod")?;
    run_on(&mut pod, cfg)
}

/// Run on an existing pod (benches reuse one pod across cases).
pub fn run_on(pod: &mut Pod, cfg: &ServeConfig) -> Result<ServeReport> {
    cfg.validate()?;
    let agent = pod.manifest.agent(&cfg.agent)?.clone();
    let d: usize = agent.obs_shape.iter().product();
    {
        // the synthetic drivers feed this env's observations to the agent
        let probe = make_env(cfg.env, cfg.seed);
        anyhow::ensure!(
            probe.obs_dim() == d,
            "env {:?} produces {}-float observations, agent {:?} expects {}",
            cfg.env,
            probe.obs_dim(),
            cfg.agent,
            d
        );
        anyhow::ensure!(
            probe.num_actions() == agent.num_actions,
            "env {:?} has {} actions, agent {:?} acts over {}",
            cfg.env,
            probe.num_actions(),
            cfg.agent,
            agent.num_actions
        );
    }
    let infer = cfg.infer_program();
    let init = format!("{}_init", cfg.agent);
    pod.load_program(&infer, &[0]).with_context(|| {
        format!("loading {infer:?} — is --batch a lowered infer batch for {:?}?", cfg.agent)
    })?;
    pod.load_program(&init, &[0])?;
    let core = pod.core(0)?;
    let outs = core.execute(&init, vec![HostTensor::scalar_i32(cfg.seed as i32)])?;
    let params = outs[0].clone().into_f32()?;

    let store = Arc::new(ParamStore::new(params));
    let stats = Arc::new(RunStats::new());
    let stop = Arc::new(AtomicBool::new(false));
    let (client, endpoint) = session_channel(cfg.queue, d);

    let start = Instant::now();
    let server = spawn_serve_loop(
        core,
        infer,
        endpoint,
        cfg.batch,
        cfg.pipeline_stages,
        agent.obs_shape.clone(),
        agent.num_actions,
        store.clone(),
        stats.clone(),
        stop.clone(),
        cfg.seed,
    );

    // Hot swapper: republish the current parameter buffer (new version,
    // same bytes — the swap machinery is exercised without perturbing the
    // policy) every `swap_every` served requests.
    let swap_stop = Arc::new(AtomicBool::new(false));
    let swapper = (cfg.swap_every > 0).then(|| {
        let store = store.clone();
        let stats = stats.clone();
        let swap_stop = swap_stop.clone();
        let every = cfg.swap_every;
        std::thread::Builder::new()
            .name("serve-swapper".into())
            .spawn(move || {
                let mut next = every;
                while !swap_stop.load(Ordering::Relaxed) {
                    if stats.request_latency.count() >= next {
                        store.publish_shared(store.latest().params.clone());
                        next += every;
                    } else {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
            })
            .expect("spawn swapper thread")
    });

    // Synthetic session drivers: connect (retrying while busy), run a
    // host-side env, one blocking request per step. Returns busy retries.
    let mut drivers = Vec::new();
    for sid in 0..cfg.sessions {
        let client = client.clone();
        let env_kind = cfg.env;
        let steps = cfg.steps;
        let seed = cfg.seed;
        drivers.push(
            std::thread::Builder::new()
                .name(format!("session-{sid}"))
                .spawn(move || -> Result<u64> {
                    let mut retries = 0u64;
                    let mut handle = loop {
                        match client.connect() {
                            Ok(h) => break h,
                            Err(ConnectError::Busy { .. }) => {
                                retries += 1;
                                std::thread::sleep(Duration::from_micros(200));
                            }
                            Err(ConnectError::Shutdown) => {
                                anyhow::bail!("server gone before session {sid} connected")
                            }
                        }
                    };
                    let mut env = make_env(env_kind, seed ^ (0x5e55_0000 + sid as u64));
                    let mut obs = vec![0.0f32; env.obs_dim()];
                    env.reset(&mut obs);
                    let mut last_version = 0u64;
                    for _ in 0..steps {
                        let reply = handle.step(&obs)?;
                        // hot swaps must be monotone per session
                        anyhow::ensure!(
                            reply.param_version >= last_version,
                            "param version went backwards ({} after {})",
                            reply.param_version,
                            last_version
                        );
                        last_version = reply.param_version;
                        let _ = env.step(reply.action as usize, &mut obs);
                    }
                    Ok(retries)
                })
                .expect("spawn session thread"),
        );
    }
    drop(client); // drivers hold the only client handles: joining them drains the server

    let mut completed = 0u64;
    let mut rejected_retries = 0u64;
    let mut driver_err: Option<anyhow::Error> = None;
    for driver in drivers {
        match driver.join().expect("session thread panicked") {
            Ok(retries) => {
                completed += 1;
                rejected_retries += retries;
            }
            Err(e) => driver_err = driver_err.or(Some(e)),
        }
    }
    swap_stop.store(true, Ordering::Relaxed);
    if let Some(h) = swapper {
        h.join().expect("swapper thread panicked");
    }
    let server_res = server.join().expect("serve loop panicked");
    stop.store(true, Ordering::Relaxed);
    if let Some(e) = driver_err {
        return Err(e.context("session driver failed"));
    }
    let (admitted, served) = server_res?;

    let elapsed = start.elapsed().as_secs_f64();
    Ok(ServeReport {
        sessions: cfg.sessions as u64,
        completed,
        admitted,
        requests: served,
        rejected_retries,
        elapsed_seconds: elapsed,
        rps: if elapsed > 0.0 { served as f64 / elapsed } else { 0.0 },
        p50_ms: stats.request_latency.percentile_seconds(50.0) * 1e3,
        p99_ms: stats.request_latency.percentile_seconds(99.0) * 1e3,
        mean_ms: stats.request_latency.mean_seconds() * 1e3,
        swaps: store.version(),
    })
}
