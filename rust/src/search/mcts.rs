//! Batched MuZero MCTS (Schrittwieser et al. 2020, no reanalyse).
//!
//! One tree per environment in the actor batch; simulations advance all
//! trees in lockstep so the three network programs run *batched* on the
//! actor core (one dynamics+prediction call per simulation for the whole
//! batch — the device never sees a batch-1 call).
//!
//! UCB follows the MuZero paper:
//! `score = Q_norm(child) + P(child) * sqrt(N(parent)) / (1 + N(child)) * c`
//! with `c = pb_c_init + log((N(parent) + pb_c_base + 1) / pb_c_base)`,
//! Q normalised by the min/max value seen in the tree, and Dirichlet noise
//! mixed into the root priors.

use crate::util::math::softmax;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct MctsConfig {
    pub num_actions: usize,
    pub latent_dim: usize,
    pub num_simulations: usize,
    pub discount: f32,
    pub pb_c_init: f32,
    pub pb_c_base: f32,
    pub root_dirichlet_alpha: f64,
    pub root_noise_frac: f32,
    /// Sample actions from visit counts with this temperature; 0 = argmax.
    pub temperature: f32,
}

impl Default for MctsConfig {
    fn default() -> Self {
        Self {
            num_actions: 3,
            latent_dim: 32,
            num_simulations: 16,
            discount: 0.997,
            pb_c_init: 1.25,
            pb_c_base: 19652.0,
            root_dirichlet_alpha: 0.3,
            root_noise_frac: 0.25,
            temperature: 1.0,
        }
    }
}

struct Node {
    prior: f32,
    visit_count: u32,
    value_sum: f32,
    reward: f32,
    latent: Vec<f32>, // empty until expanded
    /// children[a] = node index, usize::MAX if unexpanded.
    children: Vec<usize>,
}

impl Node {
    fn new(prior: f32, num_actions: usize) -> Self {
        Self {
            prior,
            visit_count: 0,
            value_sum: 0.0,
            reward: 0.0,
            latent: Vec::new(),
            children: vec![usize::MAX; num_actions],
        }
    }

    fn expanded(&self) -> bool {
        !self.latent.is_empty()
    }

    fn value(&self) -> f32 {
        if self.visit_count == 0 {
            0.0
        } else {
            self.value_sum / self.visit_count as f32
        }
    }
}

/// Running min/max of backed-up values (MuZero's Q normalisation).
#[derive(Clone, Copy)]
struct MinMax {
    min: f32,
    max: f32,
}

impl MinMax {
    fn new() -> Self {
        Self { min: f32::INFINITY, max: f32::NEG_INFINITY }
    }

    fn update(&mut self, v: f32) {
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    fn normalize(&self, v: f32) -> f32 {
        if self.max > self.min {
            (v - self.min) / (self.max - self.min)
        } else {
            v
        }
    }
}

/// One search tree (per environment slot).
struct Tree {
    nodes: Vec<Node>,
    minmax: MinMax,
    /// Path of (node, action) pairs of the in-flight simulation.
    path: Vec<(usize, usize)>,
    /// Leaf node awaiting network expansion this simulation.
    pending_leaf: usize,
    pending_parent_latent: Vec<f32>,
    pending_action: usize,
}

/// Result of a batched search: per environment, the chosen action and the
/// normalised visit distribution (the MuZero policy target).
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub actions: Vec<i32>,
    /// `[B * A]` visit-count distribution over actions.
    pub visit_policies: Vec<f32>,
    /// `[B]` root values after search.
    pub root_values: Vec<f32>,
}

/// Network evaluation callbacks the search needs. `podracer` wires these to
/// the `mz_*` XLA programs (see `muzero_actor`); tests stub them.
pub trait ModelEval {
    /// (latents [B*L], actions [B]) -> (next latents [B*L], rewards [B],
    /// priors logits [B*A], values [B])
    fn dynamics_predict(
        &mut self,
        latents: &[f32],
        actions: &[i32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>;
}

pub struct Mcts {
    pub cfg: MctsConfig,
}

impl Mcts {
    pub fn new(cfg: MctsConfig) -> Self {
        Self { cfg }
    }

    fn ucb_score(&self, parent: &Node, child: &Node, minmax: &MinMax, discount: f32) -> f32 {
        let pb_c = ((parent.visit_count as f32 + self.cfg.pb_c_base + 1.0)
            / self.cfg.pb_c_base)
            .ln()
            + self.cfg.pb_c_init;
        let prior_score =
            pb_c * child.prior * (parent.visit_count as f32).sqrt() / (1.0 + child.visit_count as f32);
        let value_score = if child.visit_count > 0 {
            minmax.normalize(child.reward + discount * child.value())
        } else {
            0.0
        };
        prior_score + value_score
    }

    /// Run a full batched search from root latents/priors/values.
    ///
    /// `root_latents: [B*L]`, `root_logits: [B*A]`, `root_values: [B]`.
    pub fn search<E: ModelEval>(
        &self,
        root_latents: &[f32],
        root_logits: &[f32],
        root_values: &[f32],
        eval: &mut E,
        rng: &mut Xoshiro256,
    ) -> anyhow::Result<SearchResult> {
        let a = self.cfg.num_actions;
        let l = self.cfg.latent_dim;
        let b = root_values.len();
        debug_assert_eq!(root_latents.len(), b * l);
        debug_assert_eq!(root_logits.len(), b * a);

        // Build roots with noisy priors.
        let mut trees: Vec<Tree> = (0..b)
            .map(|i| {
                let mut root = Node::new(1.0, a);
                root.latent = root_latents[i * l..(i + 1) * l].to_vec();
                let priors = softmax(&root_logits[i * a..(i + 1) * a]);
                let noise = rng.next_dirichlet(self.cfg.root_dirichlet_alpha, a);
                let frac = self.cfg.root_noise_frac;
                let mut nodes = vec![root];
                for (ai, p) in priors.iter().enumerate() {
                    let prior = p * (1.0 - frac) + noise[ai] as f32 * frac;
                    nodes.push(Node::new(prior, a));
                    nodes[0].children[ai] = ai + 1;
                }
                nodes[0].visit_count = 1;
                nodes[0].value_sum = root_values[i];
                let mut mm = MinMax::new();
                mm.update(root_values[i]);
                Tree {
                    nodes,
                    minmax: mm,
                    path: Vec::new(),
                    pending_leaf: 0,
                    pending_parent_latent: Vec::new(),
                    pending_action: 0,
                }
            })
            .collect();

        let mut latents_buf = vec![0.0f32; b * l];
        let mut actions_buf = vec![0i32; b];

        for _sim in 0..self.cfg.num_simulations {
            // 1) selection: walk every tree to an unexpanded child.
            for (i, tree) in trees.iter_mut().enumerate() {
                tree.path.clear();
                let mut node = 0usize;
                loop {
                    // pick the best child by UCB
                    let parent = &tree.nodes[node];
                    let mut best = 0usize;
                    let mut best_score = f32::NEG_INFINITY;
                    for ai in 0..a {
                        let ci = parent.children[ai];
                        let score = if ci == usize::MAX {
                            // fresh child of an expanded node: prior-only
                            self.ucb_score(parent, &Node::new(parent.prior, a), &tree.minmax, self.cfg.discount)
                        } else {
                            self.ucb_score(parent, &tree.nodes[ci], &tree.minmax, self.cfg.discount)
                        };
                        if score > best_score {
                            best_score = score;
                            best = ai;
                        }
                    }
                    let child = tree.nodes[node].children[best];
                    tree.path.push((node, best));
                    if child == usize::MAX || !tree.nodes[child].expanded() {
                        // leaf found (possibly an un-allocated child slot)
                        let leaf = if child == usize::MAX {
                            let idx = tree.nodes.len();
                            tree.nodes.push(Node::new(
                                1.0 / a as f32, // placeholder; real prior set on expansion of parent
                                a,
                            ));
                            tree.nodes[node].children[best] = idx;
                            idx
                        } else {
                            child
                        };
                        tree.pending_leaf = leaf;
                        tree.pending_action = best;
                        tree.pending_parent_latent = tree.nodes[node].latent.clone();
                        break;
                    }
                    node = child;
                }
                latents_buf[i * l..(i + 1) * l].copy_from_slice(&tree.pending_parent_latent);
                actions_buf[i] = tree.pending_action as i32;
            }

            // 2) batched expansion on the device.
            let (next_latents, rewards, logits, values) =
                eval.dynamics_predict(&latents_buf, &actions_buf)?;

            // 3) expand + backup each tree.
            for (i, tree) in trees.iter_mut().enumerate() {
                let leaf = tree.pending_leaf;
                tree.nodes[leaf].latent = next_latents[i * l..(i + 1) * l].to_vec();
                tree.nodes[leaf].reward = rewards[i];
                let priors = softmax(&logits[i * a..(i + 1) * a]);
                for (ai, p) in priors.iter().enumerate() {
                    if tree.nodes[leaf].children[ai] == usize::MAX {
                        let idx = tree.nodes.len();
                        tree.nodes.push(Node::new(*p, a));
                        tree.nodes[leaf].children[ai] = idx;
                    } else {
                        let ci = tree.nodes[leaf].children[ai];
                        tree.nodes[ci].prior = *p;
                    }
                }
                // backup along the path
                let mut value = values[i];
                tree.nodes[leaf].visit_count += 1;
                tree.nodes[leaf].value_sum += value;
                tree.minmax.update(tree.nodes[leaf].reward + self.cfg.discount * value);
                for &(node, action) in tree.path.iter().rev() {
                    let child = tree.nodes[node].children[action];
                    value = tree.nodes[child].reward + self.cfg.discount * value;
                    tree.nodes[node].visit_count += 1;
                    tree.nodes[node].value_sum += value;
                    tree.minmax.update(value);
                }
            }
        }

        // 4) visit-count policies + action selection.
        let mut actions = Vec::with_capacity(b);
        let mut policies = vec![0.0f32; b * a];
        let mut root_vals = Vec::with_capacity(b);
        for (i, tree) in trees.iter().enumerate() {
            let root = &tree.nodes[0];
            let counts: Vec<f64> = (0..a)
                .map(|ai| {
                    let ci = root.children[ai];
                    if ci == usize::MAX {
                        0.0
                    } else {
                        tree.nodes[ci].visit_count as f64
                    }
                })
                .collect();
            let total: f64 = counts.iter().sum::<f64>().max(1.0);
            for ai in 0..a {
                policies[i * a + ai] = (counts[ai] / total) as f32;
            }
            let action = if self.cfg.temperature <= 0.0 {
                counts
                    .iter()
                    .enumerate()
                    .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                    .map(|(ai, _)| ai)
                    .unwrap_or(0)
            } else {
                let weights: Vec<f64> = counts
                    .iter()
                    .map(|&c| c.powf(1.0 / self.cfg.temperature as f64))
                    .collect();
                rng.next_weighted(&weights)
            };
            actions.push(action as i32);
            root_vals.push(root.value());
        }
        Ok(SearchResult { actions, visit_policies: policies, root_values: root_vals })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Stub model: a 3-armed bandit where action `best` yields reward 1 in
    /// the dynamics step, everything else 0; priors are uniform.
    struct Bandit {
        best: usize,
        latent_dim: usize,
        num_actions: usize,
        calls: usize,
    }

    impl ModelEval for Bandit {
        fn dynamics_predict(
            &mut self,
            latents: &[f32],
            actions: &[i32],
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
            self.calls += 1;
            let b = actions.len();
            let l = self.latent_dim;
            let a = self.num_actions;
            let next = latents.to_vec();
            let rewards: Vec<f32> = actions
                .iter()
                .map(|&act| if act as usize == self.best { 1.0 } else { 0.0 })
                .collect();
            let logits = vec![0.0; b * a];
            let values = vec![0.0; b];
            Ok((next, rewards, logits, values))
        }
    }

    fn cfg(sims: usize) -> MctsConfig {
        MctsConfig {
            num_actions: 3,
            latent_dim: 2,
            num_simulations: sims,
            discount: 0.99,
            root_noise_frac: 0.0,
            temperature: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn finds_rewarding_arm() {
        let mcts = Mcts::new(cfg(30));
        let mut bandit = Bandit { best: 2, latent_dim: 2, num_actions: 3, calls: 0 };
        let mut rng = Xoshiro256::new(0);
        let b = 4;
        let out = mcts
            .search(&vec![0.0; b * 2], &vec![0.0; b * 3], &vec![0.0; b], &mut bandit, &mut rng)
            .unwrap();
        assert_eq!(out.actions, vec![2, 2, 2, 2]);
        // policies concentrate on arm 2
        for i in 0..b {
            assert!(out.visit_policies[i * 3 + 2] > 0.5, "{:?}", out.visit_policies);
        }
    }

    #[test]
    fn one_network_call_per_simulation() {
        let mcts = Mcts::new(cfg(12));
        let mut bandit = Bandit { best: 0, latent_dim: 2, num_actions: 3, calls: 0 };
        let mut rng = Xoshiro256::new(1);
        mcts.search(&vec![0.0; 2], &vec![0.0; 3], &[0.0], &mut bandit, &mut rng)
            .unwrap();
        assert_eq!(bandit.calls, 12, "search must batch: exactly one eval per simulation");
    }

    #[test]
    fn visit_counts_sum_to_simulations() {
        let mcts = Mcts::new(cfg(20));
        let mut bandit = Bandit { best: 1, latent_dim: 2, num_actions: 3, calls: 0 };
        let mut rng = Xoshiro256::new(2);
        let out = mcts
            .search(&vec![0.0; 2], &vec![0.0; 3], &[0.0], &mut bandit, &mut rng)
            .unwrap();
        let total: f32 = out.visit_policies.iter().sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn dirichlet_noise_adds_exploration() {
        // with full noise and temperature sampling, actions vary across envs
        let mut c = cfg(8);
        c.root_noise_frac = 1.0;
        c.temperature = 1.0;
        let mcts = Mcts::new(c);
        let mut bandit = Bandit { best: 0, latent_dim: 2, num_actions: 3, calls: 0 };
        let mut rng = Xoshiro256::new(3);
        let b = 16;
        let out = mcts
            .search(&vec![0.0; b * 2], &vec![0.0; b * 3], &vec![0.0; b], &mut bandit, &mut rng)
            .unwrap();
        let distinct: std::collections::BTreeSet<i32> = out.actions.iter().cloned().collect();
        assert!(distinct.len() > 1, "noise should diversify actions: {:?}", out.actions);
    }

    #[test]
    fn deeper_search_builds_deeper_trees() {
        // a quality check on selection: with many sims the tree must grow
        // beyond depth 1 (i.e. more nodes than root + A children + A^2).
        let mcts = Mcts::new(cfg(40));
        let mut bandit = Bandit { best: 1, latent_dim: 2, num_actions: 3, calls: 0 };
        let mut rng = Xoshiro256::new(4);
        let out = mcts
            .search(&vec![0.0; 2], &vec![0.0; 3], &[0.0], &mut bandit, &mut rng)
            .unwrap();
        // root value should reflect discounted reward of the best arm
        assert!(out.root_values[0] > 0.3, "root value {:?}", out.root_values);
    }
}
