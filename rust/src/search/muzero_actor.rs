//! The MuZero actor thread: MCTS-driven action selection on the actor core.
//!
//! Identical plumbing to the model-free actor (batched env, arena-backed
//! trajectory builder, zero-copy sharding, queue) but action selection runs
//! a full batched MCTS per step, with representation/dynamics/prediction as
//! device programs. The window's `behaviour_logits` column carries the MCTS
//! visit distributions — the policy targets of the MuZero loss.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::checkpoint::ActorSection;
use crate::coordinator::actor::{ActorCheckpoint, ShardBundle};
use crate::coordinator::param_store::ParamStore;
use crate::coordinator::queue::BoundedQueue;
use crate::coordinator::sharder::shard;
use crate::coordinator::stats::RunStats;
use crate::coordinator::trajectory::TrajectoryBuilder;
use crate::envs::{BatchedEnv, EnvFactory, WorkerPool};
use crate::runtime::tensor::HostTensor;
use crate::runtime::DeviceHandle;
use crate::util::rng::Xoshiro256;

use super::mcts::{Mcts, MctsConfig, ModelEval};

pub struct MuZeroActorConfig {
    pub actor_id: usize,
    pub batch: usize,
    pub unroll: usize,
    pub discount: f32,
    pub num_shards: usize,
    pub obs_shape: Vec<usize>,
    pub mcts: MctsConfig,
    /// Program names (from the manifest agent tag).
    pub represent: String,
    /// Fused dynamics+prediction program (one call per simulation).
    pub dynpred: String,
    pub predict: String,
    pub seed: u64,
    /// Checkpoint/restore duties — lockstep gate, deposit slot, resume
    /// state (DESIGN.md §13). Same protocol as `coordinator::actor`.
    pub checkpoint: Option<ActorCheckpoint>,
}

/// Device-backed ModelEval: the fused dynamics+prediction program — one
/// device call per MCTS simulation for the whole batch (perf: §Perf L2-1).
struct DeviceModel<'a> {
    core: &'a DeviceHandle,
    param_slot: &'a str,
    dynpred: &'a str,
    latent_dim: usize,
    batch: usize,
}

impl ModelEval for DeviceModel<'_> {
    fn dynamics_predict(
        &mut self,
        latents: &[f32],
        actions: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let lat = HostTensor::f32(vec![self.batch, self.latent_dim], latents.to_vec())?;
        let act = HostTensor::i32(vec![self.batch], actions.to_vec())?;
        let mut outs = self
            .core
            .execute_cached(
                self.dynpred,
                vec![lat, act],
                vec![(0, self.param_slot.to_string())],
            )
            .context("dynamics_predict")?;
        // outputs: latent', reward, logits, value — take ownership, no copies
        let values = outs.pop().unwrap().into_f32()?;
        let logits = outs.pop().unwrap().into_f32()?;
        let rewards = outs.pop().unwrap().into_f32()?;
        let next_latents = outs.pop().unwrap().into_f32()?;
        Ok((next_latents, rewards, logits, values))
    }
}

#[allow(clippy::too_many_arguments)]
pub fn spawn_muzero_actor(
    cfg: MuZeroActorConfig,
    core: DeviceHandle,
    factory: Arc<EnvFactory>,
    pool: Arc<WorkerPool>,
    store: Arc<ParamStore>,
    queue: Arc<BoundedQueue<ShardBundle>>,
    stats: Arc<RunStats>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<Result<()>> {
    std::thread::Builder::new()
        .name(format!("mz-actor-{}", cfg.actor_id))
        .spawn(move || muzero_actor_main(cfg, core, factory, pool, store, queue, stats, stop))
        .expect("spawn muzero actor")
}

#[allow(clippy::too_many_arguments)]
fn muzero_actor_main(
    cfg: MuZeroActorConfig,
    core: DeviceHandle,
    factory: Arc<EnvFactory>,
    pool: Arc<WorkerPool>,
    store: Arc<ParamStore>,
    queue: Arc<BoundedQueue<ShardBundle>>,
    stats: Arc<RunStats>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let b = cfg.batch;
    let d: usize = cfg.obs_shape.iter().product();
    let a = cfg.mcts.num_actions;
    let l = cfg.mcts.latent_dim;
    let mcts = Mcts::new(cfg.mcts.clone());
    let mut rng = Xoshiro256::from_stream(cfg.seed, 0x3D5 + cfg.actor_id as u64);

    anyhow::ensure!(
        cfg.num_shards >= 1 && b % cfg.num_shards == 0,
        "muzero batch {b} must divide into {} shards",
        cfg.num_shards
    );
    let env = BatchedEnv::new(&factory, b, pool)?;
    let mut obs = vec![0.0f32; b * d];
    env.reset(&mut obs).context("resetting muzero envs")?;

    let mut builder = TrajectoryBuilder::new(cfg.unroll, b, &cfg.obs_shape, a, cfg.num_shards);
    let mut rewards = vec![0.0f32; b];
    let mut dones = vec![false; b];
    let mut discounts = vec![0.0f32; b];
    let mut episode_reward = vec![0.0f64; b];

    // device-resident parameter cache (§Perf L3-1), slot per actor thread
    let param_slot = format!("mz-params#{}", cfg.actor_id);
    let mut cached_version = u64::MAX;

    // ---- checkpoint/restore (DESIGN.md §13) ------------------------------
    // Same deposit-before-push protocol as the model-free actor.
    let mut windows_done: u64 = 0;
    if let Some(res) = cfg.checkpoint.as_ref().and_then(|ck| ck.resume.as_ref()) {
        anyhow::ensure!(
            res.obs.len() == b * d,
            "restored obs has {} floats, this run needs {}",
            res.obs.len(),
            b * d
        );
        anyhow::ensure!(
            res.episode_reward.len() == b,
            "restored episode rewards cover {} envs, this run has {b}",
            res.episode_reward.len()
        );
        env.load_states(&res.env_states).context("restoring muzero env states")?;
        obs.copy_from_slice(&res.obs);
        for (er, &v) in episode_reward.iter_mut().zip(&res.episode_reward) {
            *er = v as f64;
        }
        rng = Xoshiro256::from_state(res.rng);
        windows_done = res.windows_done;
    }

    while !stop.load(Ordering::Relaxed) {
        // Lockstep gate: under checkpointing, window W starts only once the
        // learner has published update W — it equates window and update
        // counts, which the checkpoint format relies on.
        if cfg.checkpoint.is_some() {
            while store.version() < windows_done {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                std::thread::yield_now();
            }
        }
        for _t in 0..cfg.unroll {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            let snap = store.latest();
            if snap.version != cached_version {
                // Zero-copy upload: the cache command references the
                // snapshot's Arc'd buffer (DESIGN.md §11).
                core.cache(
                    &param_slot,
                    HostTensor::f32_shared(vec![snap.params.len()], snap.params.clone(), 0)?,
                )?;
                cached_version = snap.version;
            }

            // root inference: represent + predict (cached params)
            let t0 = Instant::now();
            let obs_t = HostTensor::f32(vec![b, d], obs.clone())?;
            let mut outs = core.execute_cached(
                &cfg.represent,
                vec![obs_t],
                vec![(0, param_slot.clone())],
            )?;
            let root_latents = outs.swap_remove(0).into_f32()?;
            let lat_t = HostTensor::f32(vec![b, l], root_latents.clone())?;
            let mut outs = core.execute_cached(
                &cfg.predict,
                vec![lat_t],
                vec![(0, param_slot.clone())],
            )?;
            let root_values = outs.swap_remove(1).into_f32()?;
            let root_logits = outs.swap_remove(0).into_f32()?;

            // batched tree search (device calls inside)
            let mut model = DeviceModel {
                core: &core,
                param_slot: &param_slot,
                dynpred: &cfg.dynpred,
                latent_dim: l,
                batch: b,
            };
            let result =
                mcts.search(&root_latents, &root_logits, &root_values, &mut model, &mut rng)?;
            stats.inference_latency.record(t0.elapsed());

            // env step
            let t1 = Instant::now();
            let prev_obs = obs.clone();
            env.step(&result.actions, &mut obs, &mut rewards, &mut dones)
                .context("stepping muzero environments")?;
            stats.env_step_latency.record(t1.elapsed());

            let mut ended = 0u64;
            let mut ended_reward = 0.0f64;
            for i in 0..b {
                episode_reward[i] += rewards[i] as f64;
                if dones[i] {
                    ended += 1;
                    ended_reward += episode_reward[i];
                    episode_reward[i] = 0.0;
                    discounts[i] = 0.0;
                } else {
                    discounts[i] = cfg.discount;
                }
            }
            stats.record_episodes(ended, ended_reward);
            builder.push_step(
                &prev_obs,
                &result.actions,
                &result.visit_policies, // policy targets ride the logits slot
                &rewards,
                &discounts,
            )?;
        }

        let version = store.version();
        let arena = builder.finish(&obs, version, cfg.actor_id)?;
        stats.env_frames.add(arena.frames() as u64);
        stats.trajectories.fetch_add(1, Ordering::Relaxed);
        windows_done += 1;
        // Deposit-before-push: the snapshot must exist before the learner
        // can possibly retire the update this window feeds (DESIGN.md §13).
        if let Some(ck) = &cfg.checkpoint {
            if windows_done % ck.every == 0 {
                let snap = ActorSection {
                    windows_done,
                    rng: rng.state(),
                    obs: obs.clone(),
                    episode_reward: episode_reward.iter().map(|&r| r as f32).collect(),
                    env_states: env.save_states(),
                };
                ck.slot.lock().unwrap().insert(windows_done, snap);
            }
        }
        // Zero-copy handoff: the bundle carries Arc views of the arena.
        if queue.push(shard(&arena)).is_err() {
            return Ok(());
        }
    }
    Ok(())
}
