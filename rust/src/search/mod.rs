//! Search: MCTS for the MuZero-style Sebulba agent.
//!
//! The paper: "we could reproduce results from MuZero (no Reanalyse) ...
//! using Sebulba and a pure JAX implementation of MCTS". Here the search
//! tree lives in Rust (the coordinator side), and the three network heads
//! (representation / dynamics / prediction) are XLA programs executed on the
//! actor core — so action selection stays batched on the device while tree
//! bookkeeping stays on the host, preserving the workload shape that makes
//! MuZero's actor cores the bottleneck (Fig 4c).

pub mod mcts;
pub mod muzero_actor;
pub mod muzero_run;

pub use mcts::{Mcts, MctsConfig, SearchResult};
pub use muzero_run::{MuZero, MuZeroRunConfig};
