//! Top-level MuZero-Sebulba run: like `Sebulba::run`, with MCTS actors.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::checkpoint::{
    expect_field, ActorSection, Checkpoint, MetaSection, StoreSection, ACTOR_SECTION,
    META_SECTION, STORE_SECTION,
};
use crate::coordinator::actor::{ActorCheckpoint, ShardBundle, SnapshotSlot};
use crate::coordinator::collective::GradientBus;
use crate::coordinator::learner::{LearnerCheckpoint, LearnerConfig, LearnerHandles};
use crate::coordinator::param_store::ParamStore;
use crate::coordinator::queue::BoundedQueue;
use crate::coordinator::sebulba::{join_pod_threads, spawn_guarded_learner};
use crate::coordinator::stats::RunStats;
use crate::envs::{make_factory, WorkerPool};
use crate::experiment::{
    ActorLearnerDetail, Arch, Detail, EnvKind, Report, RunSpec, Runner, Topology, ONE_POD,
};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{DeviceHandle, Pod};

use super::mcts::MctsConfig;
use super::muzero_actor::{spawn_muzero_actor, MuZeroActorConfig};

/// The MuZero *workload* (see `coordinator::Sebulba` for the pattern):
/// the core split arrives as a [`Topology`] through [`Runner`]. Reached
/// through `experiment::Experiment::new(Arch::MuZero)`.
#[derive(Clone, Debug, PartialEq)]
pub struct MuZero {
    /// Manifest agent tag ("mz_catch"); batch/unroll/latent geometry is
    /// read from the agent's manifest entry.
    pub agent: String,
    pub env_kind: EnvKind,
    /// MCTS simulations per step.
    pub num_simulations: usize,
    pub discount: f32,
    pub total_updates: u64,
    pub seed: u64,
}

impl Default for MuZero {
    fn default() -> Self {
        let cfg = MuZeroRunConfig::default();
        Self {
            agent: cfg.agent,
            env_kind: cfg.env_kind,
            num_simulations: cfg.num_simulations,
            discount: cfg.discount,
            total_updates: cfg.total_updates,
            seed: cfg.seed,
        }
    }
}

impl Runner for MuZero {
    fn arch(&self) -> Arch {
        Arch::MuZero
    }

    fn run_checkpointed(&self, pod: &mut Pod, topo: &Topology, spec: &RunSpec) -> Result<Report> {
        MuZero::check_topology(topo)?;
        run_resolved(pod, &self.resolved(topo), spec)
    }
}

impl MuZero {
    /// `resolved` carries no pipeline_stages (MuZero has no split-batch
    /// actor pipeline), so a non-1 value must be a hard error, never a
    /// silently dropped knob — the coercion class the experiment API
    /// retires. Shared by the builder and direct `Runner` users.
    pub fn check_topology(topo: &Topology) -> Result<()> {
        anyhow::ensure!(
            topo.pipeline_stages == 1,
            "muzero has no split-batch actor pipeline: topology.pipeline_stages must be 1 \
             (got {})",
            topo.pipeline_stages
        );
        Ok(())
    }

    /// Merge this workload with a core split into the resolved run config.
    pub fn resolved(&self, topo: &Topology) -> MuZeroRunConfig {
        MuZeroRunConfig {
            agent: self.agent.clone(),
            env_kind: self.env_kind,
            actor_cores: topo.actor_cores,
            learner_cores: topo.learner_cores,
            threads_per_actor_core: topo.threads_per_actor_core,
            num_simulations: self.num_simulations,
            learner_pipeline: topo.learner_pipeline,
            discount: self.discount,
            queue_capacity: topo.queue_capacity,
            env_workers: topo.env_workers,
            replicas: topo.replicas,
            total_updates: self.total_updates,
            seed: self.seed,
        }
    }
}

/// The resolved MuZero run configuration (internal form — see
/// `coordinator::SebulbaConfig` for the pattern).
#[derive(Clone, Debug, PartialEq)]
pub struct MuZeroRunConfig {
    /// Manifest agent tag ("mz_catch").
    pub agent: String,
    pub env_kind: EnvKind,
    pub actor_cores: usize,
    pub learner_cores: usize,
    pub threads_per_actor_core: usize,
    pub num_simulations: usize,
    /// Grad/apply rounds the learner keeps in flight (see
    /// `SebulbaConfig::learner_pipeline`). Defaults to 1: MuZero actors are
    /// search-bound, so the serial learner is rarely the bottleneck and the
    /// near-on-policy targets are kept maximally fresh.
    pub learner_pipeline: usize,
    pub discount: f32,
    pub queue_capacity: usize,
    pub env_workers: usize,
    pub replicas: usize,
    pub total_updates: u64,
    pub seed: u64,
}

impl Default for MuZeroRunConfig {
    fn default() -> Self {
        Self {
            agent: "mz_catch".into(),
            env_kind: EnvKind::Catch,
            actor_cores: 2,
            learner_cores: 2,
            threads_per_actor_core: 1,
            num_simulations: 16,
            learner_pipeline: 1,
            discount: 0.997,
            queue_capacity: 4,
            env_workers: 2,
            replicas: 1,
            total_updates: 20,
            seed: 11,
        }
    }
}

impl MuZeroRunConfig {
    pub fn cores_per_replica(&self) -> usize {
        self.actor_cores + self.learner_cores
    }

    pub fn total_cores(&self) -> usize {
        self.cores_per_replica() * self.replicas
    }

    /// The core-split half, as the experiment API's typed [`Topology`].
    /// MuZero has no split-batch actor pipeline, so `pipeline_stages` is 1.
    pub fn topology(&self) -> Topology {
        Topology {
            actor_cores: self.actor_cores,
            learner_cores: self.learner_cores,
            replicas: self.replicas,
            threads_per_actor_core: self.threads_per_actor_core,
            pipeline_stages: 1,
            learner_pipeline: self.learner_pipeline,
            env_workers: self.env_workers,
            queue_capacity: self.queue_capacity,
            pods: ONE_POD,
        }
    }

    /// The workload half, as the [`MuZero`] runner.
    /// `runner().resolved(&topology())` reproduces `self` exactly.
    pub fn runner(&self) -> MuZero {
        MuZero {
            agent: self.agent.clone(),
            env_kind: self.env_kind,
            num_simulations: self.num_simulations,
            discount: self.discount,
            total_updates: self.total_updates,
            seed: self.seed,
        }
    }

    /// Structural validity; the manifest-dependent geometry (batch %
    /// learner_cores) is checked at run time, when the agent is loaded.
    pub fn validate(&self) -> Result<()> {
        self.topology().validate()?;
        self.topology().require_split()?;
        if self.num_simulations == 0 {
            anyhow::bail!("num_simulations must be >= 1");
        }
        Ok(())
    }
}

pub(crate) fn run_resolved(pod: &mut Pod, cfg: &MuZeroRunConfig, spec: &RunSpec) -> Result<Report> {
    cfg.validate()?;

    // Lockstep pacing requirements for elasticity (DESIGN.md §13; same
    // invariant as the Sebulba coordinator — MuZero has no split-batch
    // pipeline or micro-batching, so only these three can break it).
    if !spec.is_plain() {
        anyhow::ensure!(
            cfg.actor_cores * cfg.threads_per_actor_core == 1,
            "checkpoint/restore/fault runs need exactly 1 actor thread (got {} cores x {} threads)",
            cfg.actor_cores,
            cfg.threads_per_actor_core
        );
        anyhow::ensure!(
            cfg.learner_pipeline == 1,
            "checkpoint/restore/fault runs need learner_pipeline == 1"
        );
        anyhow::ensure!(cfg.replicas == 1, "checkpoint/restore/fault runs need replicas == 1");
    }

    // ---- restore (DESIGN.md §13; mirrors the Sebulba coordinator) --------
    let restored = match &spec.restore_from {
        Some(path) => {
            let ckpt = Checkpoint::load_for(path, Arch::MuZero, &cfg.topology())
                .with_context(|| format!("restoring from {}", path.display()))?;
            let meta = MetaSection::decode(ckpt.section(META_SECTION)?)?;
            expect_field("agent", meta.agent.clone(), cfg.agent.clone())?;
            expect_field("seed", meta.seed, cfg.seed)?;
            expect_field("env", meta.env.clone(), cfg.env_kind.as_str().to_string())?;
            let store = StoreSection::decode(ckpt.section(STORE_SECTION)?)?;
            let actor = ActorSection::decode(ckpt.section(ACTOR_SECTION)?)?;
            expect_field("store version", store.version, meta.rounds_done)?;
            expect_field("actor windows", actor.windows_done, meta.rounds_done)?;
            Some((meta, store, actor))
        }
        None => None,
    };

    let agent = pod.manifest.agent(&cfg.agent)?.clone();
    let batch = agent.extra_usize("batch")?;
    let unroll = agent.extra_usize("unroll")?;
    let latent = agent.extra_usize("latent")?;
    let num_actions = agent.num_actions;
    let obs_shape = agent.obs_shape.clone();
    let shard_b = batch / cfg.learner_cores;

    let represent = format!("{}_represent_b{batch}", cfg.agent);
    let dynpred = format!("{}_dynpred_b{batch}", cfg.agent);
    let predict = format!("{}_predict_b{batch}", cfg.agent);
    let grad = format!("{}_grad_t{unroll}_b{shard_b}", cfg.agent);
    let apply = format!("{}_apply", cfg.agent);
    let init = format!("{}_init", cfg.agent);

    let n_per = cfg.cores_per_replica();
    cfg.topology().validate_for_pod(pod.n_cores())?;
    anyhow::ensure!(batch % cfg.learner_cores == 0, "batch must divide learner cores");

    let mut actor_core_ids = Vec::new();
    let mut learner_core_ids = Vec::new();
    let mut learner0_ids = Vec::new();
    for r in 0..cfg.replicas {
        let base = r * n_per;
        actor_core_ids.extend(base..base + cfg.actor_cores);
        learner_core_ids
            .extend(base + cfg.actor_cores..base + cfg.actor_cores + cfg.learner_cores);
        learner0_ids.push(base + cfg.actor_cores);
    }
    pod.load_programs(
        &[represent.as_str(), dynpred.as_str(), predict.as_str()],
        &actor_core_ids,
    )?;
    pod.load_program(&grad, &learner_core_ids)?;
    pod.load_program(&apply, &learner0_ids)?;
    pod.load_program(&init, &[learner0_ids[0]])?;

    // Pre-run busy baseline (see `Sebulba::run_on_with`): without it, a
    // second run on a shared pod charges itself the first run's device
    // time — inflated busy seconds, deflated projected_fps.
    let busy0: Vec<f64> = (0..cfg.total_cores())
        .map(|cid| Ok(pod.core(cid)?.busy_seconds()))
        .collect::<Result<_>>()?;

    let (params0, opt0) = match &restored {
        Some((_, s, _)) => (s.params.clone(), s.opt.clone()),
        None => {
            let outs = pod
                .core(learner0_ids[0])?
                .execute(&init, vec![HostTensor::scalar_i32(cfg.seed as i32)])
                .context("muzero init")?;
            (outs[0].clone().into_f32()?, outs[1].clone().into_f32()?)
        }
    };

    let stats = Arc::new(RunStats::new());
    let stop = Arc::new(AtomicBool::new(false));
    let bus = Arc::new(GradientBus::new(cfg.replicas));
    let factory: Arc<crate::envs::EnvFactory> = Arc::new(make_factory(cfg.env_kind, cfg.seed));

    let mut actor_joins = Vec::new();
    let mut learner_joins = Vec::new();
    // All queues exist up front so a failing learner can unblock every
    // replica's threads, not just its own (see the spawn below).
    let queues: Vec<Arc<BoundedQueue<ShardBundle>>> = (0..cfg.replicas)
        .map(|_| Arc::new(BoundedQueue::<ShardBundle>::new(cfg.queue_capacity)))
        .collect();

    // ---- checkpoint + fault wiring (replicas == 1 whenever any is on) ----
    if let Some(after) = spec.fault.as_ref().and_then(|f| f.poison_queue_after) {
        for q in &queues {
            q.poison_after_pushes(after);
        }
    }
    let start_round = restored.as_ref().map_or(0, |(m, _, _)| m.rounds_done);
    let slot: SnapshotSlot = Arc::new(Mutex::new(BTreeMap::new()));
    let actor_ck = if spec.checkpoint.is_some() || restored.is_some() {
        Some(ActorCheckpoint {
            every: spec.checkpoint.as_ref().map_or(u64::MAX, |c| c.every),
            slot: slot.clone(),
            resume: restored.as_ref().map(|(_, _, a)| a.clone()),
        })
    } else {
        None
    };
    let t_start = Instant::now();

    for r in 0..cfg.replicas {
        let base = r * n_per;
        let store = Arc::new(match &restored {
            Some((_, s, _)) => ParamStore::with_version(params0.clone(), s.version),
            None => ParamStore::new(params0.clone()),
        });
        let queue = queues[r].clone();
        let pool = WorkerPool::new(cfg.env_workers);

        for ac in 0..cfg.actor_cores {
            let core = pod.core(base + ac)?;
            for th in 0..cfg.threads_per_actor_core {
                let actor_id = (r * cfg.actor_cores + ac) * cfg.threads_per_actor_core + th;
                let mcfg = MuZeroActorConfig {
                    actor_id,
                    batch,
                    unroll,
                    discount: cfg.discount,
                    num_shards: cfg.learner_cores,
                    obs_shape: obs_shape.clone(),
                    mcts: MctsConfig {
                        num_actions,
                        latent_dim: latent,
                        num_simulations: cfg.num_simulations,
                        discount: cfg.discount,
                        ..Default::default()
                    },
                    represent: represent.clone(),
                    dynpred: dynpred.clone(),
                    predict: predict.clone(),
                    seed: cfg.seed,
                    checkpoint: actor_ck.clone(),
                };
                actor_joins.push(spawn_muzero_actor(
                    mcfg,
                    core.clone(),
                    factory.clone(),
                    pool.clone(),
                    store.clone(),
                    queue.clone(),
                    stats.clone(),
                    stop.clone(),
                ));
            }
        }

        let lcfg = LearnerConfig {
            replica_id: r,
            grad_program: grad.clone(),
            apply_program: apply.clone(),
            shards_per_round: cfg.learner_cores,
            total_updates: cfg.total_updates,
            pipeline: cfg.learner_pipeline,
            checkpoint: spec.checkpoint.as_ref().map(|cs| LearnerCheckpoint {
                spec: cs.clone(),
                slot: slot.clone(),
                meta: MetaSection {
                    agent: cfg.agent.clone(),
                    seed: cfg.seed,
                    env: cfg.env_kind.as_str().to_string(),
                    rounds_done: 0,
                },
                arch: Arch::MuZero,
                topology: cfg.topology(),
            }),
            fault: spec.fault.clone(),
            start_round,
        };
        let cores: Vec<DeviceHandle> = (0..cfg.learner_cores)
            .map(|i| pod.core(base + cfg.actor_cores + i))
            .collect::<Result<_>>()?;
        let handles = LearnerHandles {
            cores,
            store: store.clone(),
            queue: queue.clone(),
            stats: stats.clone(),
            bus: bus.clone(),
        };
        learner_joins.push(spawn_guarded_learner(
            format!("mz-learner-{r}"),
            lcfg,
            handles,
            opt0.clone(),
            stop.clone(),
            queues.clone(),
            bus.clone(),
        ));
    }

    // Every thread is joined even on a learner error (same contract as
    // `Sebulba::run_on_with`): actors left running against a shut-down
    // queue would leak and their `Result`s would be dropped.
    let mut final_params = params0;
    let mut final_opt_state = opt0.clone();
    if let Some((params, opt)) =
        join_pod_threads("muzero", &stop, &queues, &bus, learner_joins, actor_joins)?
    {
        final_params = params;
        final_opt_state = opt;
    }

    let elapsed = t_start.elapsed().as_secs_f64();
    // This run's busy time only: subtract the pre-run baseline per core.
    let mut critical: f64 = 1e-12;
    for cid in 0..cfg.total_cores() {
        critical = critical.max(pod.core(cid)?.busy_seconds() - busy0[cid]);
    }
    // Exposed learner schedule as critical-path candidate (DESIGN.md §9).
    critical = critical.max(stats.learner_active_max_seconds());
    let mut actor_busy = 0.0;
    for &cid in &actor_core_ids {
        actor_busy += pod.core(cid)?.busy_seconds() - busy0[cid];
    }
    let mut learner_busy = 0.0;
    for &cid in &learner_core_ids {
        learner_busy += pod.core(cid)?.busy_seconds() - busy0[cid];
    }
    let frames = stats.env_frames.frames();
    Ok(Report {
        arch: Arch::MuZero,
        steps: frames,
        updates: stats.updates.load(Ordering::Relaxed),
        elapsed,
        throughput: frames as f64 / elapsed.max(1e-12),
        projected_throughput: frames as f64 / critical,
        final_params,
        detail: Detail::ActorLearner(ActorLearnerDetail {
            mean_staleness: stats.mean_staleness(),
            mean_episode_reward: stats.mean_episode_reward(),
            episodes: stats.episodes.load(Ordering::Relaxed),
            last_loss: stats.last_loss(),
            actor_busy_seconds: actor_busy,
            learner_busy_seconds: learner_busy,
            // MuZero actors are not instrumented with the actor-overlap
            // accounting (record_actor_overlap is Sebulba-actor only), so
            // the four actor_* pipeline fields read 0 for this runner; the
            // learner_* fields are live (shared learner thread).
            actor_infer_seconds: stats.actor_infer_seconds(),
            actor_env_step_seconds: stats.actor_env_seconds(),
            actor_loop_seconds: stats.actor_loop_seconds(),
            actor_overlap_seconds: stats.actor_overlap_seconds(),
            learner_grad_seconds: stats.learner_grad_seconds(),
            learner_collective_seconds: stats.learner_collective_seconds(),
            learner_apply_seconds: stats.learner_apply_seconds(),
            learner_active_seconds: stats.learner_active_seconds(),
            learner_overlap_seconds: stats.learner_overlap_seconds(),
            queue_push_block_seconds: queues.iter().map(|q| q.push_block_seconds()).sum(),
            queue_pop_block_seconds: queues.iter().map(|q| q.pop_block_seconds()).sum(),
            infer_calls: stats.infer_calls(),
            grad_calls: stats.grad_calls(),
            apply_calls: stats.apply_calls(),
            env_step_calls: stats.env_step_calls(),
            pods_joined: 0,
            pods_evicted: 0,
            membership_epoch: 0,
            join_param_version: 0,
            final_opt_state,
        }),
    })
}
