//! Deterministic PRNG substrate (no `rand` crate in the vendored set).
//!
//! `SplitMix64` seeds streams; `Xoshiro256` (xoshiro256++) is the workhorse
//! generator. Distribution helpers cover everything the coordinator needs:
//! uniform, normal (Box–Muller), gamma (Marsaglia–Tsang) and Dirichlet
//! (for MCTS root exploration noise).
//!
//! Every thread in the system derives its stream as
//! `Xoshiro256::from_stream(run_seed, stream_id)`, so whole runs are
//! reproducible from a single seed (see DESIGN.md §7).

/// SplitMix64: tiny, full-period seeder (Steele et al. 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ (Blackman & Vigna 2019): fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (the canonical initialisation).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream: hash (seed, stream) through SplitMix64.
    pub fn from_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let a = sm.next_u64();
        let mut sm2 = SplitMix64::new(a ^ stream.wrapping_mul(0xA24BAED4963EE407));
        Self { s: [sm2.next_u64(), sm2.next_u64(), sm2.next_u64(), sm2.next_u64()] }
    }

    /// The full generator state, for checkpointing: `from_state(state())`
    /// continues the sequence exactly where this generator left off.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a checkpointed [`Self::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn next_below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u64() as u32 as u64;
        let mut m = x.wrapping_mul(n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64() as u32 as u64;
                m = x.wrapping_mul(n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// i32 seed for an XLA program invocation.
    #[inline]
    pub fn next_program_seed(&mut self) -> i32 {
        (self.next_u64() & 0x7FFF_FFFF) as i32
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (2000); shape > 0.
    pub fn next_gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.next_gamma(shape + 1.0);
            let u = self.next_f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 1e-300 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) sample of dimension `n`.
    pub fn next_dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.next_gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum > 0.0 {
            for v in &mut g {
                *v /= sum;
            }
        } else {
            for v in &mut g {
                *v = 1.0 / n as f64;
            }
        }
        g
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn next_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.next_below(weights.len() as u32) as usize;
        }
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_streams_differ() {
        let mut a = Xoshiro256::from_stream(1, 0);
        let mut b = Xoshiro256::from_stream(1, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "streams should be effectively independent");
    }

    #[test]
    fn state_roundtrip_continues_the_sequence() {
        let mut a = Xoshiro256::from_stream(42, 7);
        for _ in 0..13 {
            a.next_u64();
        }
        let mut b = Xoshiro256::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // distribution helpers share the same underlying stream
        let mut c = Xoshiro256::from_state(a.state());
        assert_eq!(a.next_normal(), c.next_normal());
        assert_eq!(a.next_program_seed(), c.next_program_seed());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Xoshiro256::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Xoshiro256::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.next_below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Xoshiro256::new(6);
        for &shape in &[0.3, 1.0, 4.5] {
            let n = 30_000;
            let mut sum = 0.0;
            for _ in 0..n {
                let g = r.next_gamma(shape);
                assert!(g >= 0.0);
                sum += g;
            }
            let mean = sum / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(0.5),
                "shape {shape}: mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Xoshiro256::new(7);
        let d = r.next_dirichlet(0.3, 5);
        assert_eq!(d.len(), 5);
        let sum: f64 = d.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(d.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn weighted_sampling_distribution() {
        let mut r = Xoshiro256::new(8);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.next_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn program_seed_non_negative() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..1000 {
            assert!(r.next_program_seed() >= 0);
        }
    }
}
