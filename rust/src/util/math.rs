//! Small numeric helpers shared by the coordinator and the search module.

/// Numerically-stable softmax.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut out: Vec<f32> = logits.iter().map(|&l| (l - max).exp()).collect();
    let sum: f32 = out.iter().sum();
    if sum > 0.0 {
        for v in &mut out {
            *v /= sum;
        }
    } else {
        let u = 1.0 / out.len() as f32;
        out.iter_mut().for_each(|v| *v = u);
    }
    out
}

/// Index of the maximum element (first on ties). Panics on empty input.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// p-th percentile (0..=100) by nearest-rank on a copy; 0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_stable_with_large_logits() {
        let p = softmax(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-6);
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
