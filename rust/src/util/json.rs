//! Minimal JSON parser/writer (the vendored crate set has no `serde`).
//!
//! Covers the full JSON grammar; used for the artifact manifest, run
//! configs, and benchmark result dumps. Numbers are kept as f64 (the
//! manifest only contains sizes well below 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that errors with the key name (manifest-friendly).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// [1,2,3] -> Vec<usize>; errors on non-numeric entries.
    pub fn as_usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        let arr = self.as_arr().ok_or_else(|| anyhow::anyhow!("expected array"))?;
        arr.iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected , or }"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected , or ]"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        // surrogate pairs
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("missing low surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                low = low * 16
                                    + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        self.pos = start + len;
                        let chunk = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| self.err("truncated utf8"))?;
                        s.push_str(
                            std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Convenience constructors for building result dumps.
impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize(), Some(1));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"Aé"));
        // utf8 passthrough
        let j = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo→"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01a").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"agents":{"x":{"n":7}},"arr":[1.5,-2,true,null,"s"]}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[3, 4, 5]").unwrap();
        assert_eq!(j.as_usize_vec().unwrap(), vec![3, 4, 5]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
