//! Hand-rolled CLI argument parser (no `clap` in the vendored set).
//!
//! Supports `--key value`, `--key=value` and bare `--flag` forms, plus
//! positional arguments. Typed getters parse on demand with good errors.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse an iterator of arguments (usually `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Hard-error on flag names the subcommand does not accept — every
    /// `podracer` surface honours or rejects, never silently ignores.
    pub fn check_known(&self, cmd: &str, accepted: &[&str]) -> anyhow::Result<()> {
        for key in self.flags.keys() {
            if !accepted.contains(&key.as_str()) {
                anyhow::bail!(
                    "unknown flag --{key} for `podracer {cmd}` (accepted: {})",
                    accepted.iter().map(|f| format!("--{f}")).collect::<Vec<_>>().join(" ")
                );
            }
        }
        Ok(())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.flags.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(anyhow::anyhow!("--{key} expects a bool, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["train", "--steps", "100", "--fast", "--lr=0.5", "extra"]);
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.get_bool("fast", false).unwrap());
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_str("name", "x"), "x");
        assert!(!a.has("missing"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "2"]);
        assert_eq!(a.get_str("a", ""), "true");
        assert_eq!(a.get_usize("b", 0).unwrap(), 2);
    }

    #[test]
    fn check_known_rejects_unknown_flags() {
        let a = parse(&["--steps", "1"]);
        assert!(a.check_known("train", &["steps"]).is_ok());
        let err = a.check_known("train", &["updates"]).unwrap_err().to_string();
        assert!(err.contains("--steps") && err.contains("--updates"), "{err}");
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["--n", "xyz"]);
        assert!(a.get_usize("n", 0).is_err());
        assert!(a.get_bool("n", false).is_err());
    }
}
