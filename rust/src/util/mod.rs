//! Shared substrates: JSON, PRNG, CLI parsing, logging, small math helpers.

pub mod cli;
pub mod json;
pub mod logging;
pub mod math;
pub mod rng;
