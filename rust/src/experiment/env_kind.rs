//! Typed host-environment kinds.
//!
//! Replaces the stringly `env_kind: &'static str` that used to be plumbed
//! through `SebulbaConfig` / `MuZeroRunConfig` / `envs::make_factory`: an
//! unknown `--env` now fails at parse time with the list of valid kinds,
//! instead of being silently coerced to `"catch"` (the old
//! `env_kind_static` footgun) or erroring deep inside config validation.
//! `envs::make_factory` takes an `EnvKind` and is infallible.

use std::fmt;
use std::str::FromStr;

/// Every host-side environment the crate ships (see [`crate::envs`]).
/// Adding a sixth env means adding a variant here, a `match` arm in
/// `envs::build_env`, and (for real training) an agent in
/// `python/compile/aot.py` — the compiler walks you to every site.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EnvKind {
    /// 10x5 Catch (flat 50-dim observation, 3 actions).
    Catch,
    /// 8x8 GridWorld with walls (flat 128-dim observation, 4 actions).
    Gridworld,
    /// Classic CartPole (4-dim observation, 2 actions).
    Cartpole,
    /// 10-state chain exploration task (10-dim observation, 2 actions).
    Chain,
    /// Atari substitute: 42x42x2 pixel rendering, sticky actions,
    /// episodic lives (6 actions).
    AtariLike,
}

impl EnvKind {
    /// Every variant, in canonical order (what the CLI smoke matrix and
    /// error messages enumerate).
    pub const ALL: [EnvKind; 5] = [
        EnvKind::Catch,
        EnvKind::Gridworld,
        EnvKind::Cartpole,
        EnvKind::Chain,
        EnvKind::AtariLike,
    ];

    /// The canonical CLI / manifest name.
    pub fn as_str(self) -> &'static str {
        match self {
            EnvKind::Catch => "catch",
            EnvKind::Gridworld => "gridworld",
            EnvKind::Cartpole => "cartpole",
            EnvKind::Chain => "chain",
            EnvKind::AtariLike => "atari_like",
        }
    }

    /// `"catch, gridworld, cartpole, chain, atari_like"` — for diagnostics.
    pub fn valid_names() -> String {
        Self::ALL.map(EnvKind::as_str).join(", ")
    }
}

impl fmt::Display for EnvKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for EnvKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        for kind in Self::ALL {
            if kind.as_str() == s {
                return Ok(kind);
            }
        }
        anyhow::bail!("unknown environment {s:?} (valid: {})", Self::valid_names())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_through_from_str() {
        for kind in EnvKind::ALL {
            assert_eq!(kind.as_str().parse::<EnvKind>().unwrap(), kind);
            assert_eq!(format!("{kind}"), kind.as_str());
        }
    }

    #[test]
    fn unknown_names_error_with_the_valid_list() {
        let err = "pong".parse::<EnvKind>().unwrap_err().to_string();
        assert!(err.contains("pong"), "{err}");
        for kind in EnvKind::ALL {
            assert!(err.contains(kind.as_str()), "error must list {kind}: {err}");
        }
        // the old env_kind_static coerced anything unknown to catch — the
        // typed parse must never do that
        assert!("".parse::<EnvKind>().is_err());
        assert!("Catch".parse::<EnvKind>().is_err(), "names are case-sensitive");
    }
}
