//! The declarative core split: how a pod's cores are divided between
//! acting and learning, how many replicas tile it, and how deep the
//! actor/learner software pipelines run.
//!
//! This is the paper's one idea stated as data: Anakin and Sebulba differ
//! only in where the acting/learning boundary falls (in-graph vs across
//! cores), so one `Topology` value describes a run of any architecture.
//! Architectures read the fields they use: Anakin treats the pod as
//! `total_cores()` identical replicas of the fused act+learn program (the
//! actor/learner split is degenerate — build its topology with
//! [`Topology::anakin`]); Sebulba and MuZero require a proper split
//! (`require_split`). Knobs an architecture cannot honour are rejected at
//! build/run time (`Anakin::check_topology`, `MuZero::check_topology`) —
//! never silently dropped.

use std::fmt;
use std::num::NonZeroUsize;
use std::str::FromStr;

use anyhow::{bail, Result};

/// Which half of a (possibly multi-pod) experiment a process runs
/// (DESIGN.md §15). Single-process runs are `Colocated` — the historical
/// behaviour and the default. Distributed Sebulba splits one experiment
/// into a `Learner` pod plus `pods - 1` `Actor` pods connected over the
/// transport seam.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum PodRole {
    /// Actors and learners in one process (the in-memory coordinator).
    #[default]
    Colocated,
    /// This process owns the learner cores: listens, learns, publishes.
    Learner,
    /// This process owns actor cores: connects, acts, ships trajectories.
    Actor,
}

impl PodRole {
    pub fn as_str(self) -> &'static str {
        match self {
            PodRole::Colocated => "colocated",
            PodRole::Learner => "learner",
            PodRole::Actor => "actor",
        }
    }
}

impl fmt::Display for PodRole {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for PodRole {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "colocated" => Ok(PodRole::Colocated),
            "learner" => Ok(PodRole::Learner),
            "actor" => Ok(PodRole::Actor),
            other => bail!("unknown pod role {other:?} (valid: colocated, learner, actor)"),
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Actor cores per replica (paper: `A`). May be 0 only for
    /// architectures without a host-side acting path (Anakin).
    pub actor_cores: usize,
    /// Learner cores per replica (paper: `8 - A`). For Anakin this is the
    /// whole slice: every core runs the fused on-device loop.
    pub learner_cores: usize,
    /// Replicas (each gets its own cores + host state; cross-replica
    /// reduction runs on the collective bus).
    pub replicas: usize,
    /// Actor threads per actor core (paper: >= 1 Python threads to hide
    /// env stepping behind device time).
    pub threads_per_actor_core: usize,
    /// Sub-batches each actor thread round-robins through the infer→step
    /// cycle (DESIGN.md §2). 1 = fully synchronous actor.
    pub pipeline_stages: usize,
    /// Grad/apply rounds the learner keeps in flight (DESIGN.md §9).
    /// 1 = serial learner.
    pub learner_pipeline: usize,
    /// Worker threads in the shared env-stepping pool, per replica.
    pub env_workers: usize,
    /// Trajectory-queue capacity per replica (backpressure bound).
    pub queue_capacity: usize,
    /// Processes the experiment spans: 1 = single-process (colocated, the
    /// historical behaviour), N >= 2 = one learner pod + N-1 actor pods over
    /// the transport seam (DESIGN.md §15). `NonZeroUsize` so "no pods" is
    /// unrepresentable rather than a runtime check.
    pub pods: NonZeroUsize,
}

/// The single-process pod count (1) — `pods`' default.
pub const ONE_POD: NonZeroUsize = NonZeroUsize::MIN;

impl Default for Topology {
    fn default() -> Self {
        Self {
            actor_cores: 2,
            learner_cores: 2,
            replicas: 1,
            threads_per_actor_core: 2,
            pipeline_stages: 2,
            learner_pipeline: 2,
            env_workers: 2,
            queue_capacity: 4,
            pods: ONE_POD,
        }
    }
}

impl Topology {
    /// An Anakin slice of `cores` cores: no actor/learner distinction
    /// (every core runs the fused act+learn program), all pipeline depths
    /// collapsed to the trivial 1.
    pub fn anakin(cores: usize) -> Self {
        Self {
            actor_cores: 0,
            learner_cores: cores,
            replicas: 1,
            threads_per_actor_core: 1,
            pipeline_stages: 1,
            learner_pipeline: 1,
            env_workers: 1,
            queue_capacity: 1,
            pods: ONE_POD,
        }
    }

    /// A single-replica `actor`:`learner` split with default depths.
    pub fn split(actor_cores: usize, learner_cores: usize) -> Self {
        Self { actor_cores, learner_cores, ..Self::default() }
    }

    pub fn cores_per_replica(&self) -> usize {
        self.actor_cores + self.learner_cores
    }

    pub fn total_cores(&self) -> usize {
        self.cores_per_replica() * self.replicas
    }

    /// Structural validity — the checks every architecture shares. The
    /// architecture-specific geometry (batch divisibility, shard counts)
    /// lives with the resolved configs ([`crate::coordinator::SebulbaConfig`]).
    pub fn validate(&self) -> Result<()> {
        if self.cores_per_replica() == 0 {
            bail!("topology has zero cores per replica");
        }
        if self.replicas == 0 {
            bail!("replicas must be >= 1");
        }
        if self.threads_per_actor_core == 0 {
            bail!("threads_per_actor_core must be >= 1");
        }
        if self.pipeline_stages == 0 {
            bail!("pipeline_stages must be >= 1 (1 = synchronous actor)");
        }
        if self.learner_pipeline == 0 {
            bail!("learner_pipeline must be >= 1 (1 = serial learner)");
        }
        if self.env_workers == 0 {
            bail!("env_workers must be >= 1");
        }
        if self.queue_capacity == 0 {
            bail!("queue_capacity must be >= 1");
        }
        Ok(())
    }

    /// [`Self::validate`] plus the pod bound: the split must fit the pod
    /// it is about to run on. Single-process form — equivalent to
    /// [`Self::validate_for_role`] with [`PodRole::Colocated`].
    pub fn validate_for_pod(&self, pod_cores: usize) -> Result<()> {
        self.validate_for_role(PodRole::Colocated, pod_cores)
    }

    /// Cores one process needs when it plays `role` in this topology: a
    /// colocated pod hosts everything, a learner pod only the learner
    /// slice, an actor pod only one pod's actor slice.
    pub fn cores_for_role(&self, role: PodRole) -> usize {
        match role {
            PodRole::Colocated => self.total_cores(),
            PodRole::Learner => self.learner_cores * self.replicas,
            PodRole::Actor => self.actor_cores,
        }
    }

    /// [`Self::validate`] plus the per-role pod bound (DESIGN.md §15):
    /// the slice this process is responsible for must fit its local pod.
    pub fn validate_for_role(&self, role: PodRole, pod_cores: usize) -> Result<()> {
        self.validate()?;
        let need = self.cores_for_role(role);
        if need > pod_cores {
            bail!(
                "topology wants {} cores for the {} role ({}A+{}L x {} replicas) \
                 but the pod has {}",
                need,
                role,
                self.actor_cores,
                self.learner_cores,
                self.replicas,
                pod_cores
            );
        }
        Ok(())
    }

    /// A stable 64-bit digest of every field (FNV-1a over the field
    /// values in declaration order). Checkpoints store it in their header
    /// so a restore into a differently-shaped pod is a typed
    /// `TopologyMismatch` error instead of undefined scheduling
    /// (DESIGN.md §13).
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325; // FNV offset basis
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3); // FNV prime
            }
        };
        mix(self.actor_cores as u64);
        mix(self.learner_cores as u64);
        mix(self.replicas as u64);
        mix(self.threads_per_actor_core as u64);
        mix(self.pipeline_stages as u64);
        mix(self.learner_pipeline as u64);
        mix(self.env_workers as u64);
        mix(self.queue_capacity as u64);
        mix(self.pods.get() as u64);
        h
    }

    /// Architectures with a host-side acting path (Sebulba, MuZero) need a
    /// proper actor/learner split.
    pub fn require_split(&self) -> Result<()> {
        if self.actor_cores == 0 || self.learner_cores == 0 {
            bail!(
                "need at least one actor core and one learner core (got {}A+{}L)",
                self.actor_cores,
                self.learner_cores
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_and_anakin_are_valid() {
        Topology::default().validate().unwrap();
        Topology::default().require_split().unwrap();
        let t = Topology::anakin(4);
        t.validate().unwrap();
        assert_eq!(t.total_cores(), 4);
        assert!(t.require_split().is_err(), "anakin topology has no split");
    }

    #[test]
    fn zero_cores_rejected() {
        let t = Topology { actor_cores: 0, learner_cores: 0, ..Default::default() };
        assert!(t.validate().unwrap_err().to_string().contains("zero cores"));
        assert!(Topology::anakin(0).validate().is_err());
    }

    #[test]
    fn bad_replica_counts_rejected() {
        let t = Topology { replicas: 0, ..Default::default() };
        assert!(t.validate().unwrap_err().to_string().contains("replicas"));
    }

    #[test]
    fn zero_pipeline_depths_rejected() {
        let t = Topology { pipeline_stages: 0, ..Default::default() };
        assert!(t.validate().unwrap_err().to_string().contains("pipeline_stages"));
        let t = Topology { learner_pipeline: 0, ..Default::default() };
        assert!(t.validate().unwrap_err().to_string().contains("learner_pipeline"));
        let t = Topology { threads_per_actor_core: 0, ..Default::default() };
        assert!(t.validate().is_err());
        let t = Topology { env_workers: 0, ..Default::default() };
        assert!(t.validate().is_err());
        let t = Topology { queue_capacity: 0, ..Default::default() };
        assert!(t.validate().is_err());
    }

    #[test]
    fn fingerprint_distinguishes_every_field() {
        let base = Topology::default();
        assert_eq!(base.fingerprint(), Topology::default().fingerprint());
        let variants = [
            Topology { actor_cores: 3, ..base.clone() },
            Topology { learner_cores: 3, ..base.clone() },
            Topology { replicas: 2, ..base.clone() },
            Topology { threads_per_actor_core: 1, ..base.clone() },
            Topology { pipeline_stages: 1, ..base.clone() },
            Topology { learner_pipeline: 1, ..base.clone() },
            Topology { env_workers: 1, ..base.clone() },
            Topology { queue_capacity: 1, ..base.clone() },
            Topology { pods: NonZeroUsize::new(2).unwrap(), ..base.clone() },
        ];
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(v.fingerprint(), base.fingerprint(), "field {i} not hashed");
        }
        // field *positions* matter: swapping actor/learner counts differs
        assert_ne!(
            Topology::split(1, 2).fingerprint(),
            Topology::split(2, 1).fingerprint()
        );
    }

    #[test]
    fn pod_roles_roundtrip_and_reject_unknowns() {
        for role in [PodRole::Colocated, PodRole::Learner, PodRole::Actor] {
            assert_eq!(role.as_str().parse::<PodRole>().unwrap(), role);
        }
        assert!("driver".parse::<PodRole>().is_err());
        assert_eq!(PodRole::default(), PodRole::Colocated);
    }

    #[test]
    fn per_role_validation_sizes_each_pod_for_its_slice() {
        // 3A+2L: a colocated pod needs all 5 cores, a learner pod only its
        // 2, an actor pod only its 3.
        let t = Topology::split(3, 2);
        assert_eq!(t.cores_for_role(PodRole::Colocated), 5);
        assert_eq!(t.cores_for_role(PodRole::Learner), 2);
        assert_eq!(t.cores_for_role(PodRole::Actor), 3);
        t.validate_for_role(PodRole::Learner, 2).unwrap();
        t.validate_for_role(PodRole::Actor, 3).unwrap();
        assert!(t.validate_for_role(PodRole::Colocated, 4).is_err());
        let err = t.validate_for_role(PodRole::Learner, 1).unwrap_err().to_string();
        assert!(err.contains("learner") && err.contains("pod has 1"), "{err}");
        // structural validity is still checked first
        let bad = Topology { replicas: 0, ..t };
        assert!(bad.validate_for_role(PodRole::Actor, 8).is_err());
    }

    #[test]
    fn split_exceeding_pod_rejected() {
        // 3A+2L fits a 5-core pod exactly, fails a 4-core pod with a
        // diagnostic naming both sides
        let t = Topology::split(3, 2);
        t.validate_for_pod(5).unwrap();
        let err = t.validate_for_pod(4).unwrap_err().to_string();
        assert!(err.contains("5 cores") && err.contains("pod has 4"), "{err}");
        // replication multiplies the demand
        let t = Topology { replicas: 2, ..Topology::split(2, 2) };
        assert!(t.validate_for_pod(7).is_err());
        t.validate_for_pod(8).unwrap();
    }
}
