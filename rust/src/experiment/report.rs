//! The one run report every architecture produces.
//!
//! `AnakinReport` and Sebulba's `RunReport` used to be separate structs
//! with divergent field names (`sps` vs `fps`, `steps` vs `frames`), so the
//! CLI, the benches and the CI gate each carried per-architecture code.
//! [`Report`] unifies the common surface (steps, updates, throughput,
//! `final_params`) and pushes the architecture-specific accounting into a
//! typed [`Detail`] payload.

use super::Arch;
use crate::checkpoint::format::crc32_update;
use crate::util::json::Json;

/// Per-outer-iteration Anakin metrics, averaged over cores and in-graph
/// updates: `[loss, pg_loss, baseline_loss, entropy, episode_reward]`.
pub type MetricRow = [f64; 5];

/// What a run produced. `steps` counts environment steps (frames, for the
/// actor/learner architectures); `throughput` is wall-clock steps/sec and
/// `projected_throughput` is steps/sec over the critical-path busy time —
/// the number comparable across core counts on the 1-CPU testbed
/// (DESIGN.md §1).
#[derive(Debug)]
pub struct Report {
    pub arch: Arch,
    pub steps: u64,
    pub updates: u64,
    pub elapsed: f64,
    /// Wall-clock steps/sec (sps for Anakin, fps for Sebulba/MuZero).
    pub throughput: f64,
    /// Steps/sec if the simulated cores ran truly in parallel
    /// (steps / critical-path busy time — DESIGN.md §1/§9/§10).
    pub projected_throughput: f64,
    pub final_params: Vec<f32>,
    pub detail: Detail,
}

/// Architecture-specific accounting.
#[derive(Debug)]
pub enum Detail {
    /// Replicated on-device loop (Anakin).
    Anakin(AnakinDetail),
    /// Decomposed actor/learner coordination (Sebulba and MuZero — MuZero
    /// shares the learner path and reports through the same shape; its
    /// `actor_*` pipeline fields read 0 because MCTS actors are not
    /// instrumented with the split-batch overlap accounting).
    ActorLearner(ActorLearnerDetail),
}

/// Replica-schedule accounting for the Anakin drivers (DESIGN.md §10).
#[derive(Debug)]
pub struct AnakinDetail {
    /// Learning curve, one [`MetricRow`] per outer iteration.
    pub metrics: Vec<MetricRow>,
    /// Device time the replica schedule was exposed to, summed over
    /// replicas.
    pub replica_device_seconds: f64,
    /// Host conversion + metric accumulation time, summed over replicas.
    pub replica_host_seconds: f64,
    /// Collective time (bus wait + reduction), summed over replicas.
    pub replica_collective_seconds: f64,
    /// Active wall per replica (loop wall minus collective wait), summed.
    pub replica_active_seconds: f64,
    /// Work the threaded schedule hid: per replica,
    /// `max(0, device + host − active)`. ~0 under the serial driver.
    pub replica_overlap_seconds: f64,
    /// Max per-replica busy time — the critical-path contribution
    /// `projected_throughput` divides by.
    pub replica_busy_max_seconds: f64,
}

/// Actor/learner pipeline accounting (DESIGN.md §2/§9) shared by Sebulba
/// and MuZero runs.
#[derive(Debug)]
pub struct ActorLearnerDetail {
    pub mean_staleness: f64,
    pub mean_episode_reward: f64,
    pub episodes: u64,
    pub last_loss: f32,
    pub actor_busy_seconds: f64,
    pub learner_busy_seconds: f64,
    /// Device time actor threads spent on inference (issue → harvest).
    pub actor_infer_seconds: f64,
    /// Host time actor threads spent stepping environments.
    pub actor_env_step_seconds: f64,
    /// Actor hot-loop wall time, excluding trajectory-queue backpressure.
    pub actor_loop_seconds: f64,
    /// Work the split-batch pipeline hid (~0 at `pipeline_stages = 1`).
    pub actor_overlap_seconds: f64,
    /// Device span of learner grad rounds (issue → harvest).
    pub learner_grad_seconds: f64,
    /// Host time in the collective (tree mean + bus wait).
    pub learner_collective_seconds: f64,
    /// Apply-program spans (issue → new params on host).
    pub learner_apply_seconds: f64,
    /// Learner hot-loop wall time, excluding queue starvation.
    pub learner_active_seconds: f64,
    /// Overlap indicator (~0 at `learner_pipeline = 1`).
    pub learner_overlap_seconds: f64,
    pub queue_push_block_seconds: f64,
    pub queue_pop_block_seconds: f64,
    /// Completed inference calls (the latency histogram's sample count).
    pub infer_calls: u64,
    /// Completed learner grad rounds.
    pub grad_calls: u64,
    /// Completed apply rounds.
    pub apply_calls: u64,
    /// Batched env-step rounds recorded by actor threads.
    pub env_step_calls: u64,
    /// Elastic membership accounting (DESIGN.md §16). On a learner pod:
    /// pods admitted / retired over the run and the final membership
    /// epoch. On an actor pod: `membership_epoch` is its admission epoch.
    /// All 0 for in-memory and static distributed runs.
    pub pods_joined: u64,
    pub pods_evicted: u64,
    pub membership_epoch: u64,
    /// Actor pods only: the params version received in the admission
    /// handshake (a late joiner sees the learner's *current* version, not
    /// 0). 0 on learner pods and in-memory runs.
    pub join_param_version: u64,
    /// Optimiser state of replica 0's learner (for warm-starting).
    pub final_opt_state: Vec<f32>,
}

impl Report {
    /// The detail payload, if this was an Anakin run.
    pub fn as_anakin(&self) -> Option<&AnakinDetail> {
        match &self.detail {
            Detail::Anakin(d) => Some(d),
            Detail::ActorLearner(_) => None,
        }
    }

    /// The detail payload, if this was a Sebulba or MuZero run.
    pub fn as_actor_learner(&self) -> Option<&ActorLearnerDetail> {
        match &self.detail {
            Detail::ActorLearner(d) => Some(d),
            Detail::Anakin(_) => None,
        }
    }

    /// `(params, opt_state)` for staging a follow-up run
    /// (`ExperimentBuilder::warm_start`). `None` for Anakin runs, whose
    /// optimiser state lives in-graph.
    pub fn into_warm_start(self) -> Option<(Vec<f32>, Vec<f32>)> {
        match self.detail {
            Detail::ActorLearner(d) => Some((self.final_params, d.final_opt_state)),
            Detail::Anakin(_) => None,
        }
    }

    fn steps_label(&self) -> &'static str {
        match self.arch {
            Arch::Anakin => "steps",
            Arch::Sebulba | Arch::MuZero => "frames",
        }
    }

    fn rate_label(&self) -> &'static str {
        match self.arch {
            Arch::Anakin => "sps",
            Arch::Sebulba | Arch::MuZero => "fps",
        }
    }

    /// CRC32 over the final params' f32 bit patterns (little-endian) — a
    /// compact bit-identity fingerprint for oracles and league results.
    pub fn final_params_crc32(&self) -> u32 {
        let mut state = 0xFFFF_FFFFu32;
        for p in &self.final_params {
            state = crc32_update(state, &p.to_le_bytes());
        }
        state ^ 0xFFFF_FFFF
    }

    /// The machine-readable report (`--report-json`): stable field names,
    /// every per-stage second the planner's `CostModel::fold` consumes, and
    /// a params digest instead of the raw parameter vector.
    pub fn to_json(&self) -> Json {
        let detail = match &self.detail {
            Detail::Anakin(d) => {
                let (first, last) = (d.metrics.first(), d.metrics.last());
                let row = |r: Option<&MetricRow>, i: usize| match r {
                    Some(m) => Json::num(m[i]),
                    None => Json::Null,
                };
                Json::obj(vec![
                    ("kind", Json::str("anakin")),
                    ("metrics_rows", Json::num(d.metrics.len() as f64)),
                    ("first_loss", row(first, 0)),
                    ("last_loss", row(last, 0)),
                    ("first_reward", row(first, 4)),
                    ("last_reward", row(last, 4)),
                    ("replica_device_seconds", Json::num(d.replica_device_seconds)),
                    ("replica_host_seconds", Json::num(d.replica_host_seconds)),
                    ("replica_collective_seconds", Json::num(d.replica_collective_seconds)),
                    ("replica_active_seconds", Json::num(d.replica_active_seconds)),
                    ("replica_overlap_seconds", Json::num(d.replica_overlap_seconds)),
                    ("replica_busy_max_seconds", Json::num(d.replica_busy_max_seconds)),
                ])
            }
            Detail::ActorLearner(d) => Json::obj(vec![
                ("kind", Json::str("actor_learner")),
                ("mean_staleness", Json::num(d.mean_staleness)),
                ("mean_episode_reward", Json::num(d.mean_episode_reward)),
                ("episodes", Json::num(d.episodes as f64)),
                ("last_loss", Json::num(d.last_loss as f64)),
                ("actor_busy_seconds", Json::num(d.actor_busy_seconds)),
                ("learner_busy_seconds", Json::num(d.learner_busy_seconds)),
                ("actor_infer_seconds", Json::num(d.actor_infer_seconds)),
                ("actor_env_step_seconds", Json::num(d.actor_env_step_seconds)),
                ("actor_loop_seconds", Json::num(d.actor_loop_seconds)),
                ("actor_overlap_seconds", Json::num(d.actor_overlap_seconds)),
                ("learner_grad_seconds", Json::num(d.learner_grad_seconds)),
                ("learner_collective_seconds", Json::num(d.learner_collective_seconds)),
                ("learner_apply_seconds", Json::num(d.learner_apply_seconds)),
                ("learner_active_seconds", Json::num(d.learner_active_seconds)),
                ("learner_overlap_seconds", Json::num(d.learner_overlap_seconds)),
                ("queue_push_block_seconds", Json::num(d.queue_push_block_seconds)),
                ("queue_pop_block_seconds", Json::num(d.queue_pop_block_seconds)),
                ("infer_calls", Json::num(d.infer_calls as f64)),
                ("grad_calls", Json::num(d.grad_calls as f64)),
                ("apply_calls", Json::num(d.apply_calls as f64)),
                ("env_step_calls", Json::num(d.env_step_calls as f64)),
                ("pods_joined", Json::num(d.pods_joined as f64)),
                ("pods_evicted", Json::num(d.pods_evicted as f64)),
                ("membership_epoch", Json::num(d.membership_epoch as f64)),
                ("join_param_version", Json::num(d.join_param_version as f64)),
                ("final_opt_state_len", Json::num(d.final_opt_state.len() as f64)),
            ]),
        };
        Json::obj(vec![
            ("arch", Json::str(self.arch.as_str())),
            ("steps", Json::num(self.steps as f64)),
            ("updates", Json::num(self.updates as f64)),
            ("elapsed_seconds", Json::num(self.elapsed)),
            ("throughput", Json::num(self.throughput)),
            ("projected_throughput", Json::num(self.projected_throughput)),
            ("final_params_len", Json::num(self.final_params.len() as f64)),
            ("final_params_crc32", Json::num(self.final_params_crc32() as f64)),
            ("detail", detail),
        ])
    }

    /// The multi-line human summary the CLI prints — one code path for all
    /// three architectures.
    pub fn summary(&self) -> String {
        let rate = self.rate_label();
        let mut out = format!(
            "{}: {}={} updates={} elapsed={:.2}s {}={:.0} projected_{}={:.0}",
            self.arch,
            self.steps_label(),
            self.steps,
            self.updates,
            self.elapsed,
            rate,
            self.throughput,
            rate,
            self.projected_throughput
        );
        match &self.detail {
            Detail::Anakin(d) => {
                out.push_str(&format!(
                    "\n  replica schedule: device={:.2}s host={:.2}s collective={:.2}s \
                     hidden_by_overlap={:.2}s busy_max={:.2}s",
                    d.replica_device_seconds,
                    d.replica_host_seconds,
                    d.replica_collective_seconds,
                    d.replica_overlap_seconds,
                    d.replica_busy_max_seconds
                ));
                if let (Some(first), Some(last)) = (d.metrics.first(), d.metrics.last()) {
                    out.push_str(&format!(
                        "\n  reward: {:.3} -> {:.3} | loss: {:.4} -> {:.4}",
                        first[4], last[4], first[0], last[0]
                    ));
                }
            }
            Detail::ActorLearner(d) => {
                out.push_str(&format!(
                    "\n  episodes={} mean_reward={:.3} staleness={:.2} last_loss={:.4}",
                    d.episodes, d.mean_episode_reward, d.mean_staleness, d.last_loss
                ));
                out.push_str(&format!(
                    "\n  actor pipeline: infer={:.2}s env_step={:.2}s hidden_by_overlap={:.2}s",
                    d.actor_infer_seconds, d.actor_env_step_seconds, d.actor_overlap_seconds
                ));
                out.push_str(&format!(
                    "\n  learner pipeline: grad={:.2}s collective={:.2}s apply={:.2}s \
                     hidden_by_overlap={:.2}s",
                    d.learner_grad_seconds,
                    d.learner_collective_seconds,
                    d.learner_apply_seconds,
                    d.learner_overlap_seconds
                ));
                if d.membership_epoch > 0 {
                    out.push_str(&format!(
                        "\n  membership: epoch={} pods_joined={} pods_evicted={}",
                        d.membership_epoch, d.pods_joined, d.pods_evicted
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sebulba_report() -> Report {
        Report {
            arch: Arch::Sebulba,
            steps: 1280,
            updates: 2,
            elapsed: 0.5,
            throughput: 2560.0,
            projected_throughput: 5120.0,
            final_params: vec![1.0, 2.0],
            detail: Detail::ActorLearner(ActorLearnerDetail {
                mean_staleness: 1.0,
                mean_episode_reward: 0.25,
                episodes: 7,
                last_loss: 0.125,
                actor_busy_seconds: 0.1,
                learner_busy_seconds: 0.2,
                actor_infer_seconds: 0.05,
                actor_env_step_seconds: 0.04,
                actor_loop_seconds: 0.09,
                actor_overlap_seconds: 0.0,
                learner_grad_seconds: 0.1,
                learner_collective_seconds: 0.01,
                learner_apply_seconds: 0.02,
                learner_active_seconds: 0.15,
                learner_overlap_seconds: 0.0,
                queue_push_block_seconds: 0.0,
                queue_pop_block_seconds: 0.0,
                infer_calls: 40,
                grad_calls: 2,
                apply_calls: 2,
                env_step_calls: 40,
                pods_joined: 0,
                pods_evicted: 0,
                membership_epoch: 0,
                join_param_version: 0,
                final_opt_state: vec![3.0],
            }),
        }
    }

    #[test]
    fn summary_is_arch_labelled() {
        let s = sebulba_report().summary();
        assert!(s.starts_with("sebulba: frames=1280"), "{s}");
        assert!(s.contains("fps=2560"), "{s}");
        assert!(s.contains("learner pipeline:"), "{s}");
    }

    #[test]
    fn to_json_has_stable_names_and_params_digest() {
        let r = sebulba_report();
        let j = r.to_json();
        assert_eq!(j.get("arch").unwrap().as_str(), Some("sebulba"));
        assert_eq!(j.get("steps").unwrap().as_usize(), Some(1280));
        assert_eq!(j.get("final_params_len").unwrap().as_usize(), Some(2));
        assert_eq!(
            j.get("final_params_crc32").unwrap().as_f64(),
            Some(r.final_params_crc32() as f64)
        );
        let d = j.get("detail").unwrap();
        assert_eq!(d.get("kind").unwrap().as_str(), Some("actor_learner"));
        // the per-stage seconds the planner folds must be present by name
        for key in [
            "actor_infer_seconds",
            "actor_env_step_seconds",
            "learner_grad_seconds",
            "learner_collective_seconds",
            "learner_apply_seconds",
            "infer_calls",
            "grad_calls",
        ] {
            assert!(d.get(key).is_some(), "missing {key}");
        }
        // serialized form must parse back (canonical writer round-trip)
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn params_digest_tracks_bit_identity() {
        let a = sebulba_report();
        let mut b = sebulba_report();
        assert_eq!(a.final_params_crc32(), b.final_params_crc32());
        b.final_params[0] = f32::from_bits(a.final_params[0].to_bits() ^ 1);
        assert_ne!(a.final_params_crc32(), b.final_params_crc32());
    }

    #[test]
    fn accessors_match_the_detail_variant() {
        let r = sebulba_report();
        assert!(r.as_actor_learner().is_some());
        assert!(r.as_anakin().is_none());
        let (params, opt) = r.into_warm_start().unwrap();
        assert_eq!(params, vec![1.0, 2.0]);
        assert_eq!(opt, vec![3.0]);
    }
}
