//! The `Runner` trait: one contract over Anakin, Sebulba and MuZero.
//!
//! A runner is a *workload*: everything about a run that is not the core
//! split (agent tag, environment, batch geometry, seed, update budget).
//! The split itself arrives as a [`Topology`] at run time, so one workload
//! value can be swept across topologies — which is exactly what the benches
//! do — and `Experiment` can treat all three architectures uniformly
//! through `Box<dyn Runner>`.

use std::path::PathBuf;

use anyhow::Result;

use crate::checkpoint::CheckpointSpec;
use crate::runtime::Pod;
use crate::testkit::FaultPlan;

use super::{Arch, Report, Topology};

/// Per-run elasticity knobs (DESIGN.md §13): periodic checkpointing, a
/// restore source, and the injectable fault plan the resilience tests use.
/// `RunSpec::default()` is a plain uninterrupted run — the historical
/// behaviour of [`Runner::run`].
#[derive(Clone, Debug, Default)]
pub struct RunSpec {
    /// Write a checkpoint every N update rounds (None = never).
    pub checkpoint: Option<CheckpointSpec>,
    /// Resume from this checkpoint file instead of initializing fresh.
    /// The update budget stays absolute: a workload configured for
    /// `updates(N)` runs until N *total* rounds, counting the restored ones.
    pub restore_from: Option<PathBuf>,
    /// Scheduled faults (tests only; None on production paths).
    pub fault: Option<FaultPlan>,
}

impl RunSpec {
    /// True if this spec changes nothing about a plain run.
    pub fn is_plain(&self) -> bool {
        self.checkpoint.is_none()
            && self.restore_from.is_none()
            && self.fault.as_ref().map_or(true, |f| f.is_empty())
    }
}

/// Contract: `run` validates `topo` against the pod (`topo.total_cores()
/// <= pod.n_cores()`), loads its programs, executes to the configured
/// update budget and returns a [`Report`] whose `detail` variant matches
/// `self.arch()`. Runs with equal workload + topology + seed on equal
/// artifacts are deterministic wherever the architecture itself is
/// (Anakin: bit-exact; Sebulba/MuZero: up to actor/learner interleaving —
/// see DESIGN.md §12).
///
/// With a non-plain [`RunSpec`] the run additionally honours the
/// elasticity contract (DESIGN.md §13): checkpoints are written atomically
/// every `checkpoint.every` rounds, a restore resumes the *exact* state of
/// the checkpointed run, and K updates + restore + K more updates produce
/// `final_params` bit-identical to an uninterrupted 2K-update run.
pub trait Runner: Send + Sync {
    fn arch(&self) -> Arch;

    /// Execute with elasticity knobs. This is the required entry point;
    /// implementations must honour every field of `spec` or reject the
    /// combination with a typed error — never silently ignore a knob.
    fn run_checkpointed(&self, pod: &mut Pod, topo: &Topology, spec: &RunSpec) -> Result<Report>;

    /// A plain uninterrupted run (the historical contract).
    fn run(&self, pod: &mut Pod, topo: &Topology) -> Result<Report> {
        self.run_checkpointed(pod, topo, &RunSpec::default())
    }
}
