//! The `Runner` trait: one contract over Anakin, Sebulba and MuZero.
//!
//! A runner is a *workload*: everything about a run that is not the core
//! split (agent tag, environment, batch geometry, seed, update budget).
//! The split itself arrives as a [`Topology`] at run time, so one workload
//! value can be swept across topologies — which is exactly what the benches
//! do — and `Experiment` can treat all three architectures uniformly
//! through `Box<dyn Runner>`.

use anyhow::Result;

use crate::runtime::Pod;

use super::{Arch, Report, Topology};

/// Contract: `run` validates `topo` against the pod (`topo.total_cores()
/// <= pod.n_cores()`), loads its programs, executes to the configured
/// update budget and returns a [`Report`] whose `detail` variant matches
/// `self.arch()`. Runs with equal workload + topology + seed on equal
/// artifacts are deterministic wherever the architecture itself is
/// (Anakin: bit-exact; Sebulba/MuZero: up to actor/learner interleaving —
/// see DESIGN.md §12).
pub trait Runner: Send + Sync {
    fn arch(&self) -> Arch;

    fn run(&self, pod: &mut Pod, topo: &Topology) -> Result<Report>;
}
