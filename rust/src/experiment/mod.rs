//! # The one Podracer API
//!
//! The paper presents Anakin and Sebulba as two instances of a single idea
//! — a declarative split of pod cores between acting and learning — and
//! this module is that idea as an API (DESIGN.md §12). One builder reaches
//! every architecture:
//!
//! ```no_run
//! use podracer::experiment::{Arch, EnvKind, Experiment, Topology};
//!
//! let report = Experiment::new(Arch::Sebulba)
//!     .agent("seb_catch")
//!     .env(EnvKind::Catch)
//!     .topology(Topology::split(2, 2))
//!     .updates(200)
//!     .seed(42)
//!     .build()?
//!     .run()?;
//! println!("{}", report.summary());
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! * [`Topology`] — the typed core split (cores, replicas, pipeline
//!   depths), shared by all architectures.
//! * [`EnvKind`] — typed host environments; unknown names are parse
//!   errors, never silent defaults.
//! * [`Runner`] — the trait Anakin, Sebulba and MuZero implement; an
//!   [`Experiment`] is a validated `(runner, topology, artifacts)` triple.
//! * [`Report`] — the unified run report with a per-architecture
//!   [`Detail`] payload.
//!
//! The pre-refactor entrypoints (`Anakin::run`, `Sebulba::run_on_with`,
//! `run_muzero`) are gone — their one-PR deprecation window closed;
//! everything goes through `Experiment`. The serving frontend is not an
//! `Arch` (it trains nothing and has no topology split); `podracer serve`
//! parses through [`serve_from_args`] with the same hard-error flag
//! discipline.

pub mod env_kind;
pub mod report;
pub mod runner;
pub mod topology;

use std::fmt;
use std::path::{Path, PathBuf};
use std::str::FromStr;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::anakin::{Anakin, Driver, Mode};
use crate::checkpoint::CheckpointSpec;
use crate::coordinator::sebulba::Sebulba;
use crate::runtime::Pod;
use crate::search::muzero_run::MuZero;
use crate::testkit::FaultPlan;
use crate::transport::DistSebulba;
use crate::util::cli::Args;

pub use env_kind::EnvKind;
pub use report::{ActorLearnerDetail, AnakinDetail, Detail, MetricRow, Report};
pub use runner::{RunSpec, Runner};
pub use topology::{PodRole, Topology, ONE_POD};

/// The three Podracer architectures.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Arch {
    /// Fully on-device online learning (paper Fig. 1b / Fig. 2).
    Anakin,
    /// Decomposed actor/learner coordination (paper Fig. 1c / Fig. 3).
    Sebulba,
    /// Sebulba with MCTS actors driving a learned model.
    MuZero,
}

impl Arch {
    pub const ALL: [Arch; 3] = [Arch::Anakin, Arch::Sebulba, Arch::MuZero];

    pub fn as_str(self) -> &'static str {
        match self {
            Arch::Anakin => "anakin",
            Arch::Sebulba => "sebulba",
            Arch::MuZero => "muzero",
        }
    }
}

impl fmt::Display for Arch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for Arch {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        for arch in Self::ALL {
            if arch.as_str() == s {
                return Ok(arch);
            }
        }
        bail!("unknown architecture {s:?} (valid: anakin, sebulba, muzero)")
    }
}

/// A validated, runnable experiment: a [`Runner`] workload plus the
/// [`Topology`] it runs on and the artifacts it loads programs from.
pub struct Experiment {
    arch: Arch,
    topo: Topology,
    /// Which slice of the topology this process runs (DESIGN.md §15).
    /// `Colocated` (the default) is the single-process experiment.
    role: PodRole,
    artifacts: PathBuf,
    runner: Box<dyn Runner>,
    spec: RunSpec,
}

impl Experiment {
    /// Start describing an experiment for `arch`. Finish with
    /// [`ExperimentBuilder::build`].
    #[allow(clippy::new_ret_no_self)] // the builder entrypoint is the API's front door
    pub fn new(arch: Arch) -> ExperimentBuilder {
        ExperimentBuilder::new(arch)
    }

    /// Declarative CLI construction: `podracer <arch> [--flags]` with no
    /// per-architecture code at the call site. Unknown flag *names* and
    /// unknown flag *values* (`--env`, `--mode`, `--driver`, `--data-path`)
    /// are hard errors.
    pub fn from_args(arch: Arch, args: &Args) -> Result<Experiment> {
        from_args::build(arch, args)
    }

    pub fn arch(&self) -> Arch {
        self.arch
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Which slice of the topology this process runs.
    pub fn role(&self) -> PodRole {
        self.role
    }

    /// Build a pod sized for this process's role and run to completion.
    /// A colocated run allocates the whole topology; a learner or actor
    /// pod allocates only its slice (DESIGN.md §15).
    pub fn run(&self) -> Result<Report> {
        let mut pod = Pod::new(&self.artifacts, self.topo.cores_for_role(self.role))?;
        self.runner.run_checkpointed(&mut pod, &self.topo, &self.spec)
    }

    /// Run on an existing pod (must have >= `topology().total_cores()`
    /// cores) — reuses loaded programs across runs.
    pub fn run_on(&self, pod: &mut Pod) -> Result<Report> {
        self.runner.run_checkpointed(pod, &self.topo, &self.spec)
    }
}

/// Builder for [`Experiment`]. Generic knobs (`agent`, `env`, `topology`,
/// `seed`, `updates`) apply everywhere they make sense; architecture-
/// specific knobs (`mode`/`driver` for Anakin, `actor_batch`/`unroll`/
/// `micro_batches`/`copy_path`/`warm_start` for Sebulba, `num_simulations`
/// for MuZero) are rejected by [`Self::build`] when set for the wrong
/// architecture — a typo'd experiment fails loudly instead of silently
/// ignoring a knob.
pub struct ExperimentBuilder {
    arch: Arch,
    artifacts: Option<PathBuf>,
    agent: Option<String>,
    env: Option<EnvKind>,
    topo: Option<Topology>,
    seed: Option<u64>,
    updates: Option<u64>,
    mode: Option<Mode>,
    driver: Option<Driver>,
    actor_batch: Option<usize>,
    unroll: Option<usize>,
    micro_batches: Option<usize>,
    discount: Option<f32>,
    copy_path: Option<bool>,
    num_simulations: Option<usize>,
    warm_start: Option<(Vec<f32>, Vec<f32>)>,
    checkpoint_every: Option<u64>,
    checkpoint_path: Option<PathBuf>,
    restore_from: Option<PathBuf>,
    fault: Option<FaultPlan>,
    role: Option<PodRole>,
    listen: Option<String>,
    connect: Option<String>,
    elastic: Option<bool>,
    min_actor_pods: Option<usize>,
    heartbeat_ms: Option<u64>,
}

impl ExperimentBuilder {
    fn new(arch: Arch) -> Self {
        Self {
            arch,
            artifacts: None,
            agent: None,
            env: None,
            topo: None,
            seed: None,
            updates: None,
            mode: None,
            driver: None,
            actor_batch: None,
            unroll: None,
            micro_batches: None,
            discount: None,
            copy_path: None,
            num_simulations: None,
            warm_start: None,
            checkpoint_every: None,
            checkpoint_path: None,
            restore_from: None,
            fault: None,
            role: None,
            listen: None,
            connect: None,
            elastic: None,
            min_actor_pods: None,
            heartbeat_ms: None,
        }
    }

    /// Artifacts directory (default: [`crate::artifacts_dir`]).
    pub fn artifacts(mut self, dir: &Path) -> Self {
        self.artifacts = Some(dir.to_path_buf());
        self
    }

    /// Agent tag in the artifact manifest (defaults: `anakin_catch`,
    /// `seb_catch`, `mz_catch`).
    pub fn agent(mut self, tag: &str) -> Self {
        self.agent = Some(tag.to_string());
        self
    }

    /// Host environment (Sebulba/MuZero; Anakin's env is baked into the
    /// agent program).
    pub fn env(mut self, kind: EnvKind) -> Self {
        self.env = Some(kind);
        self
    }

    pub fn topology(mut self, topo: Topology) -> Self {
        self.topo = Some(topo);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Update budget: learner updates per replica (Sebulba/MuZero) or
    /// outer driver iterations (Anakin).
    pub fn updates(mut self, updates: u64) -> Self {
        self.updates = Some(updates);
        self
    }

    /// Anakin collective mode (bundled | psum).
    pub fn mode(mut self, mode: Mode) -> Self {
        self.mode = Some(mode);
        self
    }

    /// Anakin host schedule (threaded | serial).
    pub fn driver(mut self, driver: Driver) -> Self {
        self.driver = Some(driver);
        self
    }

    /// Environments per Sebulba actor thread (Fig 4b's actor batch).
    pub fn actor_batch(mut self, batch: usize) -> Self {
        self.actor_batch = Some(batch);
        self
    }

    /// Trajectory length T (Sebulba).
    pub fn unroll(mut self, unroll: usize) -> Self {
        self.unroll = Some(unroll);
        self
    }

    /// Sequential updates per trajectory (Sebulba).
    pub fn micro_batches(mut self, n: usize) -> Self {
        self.micro_batches = Some(n);
        self
    }

    pub fn discount(mut self, discount: f32) -> Self {
        self.discount = Some(discount);
        self
    }

    /// Use the materializing data path instead of zero-copy arena views
    /// (Sebulba bit-exactness oracle — DESIGN.md §11).
    pub fn copy_path(mut self, copy: bool) -> Self {
        self.copy_path = Some(copy);
        self
    }

    /// MCTS simulations per step (MuZero).
    pub fn num_simulations(mut self, n: usize) -> Self {
        self.num_simulations = Some(n);
        self
    }

    /// Warm-start from a previous run's `(params, opt_state)` (Sebulba) —
    /// lets drivers stage long trainings, see `examples/sebulba_atari.rs`.
    pub fn warm_start(mut self, params: Vec<f32>, opt_state: Vec<f32>) -> Self {
        self.warm_start = Some((params, opt_state));
        self
    }

    /// Write a checkpoint every `n` learner updates (Sebulba/MuZero) or
    /// outer iterations (Anakin). Applies to every architecture; the file
    /// lands at [`Self::checkpoint_path`] (default `podracer.ckpt`).
    /// Checkpointed Sebulba/MuZero runs execute in lockstep (one window per
    /// update) so the saved state is a consistent cut — see DESIGN.md §13.
    pub fn checkpoint_every(mut self, n: u64) -> Self {
        self.checkpoint_every = Some(n);
        self
    }

    /// Where [`Self::checkpoint_every`] writes its checkpoint. Setting a
    /// path without a cadence is a build error, never a silent no-op.
    pub fn checkpoint_path(mut self, path: &Path) -> Self {
        self.checkpoint_path = Some(path.to_path_buf());
        self
    }

    /// Resume from a checkpoint written by an earlier run. The update
    /// budget stays absolute: `.updates(2 * K).restore_from(k_ckpt)` runs
    /// K more updates on top of the K already in the file.
    pub fn restore_from(mut self, path: &Path) -> Self {
        self.restore_from = Some(path.to_path_buf());
        self
    }

    /// Inject scheduled faults (kill a replica, poison a queue, truncate
    /// the checkpoint file) — resilience tests only, see `testkit`.
    pub fn fault(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }

    /// Which slice of a multi-pod topology this process runs (Sebulba
    /// only). `Learner` requires [`Self::listen`]; `Actor` requires
    /// [`Self::connect`]; the default `Colocated` is the single-process
    /// experiment (DESIGN.md §15).
    pub fn role(mut self, role: PodRole) -> Self {
        self.role = Some(role);
        self
    }

    /// Address the learner pod binds for actor-pod connections, e.g.
    /// `127.0.0.1:7777` (`0` picks a free port).
    pub fn listen(mut self, addr: &str) -> Self {
        self.listen = Some(addr.to_string());
        self
    }

    /// Learner-pod address an actor pod dials, e.g. `127.0.0.1:7777`.
    pub fn connect(mut self, addr: &str) -> Self {
        self.connect = Some(addr.to_string());
        self
    }

    /// Epoch-based elastic membership (distributed Sebulba only,
    /// DESIGN.md §16): the learner admits actor pods whenever they join
    /// and tolerates departures down to [`Self::min_actor_pods`].
    pub fn elastic(mut self, on: bool) -> Self {
        self.elastic = Some(on);
        self
    }

    /// Elastic learner: fail closed when active membership drops below
    /// this floor (requires [`Self::elastic`]).
    pub fn min_actor_pods(mut self, n: usize) -> Self {
        self.min_actor_pods = Some(n);
        self
    }

    /// Elastic heartbeat window in milliseconds: actors beacon at a third
    /// of it, the learner evicts after a full silent window (requires
    /// [`Self::elastic`]).
    pub fn heartbeat_ms(mut self, ms: u64) -> Self {
        self.heartbeat_ms = Some(ms);
        self
    }

    /// Reject knobs that were set but mean nothing for `arch`.
    fn reject_inapplicable(&self, knobs: &[(&str, bool)]) -> Result<()> {
        for (name, set) in knobs {
            if *set {
                bail!("`{name}` does not apply to the {} architecture", self.arch);
            }
        }
        Ok(())
    }

    /// Validate and assemble the experiment.
    pub fn build(self) -> Result<Experiment> {
        let arch = self.arch;
        let artifacts = match &self.artifacts {
            Some(p) => p.clone(),
            None => crate::artifacts_dir(),
        };
        if self.checkpoint_path.is_some() && self.checkpoint_every.is_none() {
            bail!(
                "`checkpoint_path` without `checkpoint_every` would never write \
                 a checkpoint; set both or neither"
            );
        }
        if self.checkpoint_every == Some(0) {
            bail!("`checkpoint_every` expects a positive round count, got 0");
        }
        let spec = RunSpec {
            checkpoint: self.checkpoint_every.map(|every| {
                CheckpointSpec::new(
                    every,
                    self.checkpoint_path
                        .clone()
                        .unwrap_or_else(|| PathBuf::from("podracer.ckpt")),
                )
            }),
            restore_from: self.restore_from.clone(),
            fault: self.fault.clone(),
        };
        let role = self.role.unwrap_or_default();
        let (topo, runner): (Topology, Box<dyn Runner>) = match arch {
            Arch::Anakin => {
                self.reject_inapplicable(&[
                    ("env", self.env.is_some()),
                    ("actor_batch", self.actor_batch.is_some()),
                    ("unroll", self.unroll.is_some()),
                    ("micro_batches", self.micro_batches.is_some()),
                    ("discount", self.discount.is_some()),
                    ("copy_path", self.copy_path.is_some()),
                    ("num_simulations", self.num_simulations.is_some()),
                    ("warm_start", self.warm_start.is_some()),
                    ("role", self.role.is_some()),
                    ("listen", self.listen.is_some()),
                    ("connect", self.connect.is_some()),
                    ("elastic", self.elastic.is_some()),
                    ("min_actor_pods", self.min_actor_pods.is_some()),
                    ("heartbeat_ms", self.heartbeat_ms.is_some()),
                ])?;
                let defaults = Anakin::default();
                let topo = self.topo.unwrap_or_else(|| Topology::anakin(2));
                let runner = Anakin {
                    agent: self.agent.unwrap_or(defaults.agent),
                    mode: self.mode.unwrap_or(defaults.mode),
                    driver: self.driver.unwrap_or(defaults.driver),
                    outer_iters: self.updates.unwrap_or(defaults.outer_iters),
                    seed: self.seed.unwrap_or(defaults.seed),
                };
                Anakin::check_topology(&topo)?;
                topo.validate()?;
                if topo.pods.get() > 1 {
                    bail!("the anakin architecture is single-pod; --pods applies to sebulba only");
                }
                (topo, Box::new(runner))
            }
            Arch::Sebulba => {
                self.reject_inapplicable(&[
                    ("mode", self.mode.is_some()),
                    ("driver", self.driver.is_some()),
                    ("num_simulations", self.num_simulations.is_some()),
                ])?;
                let defaults = Sebulba::default();
                let topo = self.topo.unwrap_or_default();
                let runner = Sebulba {
                    agent: self.agent.unwrap_or(defaults.agent),
                    env_kind: self.env.unwrap_or(defaults.env_kind),
                    actor_batch: self.actor_batch.unwrap_or(defaults.actor_batch),
                    unroll: self.unroll.unwrap_or(defaults.unroll),
                    micro_batches: self.micro_batches.unwrap_or(defaults.micro_batches),
                    discount: self.discount.unwrap_or(defaults.discount),
                    total_updates: self.updates.unwrap_or(defaults.total_updates),
                    seed: self.seed.unwrap_or(defaults.seed),
                    copy_path: self.copy_path.unwrap_or(defaults.copy_path),
                    warm_start: self.warm_start,
                };
                runner.resolved(&topo).validate()?;
                let elastic = self.elastic.unwrap_or(false);
                if !elastic && (self.min_actor_pods.is_some() || self.heartbeat_ms.is_some()) {
                    bail!(
                        "`min_actor_pods`/`heartbeat_ms` configure elastic membership; \
                         add `--elastic`"
                    );
                }
                if elastic && role == PodRole::Colocated {
                    bail!(
                        "`elastic` needs a distributed role; add `--role learner` or \
                         `--role actor`"
                    );
                }
                let min_actor_pods = self.min_actor_pods.unwrap_or(1);
                let heartbeat_ms = self.heartbeat_ms.unwrap_or(1000);
                if elastic {
                    if heartbeat_ms == 0 {
                        bail!("`heartbeat_ms` must be at least 1");
                    }
                    if min_actor_pods == 0 {
                        bail!("`min_actor_pods` must be at least 1");
                    }
                    let actor_pods = topo.pods.get().saturating_sub(1);
                    if min_actor_pods > actor_pods {
                        bail!(
                            "min_actor_pods = {} but the topology provisions {} actor \
                             pod(s); the floor must be reachable at start-up",
                            min_actor_pods,
                            actor_pods
                        );
                    }
                }
                // Pod-level fault plans ride only on elastic distributed
                // runs; everything else distributed must stay plain.
                let dist_fault_ok = spec.fault.as_ref().map_or(true, |f| {
                    f.is_empty() || (elastic && f.pod_faults_only())
                });
                let runner: Box<dyn Runner> = match role {
                    PodRole::Colocated => {
                        if spec.fault.as_ref().map_or(false, |f| f.has_pod_faults()) {
                            bail!(
                                "pod-level fault plans need an elastic distributed run; \
                                 add `--elastic` and a distributed role"
                            );
                        }
                        if self.listen.is_some() || self.connect.is_some() {
                            bail!(
                                "`listen`/`connect` need a distributed role; add \
                                 `--role learner` or `--role actor`"
                            );
                        }
                        if topo.pods.get() > 1 {
                            bail!(
                                "pods = {} but role = colocated; a multi-pod topology \
                                 needs `--role learner` (one process) and `--role actor` \
                                 (the others)",
                                topo.pods
                            );
                        }
                        Box::new(runner)
                    }
                    PodRole::Learner => {
                        if self.connect.is_some() {
                            bail!("the learner role listens; `connect` is for actor pods");
                        }
                        let listen = match &self.listen {
                            Some(addr) => addr.clone(),
                            None => bail!("role = learner requires a `listen` address"),
                        };
                        if topo.pods.get() < 2 {
                            bail!(
                                "a distributed role needs pods >= 2 (1 learner + N actor \
                                 pods), got pods = {}",
                                topo.pods
                            );
                        }
                        if spec.checkpoint.is_some()
                            || spec.restore_from.is_some()
                            || !dist_fault_ok
                        {
                            bail!(
                                "distributed runs do not support checkpoint/restore/fault \
                                 injection beyond pod-level fault plans on elastic runs"
                            );
                        }
                        let mut dist =
                            DistSebulba::learner(runner, &listen, topo.pods.get() - 1);
                        if elastic {
                            dist = dist.with_elastic(
                                min_actor_pods,
                                Duration::from_millis(heartbeat_ms),
                            );
                        }
                        Box::new(dist)
                    }
                    PodRole::Actor => {
                        if self.listen.is_some() {
                            bail!("the actor role dials out; `listen` is for the learner pod");
                        }
                        let connect = match &self.connect {
                            Some(addr) => addr.clone(),
                            None => bail!("role = actor requires a `connect` address"),
                        };
                        if topo.pods.get() < 2 {
                            bail!(
                                "a distributed role needs pods >= 2 (1 learner + N actor \
                                 pods), got pods = {}",
                                topo.pods
                            );
                        }
                        if spec.checkpoint.is_some()
                            || spec.restore_from.is_some()
                            || !dist_fault_ok
                        {
                            bail!(
                                "distributed runs do not support checkpoint/restore/fault \
                                 injection beyond pod-level fault plans on elastic runs"
                            );
                        }
                        let mut dist = DistSebulba::actor(runner, &connect);
                        if elastic {
                            dist = dist.with_elastic(
                                min_actor_pods,
                                Duration::from_millis(heartbeat_ms),
                            );
                        }
                        Box::new(dist)
                    }
                };
                (topo, runner)
            }
            Arch::MuZero => {
                self.reject_inapplicable(&[
                    ("mode", self.mode.is_some()),
                    ("driver", self.driver.is_some()),
                    ("actor_batch", self.actor_batch.is_some()),
                    ("unroll", self.unroll.is_some()),
                    ("micro_batches", self.micro_batches.is_some()),
                    ("copy_path", self.copy_path.is_some()),
                    ("warm_start", self.warm_start.is_some()),
                    ("role", self.role.is_some()),
                    ("listen", self.listen.is_some()),
                    ("connect", self.connect.is_some()),
                    ("elastic", self.elastic.is_some()),
                    ("min_actor_pods", self.min_actor_pods.is_some()),
                    ("heartbeat_ms", self.heartbeat_ms.is_some()),
                ])?;
                let defaults = MuZero::default();
                let topo = self.topo.unwrap_or_else(|| Topology {
                    threads_per_actor_core: 1,
                    pipeline_stages: 1,
                    learner_pipeline: 1,
                    ..Topology::default()
                });
                let runner = MuZero {
                    agent: self.agent.unwrap_or(defaults.agent),
                    env_kind: self.env.unwrap_or(defaults.env_kind),
                    num_simulations: self.num_simulations.unwrap_or(defaults.num_simulations),
                    discount: self.discount.unwrap_or(defaults.discount),
                    total_updates: self.updates.unwrap_or(defaults.total_updates),
                    seed: self.seed.unwrap_or(defaults.seed),
                };
                // validate the topology as given, not the one `resolved`
                // re-derives — a non-1 pipeline_stages is an error, never
                // silently 1
                topo.validate()?;
                MuZero::check_topology(&topo)?;
                runner.resolved(&topo).validate()?;
                if topo.pods.get() > 1 {
                    bail!("the muzero architecture is single-pod; --pods applies to sebulba only");
                }
                (topo, Box::new(runner))
            }
        };
        Ok(Experiment { arch, topo, role, artifacts, runner, spec })
    }
}

mod from_args {
    use std::num::NonZeroUsize;

    use super::*;

    /// Parse `--listen`/`--connect`: a bare flag (which the CLI layer
    /// renders as the value `"true"`) is a hard error, never a default.
    fn addr_flag(args: &Args, key: &str) -> Result<Option<String>> {
        if !args.has(key) {
            return Ok(None);
        }
        let addr = args.get_str(key, "");
        if addr.is_empty() || addr == "true" {
            bail!("--{key} expects an address like 127.0.0.1:7777");
        }
        Ok(Some(addr))
    }

    const ANAKIN_FLAGS: &[&str] = &[
        "agent",
        "cores",
        "outer-iters",
        "mode",
        "driver",
        "seed",
        "checkpoint-every",
        "checkpoint-path",
        "restore",
        "topology",
        "pod-cores",
        "cost-model",
        "report-json",
    ];
    const SEBULBA_FLAGS: &[&str] = &[
        "agent",
        "env",
        "actor-cores",
        "learner-cores",
        "threads",
        "batch",
        "pipeline-stages",
        "learner-pipeline",
        "unroll",
        "micro-batches",
        "discount",
        "queue",
        "env-workers",
        "replicas",
        "updates",
        "seed",
        "data-path",
        "checkpoint-every",
        "checkpoint-path",
        "restore",
        "pods",
        "role",
        "listen",
        "connect",
        "elastic",
        "min-actor-pods",
        "heartbeat-ms",
        "topology",
        "pod-cores",
        "cost-model",
        "report-json",
    ];
    const MUZERO_FLAGS: &[&str] = &[
        "agent",
        "env",
        "actor-cores",
        "learner-cores",
        "threads",
        "simulations",
        "learner-pipeline",
        "discount",
        "queue",
        "env-workers",
        "replicas",
        "updates",
        "seed",
        "checkpoint-every",
        "checkpoint-path",
        "restore",
        "topology",
        "pod-cores",
        "cost-model",
        "report-json",
    ];

    fn check_flags(cmd: &str, args: &Args, accepted: &[&str]) -> Result<()> {
        for key in args.flags.keys() {
            if !accepted.contains(&key.as_str()) {
                bail!(
                    "unknown flag --{key} for `podracer {cmd}` (accepted: {})",
                    accepted.iter().map(|f| format!("--{f}")).collect::<Vec<_>>().join(" ")
                );
            }
        }
        Ok(())
    }

    /// Parse a typed flag value, naming the flag in the error.
    fn parse_flag<T>(args: &Args, key: &str, default: &str) -> Result<T>
    where
        T: FromStr<Err = anyhow::Error>,
    {
        let raw = args.get_str(key, default);
        raw.parse::<T>().with_context(|| format!("--{key} {raw:?}"))
    }

    /// Apply the elasticity flags shared by every arch:
    /// `--checkpoint-every N [--checkpoint-path P]` and `--restore P`.
    fn apply_elasticity(mut b: ExperimentBuilder, args: &Args) -> Result<ExperimentBuilder> {
        if args.has("checkpoint-every") {
            let every = args.get_u64("checkpoint-every", 0)?;
            if every == 0 {
                bail!("--checkpoint-every expects a positive round count");
            }
            b = b.checkpoint_every(every);
        }
        if args.has("checkpoint-path") {
            b = b.checkpoint_path(Path::new(&args.get_str("checkpoint-path", "")));
        }
        if args.has("restore") {
            let path = args.get_str("restore", "");
            // a bare `--restore` parses as the value "true"
            if path.is_empty() || path == "true" {
                bail!("--restore expects a checkpoint path");
            }
            b = b.restore_from(Path::new(&path));
        }
        Ok(b)
    }

    /// Parse `--topology auto [--pod-cores N] [--cost-model P]` into a
    /// planned [`Topology`], or `None` when the run is explicitly shaped.
    /// Every conflict is a hard error: `--topology` accepts only `auto`,
    /// the split knobs may not be mixed with it (the planner owns the
    /// split), and the planner knobs mean nothing without it.
    fn auto_topology(arch: Arch, args: &Args) -> Result<Option<Topology>> {
        if !args.has("topology") {
            for key in ["pod-cores", "cost-model"] {
                if args.has(key) {
                    bail!("--{key} only applies with --topology auto");
                }
            }
            return Ok(None);
        }
        let value = args.get_str("topology", "");
        if value != "auto" {
            bail!(
                "--topology expects `auto`, got {value:?} (explicit shapes use the \
                 split flags instead)"
            );
        }
        let conflicting: &[&str] = match arch {
            Arch::Anakin => &["cores"],
            Arch::Sebulba => &[
                "actor-cores",
                "learner-cores",
                "threads",
                "pipeline-stages",
                "learner-pipeline",
                "replicas",
                "env-workers",
                "queue",
                "pods",
                "role",
                "listen",
                "connect",
                "elastic",
                "min-actor-pods",
                "heartbeat-ms",
            ],
            Arch::MuZero => &[
                "actor-cores",
                "learner-cores",
                "threads",
                "learner-pipeline",
                "replicas",
                "env-workers",
                "queue",
            ],
        };
        for key in conflicting {
            if args.has(key) {
                bail!("--{key} conflicts with --topology auto (the planner owns the split)");
            }
        }
        let pod_cores = args.get_usize("pod-cores", 4)?;
        if pod_cores == 0 {
            bail!("--pod-cores expects a positive core count");
        }
        let model_path = args
            .flags
            .get("cost-model")
            .map(PathBuf::from)
            .unwrap_or_else(|| crate::artifacts_dir().join("cost_model.json"));
        let model = crate::plan::CostModel::load(&model_path).with_context(|| {
            format!(
                "loading cost model {} for --topology auto (bootstrap one with \
                 `podracer plan --calibrate` or `make bench-smoke`)",
                model_path.display()
            )
        })?;
        let mut req = crate::plan::PlanRequest::new(arch, pod_cores);
        match arch {
            Arch::Anakin => {
                req.agent = args.get_str("agent", "anakin_catch");
                // Anakin's env is baked into the fused agent program; the
                // cost cell's env label follows the agent tag.
                req.env =
                    if req.agent.contains("grid") { "gridworld" } else { "catch" }.to_string();
            }
            Arch::Sebulba => {
                req.agent = args.get_str("agent", "seb_catch");
                req.env = parse_flag::<EnvKind>(args, "env", "catch")?.as_str().to_string();
                req.actor_batch = args.get_usize("batch", 32)?;
                req.unroll = args.get_usize("unroll", 20)?;
                req.micro_batches = args.get_usize("micro-batches", 1)?;
            }
            Arch::MuZero => {
                req.agent = args.get_str("agent", "mz_catch");
                req.env = parse_flag::<EnvKind>(args, "env", "catch")?.as_str().to_string();
            }
        }
        Ok(Some(Topology::auto_for(&req, &model)?))
    }

    pub(super) fn build(arch: Arch, args: &Args) -> Result<Experiment> {
        match arch {
            Arch::Anakin => {
                check_flags(arch.as_str(), args, ANAKIN_FLAGS)?;
                let topo = match auto_topology(arch, args)? {
                    Some(t) => t,
                    None => Topology::anakin(args.get_usize("cores", 4)?),
                };
                let b = Experiment::new(arch)
                    .agent(&args.get_str("agent", "anakin_catch"))
                    .topology(topo)
                    .updates(args.get_u64("outer-iters", 20)?)
                    .mode(parse_flag(args, "mode", "bundled")?)
                    .driver(parse_flag(args, "driver", "threaded")?)
                    .seed(args.get_u64("seed", 7)?);
                apply_elasticity(b, args)?.build()
            }
            Arch::Sebulba => {
                check_flags(arch.as_str(), args, SEBULBA_FLAGS)?;
                let copy_path = match args.get_str("data-path", "arena").as_str() {
                    "arena" => false,
                    "copy" => true,
                    other => bail!("--data-path expects arena|copy, got {other:?}"),
                };
                let topo = match auto_topology(arch, args)? {
                    Some(t) => t,
                    None => {
                        let pods = NonZeroUsize::new(args.get_usize("pods", 1)?).ok_or_else(
                            || anyhow::anyhow!("--pods expects a positive pod count"),
                        )?;
                        Topology {
                            actor_cores: args.get_usize("actor-cores", 2)?,
                            learner_cores: args.get_usize("learner-cores", 2)?,
                            replicas: args.get_usize("replicas", 1)?,
                            threads_per_actor_core: args.get_usize("threads", 2)?,
                            pipeline_stages: args.get_usize("pipeline-stages", 2)?,
                            learner_pipeline: args.get_usize("learner-pipeline", 2)?,
                            env_workers: args.get_usize("env-workers", 2)?,
                            queue_capacity: args.get_usize("queue", 4)?,
                            pods,
                        }
                    }
                };
                let mut b = Experiment::new(arch)
                    .agent(&args.get_str("agent", "seb_catch"))
                    .env(parse_flag(args, "env", "catch")?)
                    .topology(topo)
                    .actor_batch(args.get_usize("batch", 32)?)
                    .unroll(args.get_usize("unroll", 20)?)
                    .micro_batches(args.get_usize("micro-batches", 1)?)
                    .discount(args.get_f64("discount", 0.99)? as f32)
                    .copy_path(copy_path)
                    .updates(args.get_u64("updates", 100)?)
                    .seed(args.get_u64("seed", 42)?);
                if args.has("role") {
                    b = b.role(parse_flag(args, "role", "colocated")?);
                }
                if let Some(addr) = addr_flag(args, "listen")? {
                    b = b.listen(&addr);
                }
                if let Some(addr) = addr_flag(args, "connect")? {
                    b = b.connect(&addr);
                }
                if args.has("elastic") {
                    // a bare `--elastic` parses as the value "true"
                    match args.get_str("elastic", "true").as_str() {
                        "true" => b = b.elastic(true),
                        "false" => b = b.elastic(false),
                        other => bail!("--elastic expects true|false, got {other:?}"),
                    }
                }
                if args.has("min-actor-pods") {
                    b = b.min_actor_pods(args.get_usize("min-actor-pods", 1)?);
                }
                if args.has("heartbeat-ms") {
                    b = b.heartbeat_ms(args.get_u64("heartbeat-ms", 1000)?);
                }
                apply_elasticity(b, args)?.build()
            }
            Arch::MuZero => {
                check_flags(arch.as_str(), args, MUZERO_FLAGS)?;
                let topo = match auto_topology(arch, args)? {
                    Some(t) => t,
                    None => Topology {
                        actor_cores: args.get_usize("actor-cores", 2)?,
                        learner_cores: args.get_usize("learner-cores", 2)?,
                        replicas: args.get_usize("replicas", 1)?,
                        threads_per_actor_core: args.get_usize("threads", 1)?,
                        pipeline_stages: 1,
                        learner_pipeline: args.get_usize("learner-pipeline", 1)?,
                        env_workers: args.get_usize("env-workers", 2)?,
                        queue_capacity: args.get_usize("queue", 4)?,
                        pods: ONE_POD,
                    },
                };
                let b = Experiment::new(arch)
                    .agent(&args.get_str("agent", "mz_catch"))
                    .env(parse_flag(args, "env", "catch")?)
                    .topology(topo)
                    .num_simulations(args.get_usize("simulations", 16)?)
                    .discount(args.get_f64("discount", 0.997)? as f32)
                    .updates(args.get_u64("updates", 20)?)
                    .seed(args.get_u64("seed", 11)?);
                apply_elasticity(b, args)?.build()
            }
        }
    }

    const SERVE_FLAGS: &[&str] = &[
        "agent",
        "env",
        "batch",
        "pipeline-stages",
        "queue",
        "sessions",
        "steps",
        "swap-every",
        "seed",
        "report-json",
    ];

    /// `podracer serve` flag parsing: same hard-error discipline as the
    /// training archs (unknown flags and unparseable values exit nonzero)
    /// and the same construction shape — a workload half
    /// ([`crate::serve::Serve`]) resolved against a core-split half
    /// ([`Topology`]), exactly like `Sebulba::resolved`/`MuZero::resolved`
    /// in [`ExperimentBuilder::build`].
    pub(super) fn build_serve(args: &Args) -> Result<crate::serve::ServeConfig> {
        check_flags("serve", args, SERVE_FLAGS)?;
        let defaults = crate::serve::ServeConfig::default();
        let topo = Topology {
            pipeline_stages: args.get_usize("pipeline-stages", defaults.pipeline_stages)?,
            queue_capacity: args.get_usize("queue", defaults.queue)?,
            ..defaults.topology()
        };
        let runner = crate::serve::Serve {
            agent: args.get_str("agent", &defaults.agent),
            env: parse_flag(args, "env", defaults.env.as_str())?,
            batch: args.get_usize("batch", defaults.batch)?,
            sessions: args.get_usize("sessions", defaults.sessions)?,
            steps: args.get_usize("steps", defaults.steps)?,
            swap_every: args.get_u64("swap-every", defaults.swap_every)?,
            seed: args.get_u64("seed", defaults.seed)?,
        };
        let cfg = runner.resolved(&topo);
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Parse `podracer serve` flags into a validated
/// [`ServeConfig`](crate::serve::ServeConfig) — the serving counterpart of
/// [`Experiment::from_args`].
pub fn serve_from_args(args: &Args) -> Result<crate::serve::ServeConfig> {
    from_args::build_serve(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn arch_roundtrips_and_rejects_unknowns() {
        for arch in Arch::ALL {
            assert_eq!(arch.as_str().parse::<Arch>().unwrap(), arch);
        }
        assert!("impala".parse::<Arch>().is_err());
    }

    #[test]
    fn builder_reaches_all_three_architectures() {
        for arch in Arch::ALL {
            let exp = Experiment::new(arch).build().unwrap();
            assert_eq!(exp.arch(), arch);
            assert!(exp.topology().total_cores() >= 1);
        }
    }

    #[test]
    fn builder_rejects_inapplicable_knobs() {
        let err =
            Experiment::new(Arch::Anakin).env(EnvKind::Gridworld).build().unwrap_err().to_string();
        assert!(err.contains("env") && err.contains("anakin"), "{err}");
        assert!(Experiment::new(Arch::Sebulba).mode(Mode::Psum).build().is_err());
        assert!(Experiment::new(Arch::MuZero).actor_batch(64).build().is_err());
        assert!(Experiment::new(Arch::MuZero).warm_start(vec![0.0], vec![0.0]).build().is_err());
    }

    #[test]
    fn builder_validates_the_resolved_config() {
        // 30 envs cannot shard over 4 learner cores — the same geometry
        // check SebulbaConfig::validate always made, now at build()
        assert!(Experiment::new(Arch::Sebulba)
            .topology(Topology::split(1, 4))
            .actor_batch(30)
            .build()
            .is_err());
        // structural topology failures surface too
        assert!(Experiment::new(Arch::Sebulba)
            .topology(Topology { learner_cores: 0, ..Topology::default() })
            .build()
            .is_err());
        assert!(Experiment::new(Arch::Anakin).topology(Topology::anakin(0)).build().is_err());
        // MuZero has no split-batch actor pipeline: a non-1 pipeline_stages
        // is a build error, never a silently dropped knob
        let err = Experiment::new(Arch::MuZero)
            .topology(Topology::split(2, 2)) // default pipeline_stages = 2
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("pipeline_stages"), "{err}");
        assert!(Experiment::new(Arch::MuZero)
            .topology(Topology { pipeline_stages: 0, ..Topology::split(2, 2) })
            .build()
            .is_err());
        // same contract for Anakin: a topology with host-pipeline knobs set
        // is rejected, not silently collapsed to the fused on-device loop
        let err = Experiment::new(Arch::Anakin)
            .topology(Topology::split(2, 2))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("Topology::anakin"), "{err}");
    }

    #[test]
    fn from_args_builds_each_arch_with_cli_defaults() {
        let exp = Experiment::from_args(Arch::Anakin, &parse(&["--cores", "2"])).unwrap();
        assert_eq!(exp.topology().total_cores(), 2);
        let exp = Experiment::from_args(Arch::Sebulba, &parse(&[])).unwrap();
        assert_eq!(exp.topology().total_cores(), 4);
        assert_eq!(exp.topology().pipeline_stages, 2);
        let exp = Experiment::from_args(Arch::MuZero, &parse(&["--replicas", "2"])).unwrap();
        assert_eq!(exp.topology().total_cores(), 8);
        assert_eq!(exp.topology().learner_pipeline, 1);
    }

    #[test]
    fn from_args_rejects_unknown_env_values() {
        // the old env_kind_static silently coerced this to "catch"
        let err = Experiment::from_args(Arch::Sebulba, &parse(&["--env", "nosuchenv"]))
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("nosuchenv") && msg.contains("catch"), "{msg}");
        assert!(Experiment::from_args(Arch::MuZero, &parse(&["--env", "pong"])).is_err());
    }

    #[test]
    fn from_args_rejects_unknown_mode_and_driver_values() {
        // the old --mode parse mapped anything non-psum to Bundled
        let err = Experiment::from_args(Arch::Anakin, &parse(&["--mode", "nosuchmode"]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("nosuchmode"), "{err:#}");
        assert!(Experiment::from_args(Arch::Anakin, &parse(&["--driver", "warp"])).is_err());
        assert!(Experiment::from_args(Arch::Sebulba, &parse(&["--data-path", "zip"])).is_err());
    }

    #[test]
    fn from_args_rejects_unknown_flag_names() {
        let err = Experiment::from_args(Arch::Sebulba, &parse(&["--batchsize", "64"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--batchsize") && err.contains("--batch"), "{err}");
        // arch-inapplicable flags are unknown for that arch
        assert!(Experiment::from_args(Arch::Anakin, &parse(&["--env", "catch"])).is_err());
        assert!(Experiment::from_args(Arch::Sebulba, &parse(&["--simulations", "4"])).is_err());
    }

    #[test]
    fn topology_auto_flag_conflicts_hard_error() {
        // --topology accepts only `auto`
        let err = Experiment::from_args(Arch::Sebulba, &parse(&["--topology", "manual"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("auto"), "{err}");
        // explicit split knobs conflict with the planner owning the split
        for (arch, knob) in [
            (Arch::Sebulba, "--actor-cores"),
            (Arch::Sebulba, "--pods"),
            (Arch::MuZero, "--learner-cores"),
            (Arch::Anakin, "--cores"),
        ] {
            let err = Experiment::from_args(arch, &parse(&["--topology", "auto", knob, "2"]))
                .unwrap_err()
                .to_string();
            assert!(err.contains("conflicts with --topology auto"), "{arch}: {err}");
        }
        // planner knobs without --topology auto are rejected, never ignored
        for knob in ["--pod-cores", "--cost-model"] {
            let err = Experiment::from_args(Arch::Sebulba, &parse(&[knob, "4"]))
                .unwrap_err()
                .to_string();
            assert!(err.contains("only applies with --topology auto"), "{err}");
        }
        // a zero-core budget is rejected before the model even loads
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--topology", "auto", "--pod-cores", "0"])
        )
        .is_err());
        // a missing cost model is a hard error naming the bootstrap command
        let err = Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--topology", "auto", "--cost-model", "/nonexistent/cm.json"]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("--calibrate"), "{err:#}");
    }

    #[test]
    fn from_args_accepts_every_documented_flag() {
        Experiment::from_args(
            Arch::Sebulba,
            &parse(&[
                "--agent", "seb_catch", "--env", "catch", "--actor-cores", "1",
                "--learner-cores", "2", "--threads", "1", "--batch", "16",
                "--pipeline-stages", "2", "--learner-pipeline", "1", "--unroll", "20",
                "--micro-batches", "1", "--discount", "0.99", "--queue", "2",
                "--env-workers", "2", "--replicas", "1", "--updates", "1", "--seed", "3",
                "--data-path", "copy", "--checkpoint-every", "2",
                "--checkpoint-path", "seb.ckpt", "--restore", "old.ckpt",
            ]),
        )
        .unwrap();
        Experiment::from_args(
            Arch::Anakin,
            &parse(&["--agent", "anakin_grid", "--cores", "2", "--outer-iters", "1", "--mode",
                     "psum", "--driver", "serial", "--seed", "1", "--checkpoint-every", "2",
                     "--checkpoint-path", "ana.ckpt", "--restore", "old.ckpt"]),
        )
        .unwrap();
        Experiment::from_args(
            Arch::MuZero,
            &parse(&["--agent", "mz_catch", "--env", "catch", "--actor-cores", "1",
                     "--learner-cores", "2", "--threads", "1", "--simulations", "4",
                     "--learner-pipeline", "1", "--discount", "0.997", "--queue", "2",
                     "--env-workers", "2", "--replicas", "1", "--updates", "1", "--seed", "2",
                     "--checkpoint-every", "2", "--checkpoint-path", "mz.ckpt",
                     "--restore", "old.ckpt"]),
        )
        .unwrap();
    }

    #[test]
    fn distributed_flags_build_learner_and_actor_roles() {
        let exp = Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner", "--listen", "127.0.0.1:0"]),
        )
        .unwrap();
        assert_eq!(exp.role(), PodRole::Learner);
        assert_eq!(exp.topology().pods.get(), 2);
        let exp = Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "3", "--role", "actor", "--connect", "127.0.0.1:7777"]),
        )
        .unwrap();
        assert_eq!(exp.role(), PodRole::Actor);
        // the default is a colocated single-pod run
        let exp = Experiment::from_args(Arch::Sebulba, &parse(&[])).unwrap();
        assert_eq!(exp.role(), PodRole::Colocated);
        assert_eq!(exp.topology().pods, ONE_POD);
    }

    #[test]
    fn distributed_flags_reject_inconsistent_combinations() {
        // pods = 0 is unrepresentable, and the CLI says so
        let err = Experiment::from_args(Arch::Sebulba, &parse(&["--pods", "0"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--pods"), "{err}");
        // a connect address without the actor role is a config bug
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--connect", "127.0.0.1:7777"])
        )
        .is_err());
        // bare --listen / --connect never default silently
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner", "--listen"])
        )
        .is_err());
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "actor", "--connect"])
        )
        .is_err());
        // a role without its address, or with the wrong one, is rejected
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner"])
        )
        .is_err());
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "actor", "--listen", "127.0.0.1:0"])
        )
        .is_err());
        // a distributed role on a single-pod topology makes no sense
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--role", "learner", "--listen", "127.0.0.1:0"])
        )
        .is_err());
        // multi-pod topologies need an explicit role
        let err = Experiment::from_args(Arch::Sebulba, &parse(&["--pods", "2"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("role"), "{err}");
        // unknown role values are parse errors
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "observer"])
        )
        .is_err());
        // distributed runs never checkpoint (elastic or not)
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner", "--listen", "127.0.0.1:0",
                     "--checkpoint-every", "2"])
        )
        .is_err());
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner", "--listen", "127.0.0.1:0",
                     "--elastic", "--checkpoint-every", "2"])
        )
        .is_err());
        // the other architectures reject multi-pod flags outright
        assert!(Experiment::from_args(Arch::Anakin, &parse(&["--pods", "2"])).is_err());
        assert!(Experiment::from_args(Arch::MuZero, &parse(&["--pods", "2"])).is_err());
        assert!(Experiment::new(Arch::Anakin).role(PodRole::Learner).build().is_err());
        assert!(Experiment::new(Arch::MuZero).listen("127.0.0.1:0").build().is_err());
        // builder-level guard matches the CLI one
        assert!(Experiment::new(Arch::Sebulba).connect("127.0.0.1:7777").build().is_err());
        assert!(Experiment::new(Arch::Sebulba)
            .topology(Topology { pods: std::num::NonZeroUsize::new(2).unwrap(),
                                 ..Topology::default() })
            .build()
            .is_err());
    }

    #[test]
    fn elastic_membership_flags_build_and_validate() {
        // both distributed roles accept the full elastic surface
        let exp = Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "3", "--role", "learner", "--listen", "127.0.0.1:0",
                     "--elastic", "--min-actor-pods", "1", "--heartbeat-ms", "250"]),
        )
        .unwrap();
        assert_eq!(exp.role(), PodRole::Learner);
        Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "3", "--role", "actor", "--connect", "127.0.0.1:7777",
                     "--elastic"]),
        )
        .unwrap();
        // `--elastic false` is the static default, spelled out
        let exp = Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner", "--listen", "127.0.0.1:0",
                     "--elastic", "false"]),
        )
        .unwrap();
        assert_eq!(exp.role(), PodRole::Learner);
        // elastic needs a distributed role
        assert!(Experiment::from_args(Arch::Sebulba, &parse(&["--elastic"])).is_err());
        assert!(Experiment::new(Arch::Sebulba).elastic(true).build().is_err());
        // the floor must be reachable with the provisioned actor pods
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner", "--listen", "127.0.0.1:0",
                     "--elastic", "--min-actor-pods", "2"])
        )
        .is_err());
        // a zero floor or a zero heartbeat window is a config bug
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner", "--listen", "127.0.0.1:0",
                     "--elastic", "--min-actor-pods", "0"])
        )
        .is_err());
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner", "--listen", "127.0.0.1:0",
                     "--elastic", "--heartbeat-ms", "0"])
        )
        .is_err());
        // the elastic knobs without --elastic are half-configured
        let err = Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner", "--listen", "127.0.0.1:0",
                     "--min-actor-pods", "1"]),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("--elastic"), "{err}");
        assert!(Experiment::new(Arch::Sebulba).heartbeat_ms(500).build().is_err());
        // non-boolean --elastic values are parse errors
        assert!(Experiment::from_args(
            Arch::Sebulba,
            &parse(&["--pods", "2", "--role", "learner", "--listen", "127.0.0.1:0",
                     "--elastic", "maybe"])
        )
        .is_err());
        // the other architectures reject the elastic surface outright
        assert!(Experiment::from_args(Arch::Anakin, &parse(&["--elastic"])).is_err());
        assert!(Experiment::from_args(Arch::MuZero, &parse(&["--elastic"])).is_err());
        assert!(Experiment::new(Arch::Anakin).elastic(true).build().is_err());
        assert!(Experiment::new(Arch::MuZero).min_actor_pods(1).build().is_err());
    }

    #[test]
    fn elasticity_flags_reject_half_configured_knobs() {
        // a path that nothing will ever write to is a config bug, not a no-op
        let err = Experiment::from_args(
            Arch::Anakin,
            &parse(&["--checkpoint-path", "x.ckpt"]),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("checkpoint_every"), "{err}");
        // zero cadence and a bare --restore are both rejected loudly
        assert!(Experiment::from_args(Arch::Sebulba, &parse(&["--checkpoint-every", "0"]))
            .is_err());
        assert!(Experiment::from_args(Arch::MuZero, &parse(&["--restore"])).is_err());
        // builder-level guard matches the CLI one
        assert!(Experiment::new(Arch::Anakin)
            .checkpoint_path(Path::new("x.ckpt"))
            .build()
            .is_err());
        assert!(Experiment::new(Arch::Sebulba).checkpoint_every(0).build().is_err());
    }
}
