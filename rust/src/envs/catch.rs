//! Catch (bsuite): identical dynamics to the JAX version in
//! `python/compile/envs_jax.py`, so the same exported MLP programs drive
//! both the Anakin (on-device) and Sebulba (host-side) variants.

use super::{read_rng, write_rng, Environment, StepResult};
use crate::checkpoint::format::{SectionReader, SectionWriter};
use crate::util::rng::Xoshiro256;
use anyhow::ensure;

pub struct Catch {
    rows: usize,
    cols: usize,
    ball_row: usize,
    ball_col: usize,
    paddle_col: usize,
    rng: Xoshiro256,
}

impl Catch {
    pub fn new(rows: usize, cols: usize, rng: Xoshiro256) -> Self {
        let mut env = Self { rows, cols, ball_row: 0, ball_col: 0, paddle_col: cols / 2, rng };
        env.reset_state();
        env
    }

    fn reset_state(&mut self) {
        self.ball_row = 0;
        self.ball_col = self.rng.next_below(self.cols as u32) as usize;
        self.paddle_col = self.cols / 2;
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs.fill(0.0);
        obs[self.ball_row * self.cols + self.ball_col] = 1.0;
        obs[(self.rows - 1) * self.cols + self.paddle_col] = 1.0;
    }
}

impl Environment for Catch {
    fn obs_dim(&self) -> usize {
        self.rows * self.cols
    }

    fn num_actions(&self) -> usize {
        3
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.reset_state();
        self.write_obs(obs);
    }

    fn step(&mut self, action: usize, obs: &mut [f32]) -> StepResult {
        debug_assert!(action < 3);
        // {0,1,2} -> {-1,0,+1}
        let delta: isize = action as isize - 1;
        let p = self.paddle_col as isize + delta;
        self.paddle_col = p.clamp(0, self.cols as isize - 1) as usize;
        self.ball_row += 1;

        if self.ball_row >= self.rows - 1 {
            let caught = self.ball_col == self.paddle_col;
            let reward = if caught { 1.0 } else { -1.0 };
            self.reset_state();
            self.write_obs(obs);
            StepResult { reward, done: true }
        } else {
            self.write_obs(obs);
            StepResult { reward: 0.0, done: false }
        }
    }

    fn save_state(&self) -> Vec<u8> {
        let mut w = SectionWriter::new();
        w.put_u64(self.ball_row as u64);
        w.put_u64(self.ball_col as u64);
        w.put_u64(self.paddle_col as u64);
        write_rng(&mut w, &self.rng);
        w.finish()
    }

    fn load_state(&mut self, state: &[u8]) -> anyhow::Result<()> {
        let mut r = SectionReader::new("catch", state);
        let ball_row = r.u64()? as usize;
        let ball_col = r.u64()? as usize;
        let paddle_col = r.u64()? as usize;
        let rng = read_rng(&mut r)?;
        r.done()?;
        ensure!(ball_row < self.rows, "ball_row {ball_row} out of range (rows {})", self.rows);
        ensure!(ball_col < self.cols, "ball_col {ball_col} out of range (cols {})", self.cols);
        ensure!(paddle_col < self.cols, "paddle_col {paddle_col} out of range");
        self.ball_row = ball_row;
        self.ball_col = ball_col;
        self.paddle_col = paddle_col;
        self.rng = rng;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> Catch {
        Catch::new(10, 5, Xoshiro256::new(0))
    }

    #[test]
    fn obs_has_two_pixels() {
        let mut e = env();
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        assert_eq!(obs.iter().filter(|&&x| x == 1.0).count(), 2);
        assert_eq!(obs.iter().filter(|&&x| x == 0.0).count(), 48);
    }

    #[test]
    fn episode_lasts_rows_minus_one_steps() {
        let mut e = env();
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        for step in 1..=9 {
            let r = e.step(1, &mut obs);
            if step < 9 {
                assert!(!r.done, "ended early at {step}");
                assert_eq!(r.reward, 0.0);
            } else {
                assert!(r.done);
                assert!(r.reward == 1.0 || r.reward == -1.0);
            }
        }
    }

    #[test]
    fn tracking_ball_always_catches() {
        let mut e = env();
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        let mut caught = 0;
        for _ in 0..20 {
            loop {
                // read positions from the observation itself (tests the obs too)
                let ball = obs.iter().position(|&x| x == 1.0).unwrap();
                let ball_col = ball % 5;
                let paddle = obs[45..50].iter().position(|&x| x == 1.0).unwrap();
                let action = match ball_col.cmp(&paddle) {
                    std::cmp::Ordering::Less => 0,
                    std::cmp::Ordering::Equal => 1,
                    std::cmp::Ordering::Greater => 2,
                };
                let r = e.step(action, &mut obs);
                if r.done {
                    assert_eq!(r.reward, 1.0, "perfect policy must catch");
                    caught += 1;
                    break;
                }
            }
        }
        assert_eq!(caught, 20);
    }

    #[test]
    fn auto_reset_returns_fresh_obs() {
        let mut e = env();
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        for _ in 0..9 {
            e.step(1, &mut obs);
        }
        // after terminal, obs must show ball back on row 0
        let ball = obs.iter().position(|&x| x == 1.0).unwrap();
        assert!(ball < 5, "ball not at top after auto-reset");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Catch::new(10, 5, Xoshiro256::new(7));
        let mut b = Catch::new(10, 5, Xoshiro256::new(7));
        let mut oa = vec![0.0; 50];
        let mut ob = vec![0.0; 50];
        a.reset(&mut oa);
        b.reset(&mut ob);
        assert_eq!(oa, ob);
        for i in 0..100 {
            let ra = a.step(i % 3, &mut oa);
            let rb = b.step(i % 3, &mut ob);
            assert_eq!(ra, rb);
            assert_eq!(oa, ob);
        }
    }
}
