//! Batched environment: the paper's "special batched environment ... exposed
//! to Python as a single environment that takes a batch of actions and
//! returns a batch of observations", stepped in parallel by the shared
//! worker pool.
//!
//! Slots are chunked over pool workers (contiguous ranges), so a step costs
//! one `run_batch` of `min(pool, batch)` jobs regardless of batch size.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::pool::WorkerPool;
use super::{EnvFactory, Environment};

struct Slot {
    env: Box<dyn Environment>,
    obs: Vec<f32>,
    reward: f32,
    done: bool,
}

pub struct BatchedEnv {
    slots: Vec<Arc<Mutex<Slot>>>,
    pool: Arc<WorkerPool>,
    obs_dim: usize,
    num_actions: usize,
}

impl BatchedEnv {
    pub fn new(factory: &EnvFactory, batch: usize, pool: Arc<WorkerPool>) -> Result<Self> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        let mut slots = Vec::with_capacity(batch);
        let mut obs_dim = 0;
        let mut num_actions = 0;
        for i in 0..batch {
            let env = factory(i);
            obs_dim = env.obs_dim();
            num_actions = env.num_actions();
            slots.push(Arc::new(Mutex::new(Slot {
                obs: vec![0.0; obs_dim],
                env,
                reward: 0.0,
                done: false,
            })));
        }
        Ok(Self { slots, pool, obs_dim, num_actions })
    }

    pub fn batch(&self) -> usize {
        self.slots.len()
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn num_actions(&self) -> usize {
        self.num_actions
    }

    /// Reset every environment; `obs_out` is `[B * obs_dim]`.
    pub fn reset(&self, obs_out: &mut [f32]) {
        assert_eq!(obs_out.len(), self.batch() * self.obs_dim);
        let chunks = self.chunk_ranges();
        self.pool.run_batch(chunks.len(), |ci| {
            let range = chunks[ci].clone();
            let slots: Vec<_> = self.slots[range].iter().map(Arc::clone).collect();
            Box::new(move || {
                for slot in &slots {
                    let mut s = slot.lock().unwrap();
                    let Slot { env, obs, .. } = &mut *s;
                    env.reset(obs);
                }
            })
        });
        self.copy_out(obs_out);
    }

    /// Step every environment with `actions` (`[B]`); writes the batched
    /// next-observations, rewards and done flags.
    pub fn step(
        &self,
        actions: &[i32],
        obs_out: &mut [f32],
        rewards: &mut [f32],
        dones: &mut [bool],
    ) {
        let b = self.batch();
        assert_eq!(actions.len(), b);
        assert_eq!(obs_out.len(), b * self.obs_dim);
        assert_eq!(rewards.len(), b);
        assert_eq!(dones.len(), b);

        let chunks = self.chunk_ranges();
        self.pool.run_batch(chunks.len(), |ci| {
            let range = chunks[ci].clone();
            let slots: Vec<_> = self.slots[range.clone()].iter().map(Arc::clone).collect();
            let acts: Vec<i32> = actions[range].to_vec();
            Box::new(move || {
                for (slot, &a) in slots.iter().zip(&acts) {
                    let mut s = slot.lock().unwrap();
                    let Slot { env, obs, reward, done } = &mut *s;
                    let r = env.step(a as usize, obs);
                    *reward = r.reward;
                    *done = r.done;
                }
            })
        });

        for (i, slot) in self.slots.iter().enumerate() {
            let s = slot.lock().unwrap();
            obs_out[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(&s.obs);
            rewards[i] = s.reward;
            dones[i] = s.done;
        }
    }

    fn chunk_ranges(&self) -> Vec<std::ops::Range<usize>> {
        let b = self.batch();
        let n_chunks = self.pool.size().min(b);
        let per = b.div_ceil(n_chunks);
        (0..n_chunks)
            .map(|c| (c * per)..((c + 1) * per).min(b))
            .filter(|r| !r.is_empty())
            .collect()
    }

    fn copy_out(&self, obs_out: &mut [f32]) {
        for (i, slot) in self.slots.iter().enumerate() {
            let s = slot.lock().unwrap();
            obs_out[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(&s.obs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::make_factory;

    fn batched(kind: &'static str, batch: usize, workers: usize) -> BatchedEnv {
        let pool = WorkerPool::new(workers);
        BatchedEnv::new(&make_factory(kind, 42), batch, pool).unwrap()
    }

    #[test]
    fn reset_fills_all_observations() {
        let be = batched("catch", 8, 3);
        let mut obs = vec![0.0; 8 * be.obs_dim()];
        be.reset(&mut obs);
        for b in 0..8 {
            let o = &obs[b * 50..(b + 1) * 50];
            assert_eq!(o.iter().filter(|&&x| x == 1.0).count(), 2, "env {b}");
        }
    }

    #[test]
    fn step_writes_disjoint_slots() {
        let be = batched("catch", 5, 2);
        let mut obs = vec![0.0; 5 * 50];
        be.reset(&mut obs);
        let actions = vec![0, 1, 2, 1, 0];
        let mut rewards = vec![0.0; 5];
        let mut dones = vec![false; 5];
        be.step(&actions, &mut obs, &mut rewards, &mut dones);
        for b in 0..5 {
            let o = &obs[b * 50..(b + 1) * 50];
            assert_eq!(o.iter().filter(|&&x| x == 1.0).count(), 2, "env {b}");
        }
    }

    #[test]
    fn batched_equals_serial() {
        // The batched env must be observationally identical to stepping the
        // same seeded envs one by one (the property the paper's batched C++
        // env preserves).
        let factory = make_factory("catch", 99);
        let pool = WorkerPool::new(4);
        let be = BatchedEnv::new(&factory, 6, pool).unwrap();
        let mut serial: Vec<_> = (0..6).map(|i| factory(i)).collect();

        let mut obs_b = vec![0.0; 6 * 50];
        be.reset(&mut obs_b);
        let mut obs_s = vec![0.0; 6 * 50];
        for (i, env) in serial.iter_mut().enumerate() {
            env.reset(&mut obs_s[i * 50..(i + 1) * 50]);
        }
        assert_eq!(obs_b, obs_s);

        let mut rewards = vec![0.0; 6];
        let mut dones = vec![false; 6];
        for round in 0..30 {
            let actions: Vec<i32> = (0..6).map(|i| ((round + i) % 3) as i32).collect();
            be.step(&actions, &mut obs_b, &mut rewards, &mut dones);
            for (i, env) in serial.iter_mut().enumerate() {
                let r = env.step(actions[i] as usize, &mut obs_s[i * 50..(i + 1) * 50]);
                assert_eq!(r.reward, rewards[i], "round {round} env {i}");
                assert_eq!(r.done, dones[i]);
            }
            assert_eq!(obs_b, obs_s, "round {round}");
        }
    }

    #[test]
    fn more_workers_than_envs_is_fine() {
        let be = batched("chain", 2, 8);
        let mut obs = vec![0.0; 2 * 10];
        be.reset(&mut obs);
        let mut rewards = vec![0.0; 2];
        let mut dones = vec![false; 2];
        be.step(&[1, 1], &mut obs, &mut rewards, &mut dones);
    }

    #[test]
    fn atari_like_batched_smoke() {
        let be = batched("atari_like", 4, 4);
        let mut obs = vec![0.0; 4 * be.obs_dim()];
        be.reset(&mut obs);
        let mut rewards = vec![0.0; 4];
        let mut dones = vec![false; 4];
        for i in 0..10 {
            let actions = vec![(i % 6) as i32; 4];
            be.step(&actions, &mut obs, &mut rewards, &mut dones);
        }
        assert!(obs.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }
}
