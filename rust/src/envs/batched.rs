//! Batched environment: the paper's "special batched environment ... exposed
//! to Python as a single environment that takes a batch of actions and
//! returns a batch of observations", stepped in parallel by the shared
//! worker pool.
//!
//! Slots are chunked over pool workers (contiguous ranges), so a step costs
//! one `run_batch` of `min(pool, batch)` jobs regardless of batch size.
//!
//! Stepping is also available in split-phase form: `step_async` submits the
//! work and returns a [`StepTicket`]; `StepTicket::wait` joins and copies
//! the results out. The pipelined Sebulba actor steps one sub-batch through
//! the ticket while the device runs inference on another (DESIGN.md §2).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::pool::{BatchTicket, WorkerPool};
use super::{EnvFactory, Environment};

struct Slot {
    env: Box<dyn Environment>,
    obs: Vec<f32>,
    reward: f32,
    done: bool,
}

/// Lock a slot, recovering from poisoning: a panicking env job poisons its
/// slot mutex, but the `Slot` fields are plain data that are always valid,
/// and the panic itself is reported through the batch ticket — treating the
/// slot as dead forever would turn one bad step into a permanently broken
/// batch.
fn lock_slot(slot: &Arc<Mutex<Slot>>) -> std::sync::MutexGuard<'_, Slot> {
    slot.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

pub struct BatchedEnv {
    slots: Vec<Arc<Mutex<Slot>>>,
    pool: Arc<WorkerPool>,
    obs_dim: usize,
    num_actions: usize,
}

impl BatchedEnv {
    pub fn new(factory: &EnvFactory, batch: usize, pool: Arc<WorkerPool>) -> Result<Self> {
        Self::with_slot_offset(factory, batch, 0, pool)
    }

    /// Like [`Self::new`], but env `i` is built as factory slot
    /// `slot_offset + i`. A pipelined actor partitions one logical batch
    /// into several sub-batch envs; the offset keeps every environment's
    /// per-slot RNG stream identical to the unsplit layout.
    pub fn with_slot_offset(
        factory: &EnvFactory,
        batch: usize,
        slot_offset: usize,
        pool: Arc<WorkerPool>,
    ) -> Result<Self> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        let mut slots = Vec::with_capacity(batch);
        let mut obs_dim = 0;
        let mut num_actions = 0;
        for i in 0..batch {
            let env = factory(slot_offset + i);
            obs_dim = env.obs_dim();
            num_actions = env.num_actions();
            slots.push(Arc::new(Mutex::new(Slot {
                obs: vec![0.0; obs_dim],
                env,
                reward: 0.0,
                done: false,
            })));
        }
        Ok(Self { slots, pool, obs_dim, num_actions })
    }

    pub fn batch(&self) -> usize {
        self.slots.len()
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn num_actions(&self) -> usize {
        self.num_actions
    }

    /// Reset every environment; `obs_out` is `[B * obs_dim]`. Errors if a
    /// reset job panicked (the pool survives; see `pool.rs`).
    pub fn reset(&self, obs_out: &mut [f32]) -> Result<()> {
        assert_eq!(obs_out.len(), self.batch() * self.obs_dim);
        let chunks = self.chunk_ranges();
        self.pool.run_batch(chunks.len(), |ci| {
            let range = chunks[ci].clone();
            let slots: Vec<_> = self.slots[range].iter().map(Arc::clone).collect();
            Box::new(move || {
                for slot in &slots {
                    let mut s = lock_slot(slot);
                    let Slot { env, obs, .. } = &mut *s;
                    env.reset(obs);
                }
            })
        })?;
        self.copy_out(obs_out);
        Ok(())
    }

    /// Step every environment with `actions` (`[B]`); writes the batched
    /// next-observations, rewards and done flags. Errors if a step job
    /// panicked.
    pub fn step(
        &self,
        actions: &[i32],
        obs_out: &mut [f32],
        rewards: &mut [f32],
        dones: &mut [bool],
    ) -> Result<()> {
        self.step_async(actions).wait(obs_out, rewards, dones).map(|_| ())
    }

    /// Submit a step without waiting. The pool workers advance the slots in
    /// the background; the returned [`StepTicket`] joins on them and copies
    /// the batched results out. The ticket owns its slot references, so it
    /// can outlive borrows of `self` (the actor stores one per stage).
    pub fn step_async(&self, actions: &[i32]) -> StepTicket {
        let b = self.batch();
        assert_eq!(actions.len(), b);

        let chunks = self.chunk_ranges();
        let ticket = self.pool.run_batch_async(chunks.len(), |ci| {
            let range = chunks[ci].clone();
            let slots: Vec<_> = self.slots[range.clone()].iter().map(Arc::clone).collect();
            let acts: Vec<i32> = actions[range].to_vec();
            Box::new(move || {
                for (slot, &a) in slots.iter().zip(&acts) {
                    let mut s = lock_slot(slot);
                    let Slot { env, obs, reward, done } = &mut *s;
                    let r = env.step(a as usize, obs);
                    *reward = r.reward;
                    *done = r.done;
                }
            })
        });
        StepTicket { slots: self.slots.clone(), obs_dim: self.obs_dim, ticket }
    }

    fn chunk_ranges(&self) -> Vec<std::ops::Range<usize>> {
        let b = self.batch();
        let n_chunks = self.pool.size().min(b);
        let per = b.div_ceil(n_chunks);
        (0..n_chunks)
            .map(|c| (c * per)..((c + 1) * per).min(b))
            .filter(|r| !r.is_empty())
            .collect()
    }

    fn copy_out(&self, obs_out: &mut [f32]) {
        for (i, slot) in self.slots.iter().enumerate() {
            let s = lock_slot(slot);
            obs_out[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(&s.obs);
        }
    }

    /// Snapshot every slot's environment state for a checkpoint. Call only
    /// between steps (no ticket outstanding) — the per-step scratch (obs,
    /// reward, done) is owned by the actor's own buffers and is not stored.
    pub fn save_states(&self) -> Vec<Vec<u8>> {
        self.slots.iter().map(|slot| lock_slot(slot).env.save_state()).collect()
    }

    /// Restore a [`Self::save_states`] snapshot into this batch. The batch
    /// size must match; per-slot decode failures carry the slot index.
    pub fn load_states(&self, states: &[Vec<u8>]) -> Result<()> {
        anyhow::ensure!(
            states.len() == self.batch(),
            "checkpoint has {} env states, batch has {} slots",
            states.len(),
            self.batch()
        );
        for (i, (slot, state)) in self.slots.iter().zip(states).enumerate() {
            lock_slot(slot)
                .env
                .load_state(state)
                .map_err(|e| anyhow::anyhow!("env slot {i}: {e}"))?;
        }
        Ok(())
    }
}

/// Outstanding `step_async` submission: join with [`Self::wait`].
pub struct StepTicket {
    slots: Vec<Arc<Mutex<Slot>>>,
    obs_dim: usize,
    ticket: BatchTicket,
}

impl StepTicket {
    /// Block until the pool has stepped every slot, then copy the batched
    /// next-observations, rewards and done flags out. Returns the host-side
    /// span (submission → last worker completion stamp) for the actor's
    /// overlap accounting, or the panic error if an env job unwound — the
    /// outputs are left unwritten in that case and the actor maps the
    /// failure into its error chain.
    pub fn wait(
        self,
        obs_out: &mut [f32],
        rewards: &mut [f32],
        dones: &mut [bool],
    ) -> Result<Duration> {
        let b = self.slots.len();
        assert_eq!(obs_out.len(), b * self.obs_dim);
        assert_eq!(rewards.len(), b);
        assert_eq!(dones.len(), b);

        let span = self.ticket.wait()?;
        for (i, slot) in self.slots.iter().enumerate() {
            let s = lock_slot(slot);
            obs_out[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(&s.obs);
            rewards[i] = s.reward;
            dones[i] = s.done;
        }
        Ok(span)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{make_factory, EnvKind};

    fn batched(kind: EnvKind, batch: usize, workers: usize) -> BatchedEnv {
        let pool = WorkerPool::new(workers);
        BatchedEnv::new(&make_factory(kind, 42), batch, pool).unwrap()
    }

    #[test]
    fn reset_fills_all_observations() {
        let be = batched(EnvKind::Catch, 8, 3);
        let mut obs = vec![0.0; 8 * be.obs_dim()];
        be.reset(&mut obs).unwrap();
        for b in 0..8 {
            let o = &obs[b * 50..(b + 1) * 50];
            assert_eq!(o.iter().filter(|&&x| x == 1.0).count(), 2, "env {b}");
        }
    }

    #[test]
    fn step_writes_disjoint_slots() {
        let be = batched(EnvKind::Catch, 5, 2);
        let mut obs = vec![0.0; 5 * 50];
        be.reset(&mut obs).unwrap();
        let actions = vec![0, 1, 2, 1, 0];
        let mut rewards = vec![0.0; 5];
        let mut dones = vec![false; 5];
        be.step(&actions, &mut obs, &mut rewards, &mut dones).unwrap();
        for b in 0..5 {
            let o = &obs[b * 50..(b + 1) * 50];
            assert_eq!(o.iter().filter(|&&x| x == 1.0).count(), 2, "env {b}");
        }
    }

    #[test]
    fn batched_equals_serial() {
        // The batched env must be observationally identical to stepping the
        // same seeded envs one by one (the property the paper's batched C++
        // env preserves).
        let factory = make_factory(EnvKind::Catch, 99);
        let pool = WorkerPool::new(4);
        let be = BatchedEnv::new(&factory, 6, pool).unwrap();
        let mut serial: Vec<_> = (0..6).map(|i| factory(i)).collect();

        let mut obs_b = vec![0.0; 6 * 50];
        be.reset(&mut obs_b).unwrap();
        let mut obs_s = vec![0.0; 6 * 50];
        for (i, env) in serial.iter_mut().enumerate() {
            env.reset(&mut obs_s[i * 50..(i + 1) * 50]);
        }
        assert_eq!(obs_b, obs_s);

        let mut rewards = vec![0.0; 6];
        let mut dones = vec![false; 6];
        for round in 0..30 {
            let actions: Vec<i32> = (0..6).map(|i| ((round + i) % 3) as i32).collect();
            be.step(&actions, &mut obs_b, &mut rewards, &mut dones).unwrap();
            for (i, env) in serial.iter_mut().enumerate() {
                let r = env.step(actions[i] as usize, &mut obs_s[i * 50..(i + 1) * 50]);
                assert_eq!(r.reward, rewards[i], "round {round} env {i}");
                assert_eq!(r.done, dones[i]);
            }
            assert_eq!(obs_b, obs_s, "round {round}");
        }
    }

    #[test]
    fn more_workers_than_envs_is_fine() {
        let be = batched(EnvKind::Chain, 2, 8);
        let mut obs = vec![0.0; 2 * 10];
        be.reset(&mut obs).unwrap();
        let mut rewards = vec![0.0; 2];
        let mut dones = vec![false; 2];
        be.step(&[1, 1], &mut obs, &mut rewards, &mut dones).unwrap();
    }

    #[test]
    fn step_async_equals_step() {
        // Two envs built from the same factory/seed; one stepped through the
        // blocking API, one through the ticket — results must be identical.
        let factory = make_factory(EnvKind::Catch, 17);
        let sync = BatchedEnv::new(&factory, 4, WorkerPool::new(2)).unwrap();
        let split = BatchedEnv::new(&factory, 4, WorkerPool::new(2)).unwrap();

        let d = sync.obs_dim();
        let (mut obs_a, mut obs_b) = (vec![0.0; 4 * d], vec![0.0; 4 * d]);
        sync.reset(&mut obs_a).unwrap();
        split.reset(&mut obs_b).unwrap();
        assert_eq!(obs_a, obs_b);

        let (mut rew_a, mut rew_b) = (vec![0.0; 4], vec![0.0; 4]);
        let (mut done_a, mut done_b) = (vec![false; 4], vec![false; 4]);
        for round in 0..25 {
            let actions: Vec<i32> = (0..4).map(|i| ((round + i) % 3) as i32).collect();
            sync.step(&actions, &mut obs_a, &mut rew_a, &mut done_a).unwrap();
            let ticket = split.step_async(&actions);
            ticket.wait(&mut obs_b, &mut rew_b, &mut done_b).unwrap();
            assert_eq!(obs_a, obs_b, "round {round}");
            assert_eq!(rew_a, rew_b);
            assert_eq!(done_a, done_b);
        }
    }

    #[test]
    fn slot_offset_partitions_match_full_batch() {
        // Splitting a batch of 6 into two offset sub-batches must reproduce
        // the unsplit envs exactly (same per-slot RNG streams) — the
        // property pipeline_stages>1 relies on.
        let factory = make_factory(EnvKind::Catch, 31);
        let full = BatchedEnv::new(&factory, 6, WorkerPool::new(2)).unwrap();
        let lo = BatchedEnv::with_slot_offset(&factory, 3, 0, WorkerPool::new(2)).unwrap();
        let hi = BatchedEnv::with_slot_offset(&factory, 3, 3, WorkerPool::new(2)).unwrap();

        let d = full.obs_dim();
        let mut obs_f = vec![0.0; 6 * d];
        let (mut obs_lo, mut obs_hi) = (vec![0.0; 3 * d], vec![0.0; 3 * d]);
        full.reset(&mut obs_f).unwrap();
        lo.reset(&mut obs_lo).unwrap();
        hi.reset(&mut obs_hi).unwrap();
        assert_eq!(&obs_f[..3 * d], &obs_lo[..]);
        assert_eq!(&obs_f[3 * d..], &obs_hi[..]);

        let mut rew_f = vec![0.0; 6];
        let mut done_f = vec![false; 6];
        let (mut rew_s, mut done_s) = (vec![0.0; 3], vec![false; 3]);
        for round in 0..20 {
            let actions: Vec<i32> = (0..6).map(|i| ((round + 2 * i) % 3) as i32).collect();
            full.step(&actions, &mut obs_f, &mut rew_f, &mut done_f).unwrap();
            lo.step(&actions[..3], &mut obs_lo, &mut rew_s, &mut done_s).unwrap();
            assert_eq!(&obs_f[..3 * d], &obs_lo[..], "round {round} (low half)");
            assert_eq!(&rew_f[..3], &rew_s[..]);
            hi.step(&actions[3..], &mut obs_hi, &mut rew_s, &mut done_s).unwrap();
            assert_eq!(&obs_f[3 * d..], &obs_hi[..], "round {round} (high half)");
            assert_eq!(&rew_f[3..], &rew_s[..]);
        }
    }

    #[test]
    fn panicking_env_surfaces_as_step_error_and_env_keeps_working() {
        use crate::envs::{Environment, StepResult};

        // An env that panics on its third step in slot 1 only.
        struct Flaky {
            slot: usize,
            steps: usize,
        }
        impl Environment for Flaky {
            fn obs_dim(&self) -> usize {
                2
            }
            fn num_actions(&self) -> usize {
                2
            }
            fn reset(&mut self, obs: &mut [f32]) {
                obs.fill(0.0);
            }
            fn step(&mut self, _action: usize, obs: &mut [f32]) -> StepResult {
                self.steps += 1;
                if self.slot == 1 && self.steps == 3 {
                    panic!("flaky env blew up on step 3");
                }
                obs.fill(self.steps as f32);
                StepResult { reward: 1.0, done: false }
            }
            fn save_state(&self) -> Vec<u8> {
                (self.steps as u64).to_le_bytes().to_vec()
            }
            fn load_state(&mut self, state: &[u8]) -> anyhow::Result<()> {
                let bytes: [u8; 8] = state.try_into().map_err(|_| anyhow::anyhow!("bad state"))?;
                self.steps = u64::from_le_bytes(bytes) as usize;
                Ok(())
            }
        }
        let factory: EnvFactory = Box::new(|slot| Box::new(Flaky { slot, steps: 0 }));
        let be = BatchedEnv::new(&factory, 2, WorkerPool::new(2)).unwrap();
        let mut obs = vec![0.0; 2 * 2];
        be.reset(&mut obs).unwrap();
        let mut rewards = vec![0.0; 2];
        let mut dones = vec![false; 2];
        be.step(&[0, 0], &mut obs, &mut rewards, &mut dones).unwrap();
        be.step(&[0, 0], &mut obs, &mut rewards, &mut dones).unwrap();
        let err = be
            .step(&[0, 0], &mut obs, &mut rewards, &mut dones)
            .expect_err("the panicking step must surface as an error");
        assert!(format!("{err:#}").contains("flaky env blew up"));
        // the pool survived: slot 0 keeps stepping (slot 1 is past its bomb)
        be.step(&[0, 0], &mut obs, &mut rewards, &mut dones).unwrap();
    }

    #[test]
    fn atari_like_batched_smoke() {
        let be = batched(EnvKind::AtariLike, 4, 4);
        let mut obs = vec![0.0; 4 * be.obs_dim()];
        be.reset(&mut obs).unwrap();
        let mut rewards = vec![0.0; 4];
        let mut dones = vec![false; 4];
        for i in 0..10 {
            let actions = vec![(i % 6) as i32; 4];
            be.step(&actions, &mut obs, &mut rewards, &mut dones).unwrap();
        }
        assert!(obs.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn save_load_states_roundtrips_mid_run() {
        // Step a batch, snapshot it, keep stepping; a *differently seeded*
        // batch restored from the snapshot must continue identically.
        let a = BatchedEnv::new(&make_factory(EnvKind::Catch, 5), 4, WorkerPool::new(2)).unwrap();
        let b = BatchedEnv::new(&make_factory(EnvKind::Catch, 77), 4, WorkerPool::new(2)).unwrap();
        let d = a.obs_dim();
        let mut obs_a = vec![0.0; 4 * d];
        a.reset(&mut obs_a).unwrap();
        let (mut rew, mut done) = (vec![0.0; 4], vec![false; 4]);
        for i in 0..7 {
            a.step(&vec![(i % 3) as i32; 4], &mut obs_a, &mut rew, &mut done).unwrap();
        }
        let snap = a.save_states();
        assert_eq!(snap.len(), 4);
        b.load_states(&snap).unwrap();

        let mut obs_b = vec![0.0; 4 * d];
        let (mut rew_b, mut done_b) = (vec![0.0; 4], vec![false; 4]);
        for round in 0..30 {
            let actions: Vec<i32> = (0..4).map(|i| ((round + i) % 3) as i32).collect();
            a.step(&actions, &mut obs_a, &mut rew, &mut done).unwrap();
            b.step(&actions, &mut obs_b, &mut rew_b, &mut done_b).unwrap();
            assert_eq!(obs_a, obs_b, "round {round}");
            assert_eq!(rew, rew_b);
            assert_eq!(done, done_b);
        }

        // wrong batch size is a typed error, not a partial restore
        let c = BatchedEnv::new(&make_factory(EnvKind::Catch, 5), 3, WorkerPool::new(2)).unwrap();
        assert!(c.load_states(&snap).is_err());
    }
}
