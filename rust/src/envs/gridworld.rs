//! Empty-room gridworld with a random goal (host-side twin of the JAX env).

use super::{read_rng, write_rng, Environment, StepResult};
use crate::checkpoint::format::{SectionReader, SectionWriter};
use crate::util::rng::Xoshiro256;
use anyhow::ensure;

pub struct GridWorld {
    size: usize,
    horizon: usize,
    row: usize,
    col: usize,
    goal_row: usize,
    goal_col: usize,
    t: usize,
    rng: Xoshiro256,
}

impl GridWorld {
    pub fn new(size: usize, horizon: usize, rng: Xoshiro256) -> Self {
        let mut env = Self { size, horizon, row: 0, col: 0, goal_row: 0, goal_col: 0, t: 0, rng };
        env.reset_state();
        env
    }

    fn reset_state(&mut self) {
        self.row = self.rng.next_below(self.size as u32) as usize;
        self.col = self.rng.next_below(self.size as u32) as usize;
        self.goal_row = self.rng.next_below(self.size as u32) as usize;
        self.goal_col = self.rng.next_below(self.size as u32) as usize;
        self.t = 0;
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs.fill(0.0);
        let n = self.size * self.size;
        obs[self.row * self.size + self.col] = 1.0;
        obs[n + self.goal_row * self.size + self.goal_col] = 1.0;
    }
}

impl Environment for GridWorld {
    fn obs_dim(&self) -> usize {
        2 * self.size * self.size
    }

    fn num_actions(&self) -> usize {
        4
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.reset_state();
        self.write_obs(obs);
    }

    fn step(&mut self, action: usize, obs: &mut [f32]) -> StepResult {
        // 0: up, 1: down, 2: left, 3: right (matches envs_jax.GridWorld)
        match action {
            0 => self.row = self.row.saturating_sub(1),
            1 => self.row = (self.row + 1).min(self.size - 1),
            2 => self.col = self.col.saturating_sub(1),
            3 => self.col = (self.col + 1).min(self.size - 1),
            _ => {}
        }
        self.t += 1;
        let at_goal = self.row == self.goal_row && self.col == self.goal_col;
        let done = at_goal || self.t >= self.horizon;
        let reward = if at_goal { 1.0 } else { 0.0 };
        if done {
            self.reset_state();
        }
        self.write_obs(obs);
        StepResult { reward, done }
    }

    fn save_state(&self) -> Vec<u8> {
        let mut w = SectionWriter::new();
        w.put_u64(self.row as u64);
        w.put_u64(self.col as u64);
        w.put_u64(self.goal_row as u64);
        w.put_u64(self.goal_col as u64);
        w.put_u64(self.t as u64);
        write_rng(&mut w, &self.rng);
        w.finish()
    }

    fn load_state(&mut self, state: &[u8]) -> anyhow::Result<()> {
        let mut r = SectionReader::new("gridworld", state);
        let row = r.u64()? as usize;
        let col = r.u64()? as usize;
        let goal_row = r.u64()? as usize;
        let goal_col = r.u64()? as usize;
        let t = r.u64()? as usize;
        let rng = read_rng(&mut r)?;
        r.done()?;
        ensure!(
            row < self.size && col < self.size && goal_row < self.size && goal_col < self.size,
            "cell ({row},{col})/goal ({goal_row},{goal_col}) out of a {0}x{0} grid",
            self.size
        );
        ensure!(t < self.horizon, "step counter {t} out of range (horizon {})", self.horizon);
        self.row = row;
        self.col = col;
        self.goal_row = goal_row;
        self.goal_col = goal_col;
        self.t = t;
        self.rng = rng;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeout_ends_episode() {
        let mut e = GridWorld::new(4, 5, Xoshiro256::new(1));
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        // force agent away from goal by bouncing into a wall corner
        let mut steps = 0;
        loop {
            let r = e.step(0, &mut obs); // keep moving up
            steps += 1;
            if r.done {
                break;
            }
            assert!(steps <= 5, "no terminal by horizon");
        }
        assert!(steps <= 5);
    }

    #[test]
    fn walls_clip_position() {
        let mut e = GridWorld::new(3, 100, Xoshiro256::new(2));
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        for _ in 0..5 {
            e.step(0, &mut obs); // up
        }
        // agent one-hot must still be inside the grid
        let pos = obs[..9].iter().position(|&x| x == 1.0).unwrap();
        assert!(pos < 3, "agent should be pinned to the top row, got cell {pos}");
    }

    #[test]
    fn reaching_goal_rewards() {
        // scan seeds for a (start != goal) instance reachable by going right
        let mut e = GridWorld::new(4, 50, Xoshiro256::new(3));
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        let mut found_reward = false;
        'outer: for _ in 0..50 {
            // naive policy: walk toward the goal via obs decoding
            for _ in 0..50 {
                let pos = obs[..16].iter().position(|&x| x == 1.0).unwrap();
                let goal = obs[16..].iter().position(|&x| x == 1.0).unwrap();
                let (pr, pc) = (pos / 4, pos % 4);
                let (gr, gc) = (goal / 4, goal % 4);
                let action = if pr > gr {
                    0
                } else if pr < gr {
                    1
                } else if pc > gc {
                    2
                } else if pc < gc {
                    3
                } else {
                    0
                };
                let r = e.step(action, &mut obs);
                if r.done {
                    if r.reward == 1.0 {
                        found_reward = true;
                        break 'outer;
                    }
                    break;
                }
            }
        }
        assert!(found_reward, "goal-seeking policy never rewarded");
    }

    #[test]
    fn obs_has_exactly_two_ones() {
        let mut e = GridWorld::new(5, 50, Xoshiro256::new(4));
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        for i in 0..200 {
            e.step(i % 4, &mut obs);
            assert_eq!(obs.iter().filter(|&&x| x == 1.0).count(), 2);
        }
    }
}
