//! Chain MDP: a deterministic credit-assignment probe.
//!
//! N states in a row; action 1 moves right, action 0 resets to the start.
//! Reaching the end yields reward 1 and ends the episode; every other step
//! yields 0. The optimal return is exactly 1 every N-1 steps, which gives
//! tests a closed-form target, and the long reward delay stresses the
//! V-trace/GAE credit-assignment path.

use super::{Environment, StepResult};
use crate::checkpoint::format::{SectionReader, SectionWriter};
use crate::util::rng::Xoshiro256;
use anyhow::ensure;

pub struct Chain {
    n: usize,
    pos: usize,
    _rng: Xoshiro256,
}

impl Chain {
    pub fn new(n: usize, rng: Xoshiro256) -> Self {
        Self { n, pos: 0, _rng: rng }
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs.fill(0.0);
        obs[self.pos] = 1.0;
    }
}

impl Environment for Chain {
    fn obs_dim(&self) -> usize {
        self.n
    }

    fn num_actions(&self) -> usize {
        2
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.pos = 0;
        self.write_obs(obs);
    }

    fn step(&mut self, action: usize, obs: &mut [f32]) -> StepResult {
        if action == 1 {
            self.pos += 1;
            if self.pos >= self.n - 1 {
                self.pos = 0;
                self.write_obs(obs);
                return StepResult { reward: 1.0, done: true };
            }
        } else {
            self.pos = 0;
        }
        self.write_obs(obs);
        StepResult { reward: 0.0, done: false }
    }

    fn save_state(&self) -> Vec<u8> {
        let mut w = SectionWriter::new();
        w.put_u64(self.pos as u64);
        w.finish()
    }

    fn load_state(&mut self, state: &[u8]) -> anyhow::Result<()> {
        let mut r = SectionReader::new("chain", state);
        let pos = r.u64()? as usize;
        r.done()?;
        ensure!(pos < self.n, "pos {pos} out of range (chain length {})", self.n);
        self.pos = pos;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimal_policy_return() {
        let mut e = Chain::new(5, Xoshiro256::new(0));
        let mut obs = vec![0.0; 5];
        e.reset(&mut obs);
        let mut total = 0.0;
        for _ in 0..16 {
            total += e.step(1, &mut obs).reward;
        }
        // 16 steps / 4 steps-per-episode = 4 rewards
        assert_eq!(total, 4.0);
    }

    #[test]
    fn action_zero_resets_progress() {
        let mut e = Chain::new(5, Xoshiro256::new(0));
        let mut obs = vec![0.0; 5];
        e.reset(&mut obs);
        e.step(1, &mut obs);
        e.step(1, &mut obs);
        assert_eq!(obs[2], 1.0);
        e.step(0, &mut obs);
        assert_eq!(obs[0], 1.0);
    }

    #[test]
    fn obs_is_onehot() {
        let mut e = Chain::new(7, Xoshiro256::new(0));
        let mut obs = vec![0.0; 7];
        e.reset(&mut obs);
        for i in 0..50 {
            e.step(i % 2, &mut obs);
            assert_eq!(obs.iter().filter(|&&x| x == 1.0).count(), 1);
        }
    }
}
