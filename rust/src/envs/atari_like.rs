//! `atari_like`: the Atari-2600 substitute (see DESIGN.md §1).
//!
//! A paddle-and-ball game rendered to greyscale pixel frames, wrapped with
//! the standard ALE protocol features that Sebulba's host-side pipeline has
//! to handle: frame skip, frame stacking, sticky actions, episodic lives and
//! a frame limit. The point is not the game — it is that the coordinator
//! exercises exactly the code path of "arbitrary environments (such as Atari
//! video games) that run on the CPU hosts": per-step pixel rendering on the
//! host, batched stepping through the thread pool, and pixel-tensor
//! marshalling to the actor cores.
//!
//! Observation layout is NHWC (`[H, W, C]`, C = stacked frames) to match
//! `ConvActorCritic` in the exported programs.

use super::{read_rng, write_rng, Environment, StepResult};
use crate::checkpoint::format::{SectionReader, SectionWriter};
use crate::util::rng::Xoshiro256;
use anyhow::ensure;

#[derive(Clone, Debug)]
pub struct Config {
    pub height: usize,
    pub width: usize,
    pub frame_stack: usize,
    pub frame_skip: usize,
    /// Probability of repeating the previous action (ALE sticky actions).
    pub sticky: f64,
    pub lives: usize,
    /// Episode frame limit (post-skip agent steps).
    pub max_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            height: 42,
            width: 42,
            frame_stack: 2,
            frame_skip: 4,
            sticky: 0.25,
            lives: 3,
            max_steps: 2_000,
        }
    }
}

pub struct AtariLike {
    cfg: Config,
    // game state (float pixel coordinates)
    ball_x: f32,
    ball_y: f32,
    vel_x: f32,
    vel_y: f32,
    paddle_x: f32,
    lives_left: usize,
    t: usize,
    prev_action: usize,
    // frame ring buffer: frame_stack frames of H*W each
    frames: Vec<f32>,
    frame_head: usize,
    rng: Xoshiro256,
}

const PADDLE_W: f32 = 7.0;
const PADDLE_SPEED: f32 = 2.0;
const BALL_R: f32 = 1.0;

impl AtariLike {
    pub fn new(cfg: Config, rng: Xoshiro256) -> Self {
        let hw = cfg.height * cfg.width;
        let mut env = Self {
            frames: vec![0.0; hw * cfg.frame_stack],
            frame_head: 0,
            ball_x: 0.0,
            ball_y: 0.0,
            vel_x: 0.0,
            vel_y: 0.0,
            paddle_x: 0.0,
            lives_left: cfg.lives,
            t: 0,
            prev_action: 0,
            cfg,
            rng,
        };
        env.serve();
        env
    }

    fn serve(&mut self) {
        let w = self.cfg.width as f32;
        self.ball_x = w * (0.25 + 0.5 * self.rng.next_f32());
        self.ball_y = self.cfg.height as f32 * 0.25;
        let angle = (self.rng.next_f32() - 0.5) * 1.2; // radians around straight-down
        let speed = 1.3;
        self.vel_x = speed * angle.sin();
        self.vel_y = speed * angle.cos();
        self.paddle_x = w / 2.0;
    }

    fn reset_episode(&mut self) {
        self.lives_left = self.cfg.lives;
        self.t = 0;
        self.prev_action = 0;
        self.serve();
        self.frames.fill(0.0);
        self.render_into_current();
    }

    /// Advance the game by one *physics* frame; returns (reward, life_lost).
    fn tick(&mut self, action: usize) -> (f32, bool) {
        let w = self.cfg.width as f32;
        let h = self.cfg.height as f32;
        // actions: 0 NOOP, 1 FIRE, 2 LEFT, 3 RIGHT, 4 LEFT+FIRE, 5 RIGHT+FIRE
        let dx = match action {
            2 | 4 => -PADDLE_SPEED,
            3 | 5 => PADDLE_SPEED,
            _ => 0.0,
        };
        self.paddle_x = (self.paddle_x + dx).clamp(PADDLE_W / 2.0, w - PADDLE_W / 2.0);

        self.ball_x += self.vel_x;
        self.ball_y += self.vel_y;
        // side walls
        if self.ball_x < BALL_R {
            self.ball_x = BALL_R;
            self.vel_x = -self.vel_x;
        } else if self.ball_x > w - BALL_R {
            self.ball_x = w - BALL_R;
            self.vel_x = -self.vel_x;
        }
        // ceiling
        if self.ball_y < BALL_R {
            self.ball_y = BALL_R;
            self.vel_y = -self.vel_y;
        }
        // paddle line is at h - 2
        if self.ball_y >= h - 3.0 && self.vel_y > 0.0 {
            let offset = self.ball_x - self.paddle_x;
            if offset.abs() <= PADDLE_W / 2.0 + BALL_R {
                // hit: bounce with english proportional to hit offset
                self.vel_y = -self.vel_y.abs();
                self.vel_x += 0.35 * (offset / (PADDLE_W / 2.0));
                self.vel_x = self.vel_x.clamp(-1.6, 1.6);
                // slight speed-up, capped (keeps episodes finite & hard)
                self.vel_y = (self.vel_y * 1.03).clamp(-2.0, -0.8);
                return (1.0, false);
            } else if self.ball_y >= h - 1.0 {
                // miss: life lost, re-serve
                self.serve();
                return (0.0, true);
            }
        }
        (0.0, false)
    }

    fn render_into_current(&mut self) {
        let (h, w) = (self.cfg.height, self.cfg.width);
        let hw = h * w;
        let start = self.frame_head * hw;
        let frame = &mut self.frames[start..start + hw];
        frame.fill(0.0);
        // walls (faint)
        for x in 0..w {
            frame[x] = 0.3;
        }
        for y in 0..h {
            frame[y * w] = 0.3;
            frame[y * w + (w - 1)] = 0.3;
        }
        // ball: 2x2 bright block
        let bx = (self.ball_x as usize).min(w - 2);
        let by = (self.ball_y as usize).min(h - 2);
        for dy in 0..2 {
            for dx in 0..2 {
                frame[(by + dy) * w + bx + dx] = 1.0;
            }
        }
        // paddle: 1 x PADDLE_W bar near the bottom
        let py = h - 2;
        let half = (PADDLE_W / 2.0) as usize;
        let px0 = (self.paddle_x as usize).saturating_sub(half).min(w - 1);
        let px1 = ((self.paddle_x + PADDLE_W / 2.0) as usize).min(w - 1);
        for x in px0..=px1 {
            frame[py * w + x] = 0.8;
        }
    }

    /// Write the stacked observation (NHWC, newest frame last channel).
    fn write_obs(&self, obs: &mut [f32]) {
        let (h, w, c) = (self.cfg.height, self.cfg.width, self.cfg.frame_stack);
        let hw = h * w;
        for ci in 0..c {
            // channel c-1 = newest (frame_head), channel 0 = oldest
            let age = c - 1 - ci;
            let slot = (self.frame_head + c - age % c) % c;
            let frame = &self.frames[slot * hw..(slot + 1) * hw];
            for i in 0..hw {
                obs[i * c + ci] = frame[i];
            }
        }
    }
}

impl Environment for AtariLike {
    fn obs_dim(&self) -> usize {
        self.cfg.height * self.cfg.width * self.cfg.frame_stack
    }

    fn num_actions(&self) -> usize {
        6
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.reset_episode();
        self.write_obs(obs);
    }

    fn step(&mut self, mut action: usize, obs: &mut [f32]) -> StepResult {
        debug_assert!(action < 6);
        // sticky actions
        if self.rng.next_f64() < self.cfg.sticky {
            action = self.prev_action;
        }
        self.prev_action = action;

        let mut reward = 0.0;
        let mut life_lost = false;
        for _ in 0..self.cfg.frame_skip {
            let (r, lost) = self.tick(action);
            reward += r;
            life_lost |= lost;
            if lost {
                break;
            }
        }
        if life_lost {
            self.lives_left = self.lives_left.saturating_sub(1);
        }
        self.t += 1;

        // advance the frame ring and render the post-step frame
        self.frame_head = (self.frame_head + 1) % self.cfg.frame_stack;
        self.render_into_current();

        let done = self.lives_left == 0 || self.t >= self.cfg.max_steps;
        if done {
            self.reset_episode();
        }
        self.write_obs(obs);
        StepResult { reward, done }
    }

    fn save_state(&self) -> Vec<u8> {
        let mut w = SectionWriter::new();
        w.put_f32(self.ball_x);
        w.put_f32(self.ball_y);
        w.put_f32(self.vel_x);
        w.put_f32(self.vel_y);
        w.put_f32(self.paddle_x);
        w.put_u64(self.lives_left as u64);
        w.put_u64(self.t as u64);
        w.put_u64(self.prev_action as u64);
        w.put_u64(self.frame_head as u64);
        w.put_f32s(&self.frames);
        write_rng(&mut w, &self.rng);
        w.finish()
    }

    fn load_state(&mut self, state: &[u8]) -> anyhow::Result<()> {
        let mut r = SectionReader::new("atari_like", state);
        let ball_x = r.f32()?;
        let ball_y = r.f32()?;
        let vel_x = r.f32()?;
        let vel_y = r.f32()?;
        let paddle_x = r.f32()?;
        let lives_left = r.u64()? as usize;
        let t = r.u64()? as usize;
        let prev_action = r.u64()? as usize;
        let frame_head = r.u64()? as usize;
        let frames = r.f32s()?;
        let rng = read_rng(&mut r)?;
        r.done()?;
        ensure!(
            frames.len() == self.frames.len(),
            "frame buffer holds {} pixels, env expects {}",
            frames.len(),
            self.frames.len()
        );
        ensure!(frame_head < self.cfg.frame_stack, "frame_head {frame_head} out of range");
        ensure!(lives_left > 0 && lives_left <= self.cfg.lives, "lives_left {lives_left} out of range");
        ensure!(t < self.cfg.max_steps, "step counter {t} out of range");
        ensure!(prev_action < 6, "prev_action {prev_action} out of range");
        ensure!(
            [ball_x, ball_y, vel_x, vel_y, paddle_x].iter().all(|v| v.is_finite()),
            "non-finite game state"
        );
        self.ball_x = ball_x;
        self.ball_y = ball_y;
        self.vel_x = vel_x;
        self.vel_y = vel_y;
        self.paddle_x = paddle_x;
        self.lives_left = lives_left;
        self.t = t;
        self.prev_action = prev_action;
        self.frame_head = frame_head;
        self.frames = frames;
        self.rng = rng;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(seed: u64) -> AtariLike {
        AtariLike::new(Config::default(), Xoshiro256::new(seed))
    }

    #[test]
    fn obs_dim_matches_layout() {
        let e = env(0);
        assert_eq!(e.obs_dim(), 42 * 42 * 2);
    }

    #[test]
    fn obs_values_in_unit_range() {
        let mut e = env(1);
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        let mut rng = Xoshiro256::new(2);
        for _ in 0..200 {
            e.step(rng.next_below(6) as usize, &mut obs);
            assert!(obs.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn episode_terminates() {
        let mut e = AtariLike::new(
            Config { lives: 1, max_steps: 10_000, ..Config::default() },
            Xoshiro256::new(3),
        );
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        let mut done = false;
        for _ in 0..10_000 {
            // NOOP forever: ball must eventually be missed
            if e.step(0, &mut obs).done {
                done = true;
                break;
            }
        }
        assert!(done, "episode with a NOOP policy never ended");
    }

    #[test]
    fn frame_limit_terminates() {
        let mut e = AtariLike::new(
            Config { max_steps: 25, lives: 99, ..Config::default() },
            Xoshiro256::new(4),
        );
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        let mut steps = 0;
        loop {
            steps += 1;
            if e.step(0, &mut obs).done {
                break;
            }
            assert!(steps <= 25);
        }
        assert_eq!(steps, 25);
    }

    #[test]
    fn tracking_policy_scores() {
        // A paddle that follows the ball should collect rewards.
        let mut e = AtariLike::new(
            Config { sticky: 0.0, ..Config::default() },
            Xoshiro256::new(5),
        );
        let mut obs = vec![0.0; e.obs_dim()];
        e.reset(&mut obs);
        let (h, w, c) = (42, 42, 2);
        let mut total = 0.0;
        for _ in 0..600 {
            // decode ball and paddle x from the newest channel
            let mut ball_x = 0usize;
            let mut paddle_x = 0usize;
            for y in 0..h - 2 {
                for x in 0..w {
                    if obs[(y * w + x) * c + (c - 1)] == 1.0 {
                        ball_x = x;
                    }
                }
            }
            for x in 0..w {
                if obs[((h - 2) * w + x) * c + (c - 1)] == 0.8 {
                    paddle_x = x;
                    break;
                }
            }
            let paddle_center = paddle_x + 3;
            let action = if ball_x > paddle_center + 1 {
                3
            } else if ball_x + 1 < paddle_center {
                2
            } else {
                0
            };
            total += e.step(action, &mut obs).reward as f64;
        }
        assert!(total >= 3.0, "tracking policy only scored {total}");
    }

    #[test]
    fn sticky_actions_are_seed_deterministic() {
        let mut a = env(7);
        let mut b = env(7);
        let mut oa = vec![0.0; a.obs_dim()];
        let mut ob = vec![0.0; b.obs_dim()];
        a.reset(&mut oa);
        b.reset(&mut ob);
        for i in 0..100 {
            let ra = a.step(i % 6, &mut oa);
            let rb = b.step(i % 6, &mut ob);
            assert_eq!(ra, rb);
        }
        assert_eq!(oa, ob);
    }
}
