//! CartPole (Barto, Sutton & Anderson 1983): the classic continuous-state
//! control benchmark, Euler-integrated like the Gym implementation.

use super::{read_rng, write_rng, Environment, StepResult};
use crate::checkpoint::format::{SectionReader, SectionWriter};
use crate::util::rng::Xoshiro256;
use anyhow::ensure;

const GRAVITY: f32 = 9.8;
const CART_MASS: f32 = 1.0;
const POLE_MASS: f32 = 0.1;
const TOTAL_MASS: f32 = CART_MASS + POLE_MASS;
const POLE_HALF_LEN: f32 = 0.5;
const FORCE_MAG: f32 = 10.0;
const TAU: f32 = 0.02;
const X_LIMIT: f32 = 2.4;
const THETA_LIMIT: f32 = 12.0 * std::f32::consts::PI / 180.0;

pub struct CartPole {
    x: f32,
    x_dot: f32,
    theta: f32,
    theta_dot: f32,
    t: usize,
    max_steps: usize,
    rng: Xoshiro256,
}

impl CartPole {
    pub fn new(rng: Xoshiro256) -> Self {
        let mut env = Self { x: 0.0, x_dot: 0.0, theta: 0.0, theta_dot: 0.0, t: 0, max_steps: 500, rng };
        env.reset_state();
        env
    }

    fn reset_state(&mut self) {
        let mut u = || (self.rng.next_f32() - 0.5) * 0.1;
        self.x = u();
        self.x_dot = u();
        self.theta = u();
        self.theta_dot = u();
        self.t = 0;
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs[0] = self.x;
        obs[1] = self.x_dot;
        obs[2] = self.theta;
        obs[3] = self.theta_dot;
    }
}

impl Environment for CartPole {
    fn obs_dim(&self) -> usize {
        4
    }

    fn num_actions(&self) -> usize {
        2
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.reset_state();
        self.write_obs(obs);
    }

    fn step(&mut self, action: usize, obs: &mut [f32]) -> StepResult {
        let force = if action == 1 { FORCE_MAG } else { -FORCE_MAG };
        let cos = self.theta.cos();
        let sin = self.theta.sin();
        let temp =
            (force + POLE_MASS * POLE_HALF_LEN * self.theta_dot * self.theta_dot * sin) / TOTAL_MASS;
        let theta_acc = (GRAVITY * sin - cos * temp)
            / (POLE_HALF_LEN * (4.0 / 3.0 - POLE_MASS * cos * cos / TOTAL_MASS));
        let x_acc = temp - POLE_MASS * POLE_HALF_LEN * theta_acc * cos / TOTAL_MASS;

        self.x += TAU * self.x_dot;
        self.x_dot += TAU * x_acc;
        self.theta += TAU * self.theta_dot;
        self.theta_dot += TAU * theta_acc;
        self.t += 1;

        let failed = self.x.abs() > X_LIMIT || self.theta.abs() > THETA_LIMIT;
        let done = failed || self.t >= self.max_steps;
        if done {
            self.reset_state();
        }
        self.write_obs(obs);
        StepResult { reward: 1.0, done }
    }

    fn save_state(&self) -> Vec<u8> {
        let mut w = SectionWriter::new();
        w.put_f32(self.x);
        w.put_f32(self.x_dot);
        w.put_f32(self.theta);
        w.put_f32(self.theta_dot);
        w.put_u64(self.t as u64);
        write_rng(&mut w, &self.rng);
        w.finish()
    }

    fn load_state(&mut self, state: &[u8]) -> anyhow::Result<()> {
        let mut r = SectionReader::new("cartpole", state);
        let x = r.f32()?;
        let x_dot = r.f32()?;
        let theta = r.f32()?;
        let theta_dot = r.f32()?;
        let t = r.u64()? as usize;
        let rng = read_rng(&mut r)?;
        r.done()?;
        ensure!(t < self.max_steps, "step counter {t} out of range (max {})", self.max_steps);
        ensure!(
            x.is_finite() && x_dot.is_finite() && theta.is_finite() && theta_dot.is_finite(),
            "non-finite physics state"
        );
        self.x = x;
        self.x_dot = x_dot;
        self.theta = theta;
        self.theta_dot = theta_dot;
        self.t = t;
        self.rng = rng;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_policy_fails_eventually() {
        let mut e = CartPole::new(Xoshiro256::new(0));
        let mut obs = vec![0.0; 4];
        e.reset(&mut obs);
        let mut rng = Xoshiro256::new(1);
        let mut steps = 0;
        loop {
            let r = e.step(rng.next_below(2) as usize, &mut obs);
            steps += 1;
            if r.done {
                break;
            }
            assert!(steps <= 500);
        }
        assert!(steps < 500, "random policy should fail before timeout");
    }

    #[test]
    fn reward_is_one_per_step() {
        let mut e = CartPole::new(Xoshiro256::new(2));
        let mut obs = vec![0.0; 4];
        e.reset(&mut obs);
        let r = e.step(0, &mut obs);
        assert_eq!(r.reward, 1.0);
    }

    #[test]
    fn reset_bounds_state() {
        let mut e = CartPole::new(Xoshiro256::new(3));
        let mut obs = vec![0.0; 4];
        for _ in 0..20 {
            e.reset(&mut obs);
            assert!(obs.iter().all(|&x| x.abs() <= 0.05 + 1e-6));
        }
    }

    #[test]
    fn balancing_policy_beats_random() {
        // simple PD-ish policy: push in the direction the pole is falling
        let mut e = CartPole::new(Xoshiro256::new(4));
        let mut obs = vec![0.0; 4];
        e.reset(&mut obs);
        let mut lens = Vec::new();
        let mut len = 0;
        for _ in 0..3000 {
            let action = if obs[2] + 0.5 * obs[3] > 0.0 { 1 } else { 0 };
            let r = e.step(action, &mut obs);
            len += 1;
            if r.done {
                lens.push(len);
                len = 0;
            }
        }
        let mean: f64 = lens.iter().map(|&l| l as f64).sum::<f64>() / lens.len().max(1) as f64;
        assert!(mean > 100.0, "PD policy mean episode {mean}");
    }
}
