//! Shared worker-thread pool — the Rust analogue of the paper's "shared pool
//! of C++ threads" that steps batched environments behind the Python facade.
//!
//! Deliberately minimal: FIFO job queue, fixed worker count, completion
//! signalled through per-batch channels by the submitter. Batches can be
//! submitted without blocking (`run_batch_async` returns a [`BatchTicket`])
//! so the pipelined Sebulba actor can overlap env stepping with device
//! inference (DESIGN.md §2).
//!
//! Panics are contained: a job that unwinds is caught *inside* the wrapped
//! batch job, its worker stays alive (no silent pool shrink), and the
//! failure surfaces through [`BatchTicket::wait`] as an error the actor
//! maps into its error chain — instead of the pre-fix behaviour, where the
//! panicking job killed its worker thread and every later `wait` on the
//! starved batch panicked on a dead channel.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// What a batch job reports back: its completion stamp, or the panic
/// message if it unwound.
type JobOutcome = std::result::Result<Instant, String>;

fn panic_detail(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    pub fn new(size: usize) -> Arc<Self> {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("env-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            // Contain unwinds from raw `submit` jobs too:
                            // a panic must never take the worker with it.
                            // Batch jobs additionally catch inside their
                            // wrapper so the ticket learns the details.
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn env worker")
            })
            .collect();
        Arc::new(Self { tx: Some(tx), workers, size })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn submit(&self, job: Job) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(job)
            .expect("worker pool died");
    }

    /// Run `n` jobs produced by `make_job` and wait for all of them.
    /// Errors if any job panicked (the pool itself stays healthy).
    pub fn run_batch<F>(&self, n: usize, make_job: F) -> Result<()>
    where
        F: Fn(usize) -> Job,
    {
        self.run_batch_async(n, make_job).wait().map(|_| ())
    }

    /// Submit `n` jobs without blocking; the returned [`BatchTicket`] joins
    /// on them later. While the ticket is outstanding the submitter is free
    /// to do other work (the double-buffering seam of DESIGN.md §2).
    pub fn run_batch_async<F>(&self, n: usize, make_job: F) -> BatchTicket
    where
        F: Fn(usize) -> Job,
    {
        let issued = Instant::now();
        let (done_tx, done_rx) = mpsc::channel::<JobOutcome>();
        for i in 0..n {
            let job = make_job(i);
            let done = done_tx.clone();
            self.submit(Box::new(move || {
                // Catch the unwind here, inside the job wrapper, so the
                // completion channel always gets exactly one message per
                // job and the worker thread survives.
                let outcome = match catch_unwind(AssertUnwindSafe(job)) {
                    Ok(()) => Ok(Instant::now()),
                    Err(payload) => Err(panic_detail(payload)),
                };
                let _ = done.send(outcome);
            }));
        }
        BatchTicket { rx: done_rx, remaining: n, issued }
    }
}

/// Completion handle for one submitted batch of jobs. Workers stamp their
/// completion times, so `wait` reports the true submission→last-job span
/// even when the submitter joins late — the overlap stats depend on this.
pub struct BatchTicket {
    rx: mpsc::Receiver<JobOutcome>,
    remaining: usize,
    issued: Instant,
}

impl BatchTicket {
    /// Block until every job in the batch has run. Returns the span from
    /// submission to the last job's completion stamp, or an error carrying
    /// the first panic message if any job unwound. The full batch is
    /// drained either way, so a failed batch leaves no stragglers behind.
    pub fn wait(self) -> Result<Duration> {
        let mut last = self.issued;
        let mut first_panic: Option<String> = None;
        for _ in 0..self.remaining {
            match self.rx.recv() {
                Ok(Ok(done)) => {
                    if done > last {
                        last = done;
                    }
                }
                Ok(Err(detail)) => {
                    if first_panic.is_none() {
                        first_panic = Some(detail);
                    }
                }
                // All workers gone mid-batch (pool dropped): nothing more
                // will arrive — report it rather than spinning.
                Err(_) => {
                    if first_panic.is_none() {
                        first_panic = Some("worker pool shut down mid-batch".to_string());
                    }
                    break;
                }
            }
        }
        match first_panic {
            None => Ok(last - self.issued),
            Some(detail) => Err(anyhow!("env job panicked: {detail}")),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        pool.run_batch(100, move |_| {
            let c = c.clone();
            Box::new(move || {
                c.fetch_add(1, Ordering::SeqCst);
            })
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn batch_blocks_until_done() {
        let pool = WorkerPool::new(2);
        let flag = Arc::new(AtomicUsize::new(0));
        let f = flag.clone();
        pool.run_batch(8, move |_| {
            let f = f.clone();
            Box::new(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                f.fetch_add(1, Ordering::SeqCst);
            })
        })
        .unwrap();
        // run_batch returned, so every job must have finished
        assert_eq!(flag.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn pool_survives_many_batches() {
        let pool = WorkerPool::new(3);
        for _ in 0..50 {
            let counter = Arc::new(AtomicUsize::new(0));
            let c = counter.clone();
            pool.run_batch(7, move |_| {
                let c = c.clone();
                Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .unwrap();
            assert_eq!(counter.load(Ordering::SeqCst), 7);
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(2);
        drop(pool); // must not hang
    }

    #[test]
    fn async_batch_overlaps_submitter_work() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        let ticket = pool.run_batch_async(6, move |_| {
            let c = c.clone();
            Box::new(move || {
                std::thread::sleep(std::time::Duration::from_millis(2));
                c.fetch_add(1, Ordering::SeqCst);
            })
        });
        let span = ticket.wait().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 6);
        assert!(span >= std::time::Duration::from_millis(2));
    }

    #[test]
    fn empty_async_batch_completes() {
        let pool = WorkerPool::new(1);
        let span = pool.run_batch_async(0, |_| Box::new(|| {})).wait().unwrap();
        assert!(span <= std::time::Duration::from_millis(50));
    }

    #[test]
    fn panicking_job_surfaces_through_the_ticket() {
        // Regression (ISSUE 4): a panicking env job used to kill its worker
        // (silent pool shrink) and make `wait` panic on a dead channel.
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        let ticket = pool.run_batch_async(4, move |i| {
            let c = c.clone();
            Box::new(move || {
                if i == 1 {
                    panic!("boom in env step {i}");
                }
                c.fetch_add(1, Ordering::SeqCst);
            })
        });
        let err = ticket.wait().expect_err("panic must surface as an error");
        let msg = format!("{err:#}");
        assert!(msg.contains("boom in env step 1"), "panic detail lost: {msg}");
        // the other 3 jobs still ran to completion (batch fully drained)
        assert_eq!(counter.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn pool_stays_full_size_after_a_panic() {
        // Both workers must survive a panicking batch: a follow-up batch
        // wider than one worker still completes (no silent shrink to a
        // single-threaded pool, no deadlock).
        let pool = WorkerPool::new(2);
        let bad = pool.run_batch_async(2, |_| Box::new(|| panic!("every job dies")));
        assert!(bad.wait().is_err());

        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        pool.run_batch(16, move |_| {
            let c = c.clone();
            Box::new(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            })
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn raw_submit_panic_keeps_worker_alive() {
        let pool = WorkerPool::new(1);
        pool.submit(Box::new(|| panic!("fire-and-forget job panics")));
        // the single worker must still process subsequent batches
        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        pool.run_batch(3, move |_| {
            let c = c.clone();
            Box::new(move || {
                c.fetch_add(1, Ordering::SeqCst);
            })
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 3);
    }
}
