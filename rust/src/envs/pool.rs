//! Shared worker-thread pool — the Rust analogue of the paper's "shared pool
//! of C++ threads" that steps batched environments behind the Python facade.
//!
//! Deliberately minimal: FIFO job queue, fixed worker count, completion
//! signalled through per-batch channels by the submitter. Batches can be
//! submitted without blocking (`run_batch_async` returns a [`BatchTicket`])
//! so the pipelined Sebulba actor can overlap env stepping with device
//! inference (DESIGN.md §2).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    pub fn new(size: usize) -> Arc<Self> {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("env-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn env worker")
            })
            .collect();
        Arc::new(Self { tx: Some(tx), workers, size })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn submit(&self, job: Job) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(job)
            .expect("worker pool died");
    }

    /// Run `n` jobs produced by `make_job` and wait for all of them.
    pub fn run_batch<F>(&self, n: usize, make_job: F)
    where
        F: Fn(usize) -> Job,
    {
        self.run_batch_async(n, make_job).wait();
    }

    /// Submit `n` jobs without blocking; the returned [`BatchTicket`] joins
    /// on them later. While the ticket is outstanding the submitter is free
    /// to do other work (the double-buffering seam of DESIGN.md §2).
    pub fn run_batch_async<F>(&self, n: usize, make_job: F) -> BatchTicket
    where
        F: Fn(usize) -> Job,
    {
        let issued = Instant::now();
        let (done_tx, done_rx) = mpsc::channel::<Instant>();
        for i in 0..n {
            let job = make_job(i);
            let done = done_tx.clone();
            self.submit(Box::new(move || {
                job();
                let _ = done.send(Instant::now());
            }));
        }
        BatchTicket { rx: done_rx, remaining: n, issued }
    }
}

/// Completion handle for one submitted batch of jobs. Workers stamp their
/// completion times, so `wait` reports the true submission→last-job span
/// even when the submitter joins late — the overlap stats depend on this.
pub struct BatchTicket {
    rx: mpsc::Receiver<Instant>,
    remaining: usize,
    issued: Instant,
}

impl BatchTicket {
    /// Block until every job in the batch has run. Returns the span from
    /// submission to the last job's completion stamp.
    pub fn wait(self) -> Duration {
        let mut last = self.issued;
        for _ in 0..self.remaining {
            let done = self.rx.recv().expect("worker panicked");
            if done > last {
                last = done;
            }
        }
        last - self.issued
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        pool.run_batch(100, move |_| {
            let c = c.clone();
            Box::new(move || {
                c.fetch_add(1, Ordering::SeqCst);
            })
        });
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn batch_blocks_until_done() {
        let pool = WorkerPool::new(2);
        let flag = Arc::new(AtomicUsize::new(0));
        let f = flag.clone();
        pool.run_batch(8, move |_| {
            let f = f.clone();
            Box::new(move || {
                std::thread::sleep(std::time::Duration::from_millis(5));
                f.fetch_add(1, Ordering::SeqCst);
            })
        });
        // run_batch returned, so every job must have finished
        assert_eq!(flag.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn pool_survives_many_batches() {
        let pool = WorkerPool::new(3);
        for _ in 0..50 {
            let counter = Arc::new(AtomicUsize::new(0));
            let c = counter.clone();
            pool.run_batch(7, move |_| {
                let c = c.clone();
                Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
            });
            assert_eq!(counter.load(Ordering::SeqCst), 7);
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(2);
        drop(pool); // must not hang
    }

    #[test]
    fn async_batch_overlaps_submitter_work() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        let ticket = pool.run_batch_async(6, move |_| {
            let c = c.clone();
            Box::new(move || {
                std::thread::sleep(std::time::Duration::from_millis(2));
                c.fetch_add(1, Ordering::SeqCst);
            })
        });
        let span = ticket.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 6);
        assert!(span >= std::time::Duration::from_millis(2));
    }

    #[test]
    fn empty_async_batch_completes() {
        let pool = WorkerPool::new(1);
        let span = pool.run_batch_async(0, |_| Box::new(|| {})).wait();
        assert!(span <= std::time::Duration::from_millis(50));
    }
}
