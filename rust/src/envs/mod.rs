//! Host-side environments for Sebulba (the paper's "arbitrary environments
//! that run on the CPU hosts").
//!
//! The substrate mirrors what the paper relies on: single environments with
//! a `reset/step` interface, and a *batched* environment (`BatchedEnv`) that
//! "is exposed ... as a single environment that takes a batch of actions and
//! returns a batch of observations; behind the scenes it steps each
//! environment in the batch in parallel using a shared pool of C++ threads"
//! — here, a shared pool of Rust threads (`pool::WorkerPool`).
//!
//! Observations are flat `f32` buffers written into caller-provided slices
//! (no allocation on the hot path); `atari_like` is the Atari substitute
//! (pixel rendering, frame stack, sticky actions, episodic lives).

pub mod atari_like;
pub mod batched;
pub mod cartpole;
pub mod catch;
pub mod chain;
pub mod gridworld;
pub mod pool;

pub use batched::BatchedEnv;
pub use pool::WorkerPool;

use crate::util::rng::Xoshiro256;

/// One transition's results (the observation is written separately).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepResult {
    pub reward: f32,
    /// True if this step *ended* an episode (the returned observation is
    /// then the first observation of a fresh episode — auto-reset).
    pub done: bool,
}

/// A host-side environment. Implementations must be deterministic given the
/// RNG stream passed at construction.
pub trait Environment: Send {
    /// Flat observation size (what the exported programs expect).
    fn obs_dim(&self) -> usize;
    fn num_actions(&self) -> usize;

    /// Start a new episode; write the initial observation into `obs`.
    fn reset(&mut self, obs: &mut [f32]);

    /// Step with `action`; write the *next* observation into `obs`
    /// (auto-reset: on `done`, `obs` is the fresh episode's first frame).
    fn step(&mut self, action: usize, obs: &mut [f32]) -> StepResult;
}

/// Environment constructors by name (used by the CLI and benches).
pub fn make_env(kind: &str, seed: u64) -> anyhow::Result<Box<dyn Environment>> {
    let rng = Xoshiro256::from_stream(seed, 0x517);
    Ok(match kind {
        "catch" => Box::new(catch::Catch::new(10, 5, rng)),
        "gridworld" => Box::new(gridworld::GridWorld::new(8, 50, rng)),
        "cartpole" => Box::new(cartpole::CartPole::new(rng)),
        "chain" => Box::new(chain::Chain::new(10, rng)),
        "atari_like" => Box::new(atari_like::AtariLike::new(
            atari_like::Config::default(),
            rng,
        )),
        other => anyhow::bail!("unknown environment {other:?}"),
    })
}

/// The environment factory type used by `BatchedEnv` (one env per slot).
pub type EnvFactory = Box<dyn Fn(usize) -> Box<dyn Environment> + Send + Sync>;

/// Factory for `kind`, deriving each slot's RNG stream from `seed`.
pub fn make_factory(kind: &'static str, seed: u64) -> EnvFactory {
    Box::new(move |slot| {
        let rng = Xoshiro256::from_stream(seed, 0x9E00 + slot as u64);
        let env: Box<dyn Environment> = match kind {
            "catch" => Box::new(catch::Catch::new(10, 5, rng)),
            "gridworld" => Box::new(gridworld::GridWorld::new(8, 50, rng)),
            "cartpole" => Box::new(cartpole::CartPole::new(rng)),
            "chain" => Box::new(chain::Chain::new(10, rng)),
            "atari_like" => Box::new(atari_like::AtariLike::new(
                atari_like::Config::default(),
                rng,
            )),
            other => panic!("unknown environment {other:?}"),
        };
        env
    })
}
