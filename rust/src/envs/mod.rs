//! Host-side environments for Sebulba (the paper's "arbitrary environments
//! that run on the CPU hosts").
//!
//! The substrate mirrors what the paper relies on: single environments with
//! a `reset/step` interface, and a *batched* environment (`BatchedEnv`) that
//! "is exposed ... as a single environment that takes a batch of actions and
//! returns a batch of observations; behind the scenes it steps each
//! environment in the batch in parallel using a shared pool of C++ threads"
//! — here, a shared pool of Rust threads (`pool::WorkerPool`).
//!
//! Observations are flat `f32` buffers written into caller-provided slices
//! (no allocation on the hot path); `atari_like` is the Atari substitute
//! (pixel rendering, frame stack, sticky actions, episodic lives).

pub mod atari_like;
pub mod batched;
pub mod cartpole;
pub mod catch;
pub mod chain;
pub mod gridworld;
pub mod pool;

pub use batched::{BatchedEnv, StepTicket};
pub use pool::{BatchTicket, WorkerPool};

pub use crate::experiment::EnvKind;

use crate::checkpoint::format::{SectionReader, SectionWriter};
use crate::util::rng::Xoshiro256;

/// One transition's results (the observation is written separately).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepResult {
    pub reward: f32,
    /// True if this step *ended* an episode (the returned observation is
    /// then the first observation of a fresh episode — auto-reset).
    pub done: bool,
}

/// A host-side environment. Implementations must be deterministic given the
/// RNG stream passed at construction.
pub trait Environment: Send {
    /// Flat observation size (what the exported programs expect).
    fn obs_dim(&self) -> usize;
    fn num_actions(&self) -> usize;

    /// Start a new episode; write the initial observation into `obs`.
    fn reset(&mut self, obs: &mut [f32]);

    /// Step with `action`; write the *next* observation into `obs`
    /// (auto-reset: on `done`, `obs` is the fresh episode's first frame).
    fn step(&mut self, action: usize, obs: &mut [f32]) -> StepResult;

    /// Serialize the complete mutable state — positions, counters and the
    /// RNG stream — so a checkpointed run continues bit-identically
    /// (DESIGN.md §13). Construction-time constants (grid sizes, horizons)
    /// are not stored; they come from the env being restored into.
    fn save_state(&self) -> Vec<u8>;

    /// Restore a [`Self::save_state`] snapshot taken from an
    /// identically-configured environment. Corrupt or out-of-range payloads
    /// are typed errors, never panics and never a silent fresh reset.
    fn load_state(&mut self, state: &[u8]) -> anyhow::Result<()>;
}

/// Append an RNG's state words to an env snapshot.
pub(crate) fn write_rng(w: &mut SectionWriter, rng: &Xoshiro256) {
    w.put_u64s(&rng.state());
}

/// Read back an RNG written by [`write_rng`].
pub(crate) fn read_rng(r: &mut SectionReader) -> anyhow::Result<Xoshiro256> {
    let words = r.u64s()?;
    let state: [u64; 4] = words
        .as_slice()
        .try_into()
        .map_err(|_| anyhow::anyhow!("env rng state must be 4 words, got {}", words.len()))?;
    Ok(Xoshiro256::from_state(state))
}

fn build_env(kind: EnvKind, rng: Xoshiro256) -> Box<dyn Environment> {
    match kind {
        EnvKind::Catch => Box::new(catch::Catch::new(10, 5, rng)),
        EnvKind::Gridworld => Box::new(gridworld::GridWorld::new(8, 50, rng)),
        EnvKind::Cartpole => Box::new(cartpole::CartPole::new(rng)),
        EnvKind::Chain => Box::new(chain::Chain::new(10, rng)),
        EnvKind::AtariLike => {
            Box::new(atari_like::AtariLike::new(atari_like::Config::default(), rng))
        }
    }
}

/// Environment constructor by kind (used by the CLI and benches). The
/// typed [`EnvKind`] makes this infallible — unknown names fail earlier,
/// at `EnvKind::from_str`.
pub fn make_env(kind: EnvKind, seed: u64) -> Box<dyn Environment> {
    build_env(kind, Xoshiro256::from_stream(seed, 0x517))
}

/// The environment factory type used by `BatchedEnv` (one env per slot).
pub type EnvFactory = Box<dyn Fn(usize) -> Box<dyn Environment> + Send + Sync>;

/// Factory for `kind`, deriving each slot's RNG stream from `seed`.
/// Infallible by construction: the per-slot closure cannot panic inside a
/// worker thread on a bad kind, because bad kinds are unrepresentable.
pub fn make_factory(kind: EnvKind, seed: u64) -> EnvFactory {
    Box::new(move |slot| {
        let rng = Xoshiro256::from_stream(seed, 0x9E00 + slot as u64);
        build_env(kind, rng)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_kind_constructs() {
        for kind in EnvKind::ALL {
            let mut env = make_env(kind, 3);
            let mut obs = vec![0.0; env.obs_dim()];
            env.reset(&mut obs);
            let factory = make_factory(kind, 3);
            let env2 = factory(0);
            assert_eq!(env2.obs_dim(), env.obs_dim());
        }
    }

    #[test]
    fn unknown_kind_is_a_parse_error_not_a_default() {
        // the stringly path used to coerce unknowns to "catch" in the CLI;
        // the typed kind rejects them at the boundary
        assert!("nope".parse::<EnvKind>().is_err());
    }

    /// The checkpoint contract for every kind: snapshot mid-episode, keep
    /// stepping the original, load the snapshot into a *differently seeded*
    /// fresh env, and the continuations must match bit for bit.
    #[test]
    fn every_kind_state_roundtrips_bit_identically() {
        for kind in EnvKind::ALL {
            let mut a = make_env(kind, 11);
            let mut obs = vec![0.0; a.obs_dim()];
            a.reset(&mut obs);
            for i in 0..23 {
                a.step(i % a.num_actions(), &mut obs);
            }
            let snap = a.save_state();

            let mut b = make_env(kind, 999); // wrong seed on purpose
            b.load_state(&snap).unwrap_or_else(|e| panic!("{kind:?}: {e}"));

            let mut oa = vec![0.0; a.obs_dim()];
            let mut ob = vec![0.0; b.obs_dim()];
            for i in 0..200 {
                let ra = a.step(i % a.num_actions(), &mut oa);
                let rb = b.step(i % b.num_actions(), &mut ob);
                assert_eq!(ra, rb, "{kind:?} diverged at step {i}");
                assert_eq!(oa, ob, "{kind:?} obs diverged at step {i}");
            }
        }
    }

    #[test]
    fn env_load_state_rejects_garbage() {
        for kind in EnvKind::ALL {
            let mut env = make_env(kind, 3);
            assert!(env.load_state(&[0xFF; 3]).is_err(), "{kind:?} accepted garbage");
            assert!(env.load_state(&[]).is_err(), "{kind:?} accepted empty state");
        }
    }
}
