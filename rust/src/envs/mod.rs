//! Host-side environments for Sebulba (the paper's "arbitrary environments
//! that run on the CPU hosts").
//!
//! The substrate mirrors what the paper relies on: single environments with
//! a `reset/step` interface, and a *batched* environment (`BatchedEnv`) that
//! "is exposed ... as a single environment that takes a batch of actions and
//! returns a batch of observations; behind the scenes it steps each
//! environment in the batch in parallel using a shared pool of C++ threads"
//! — here, a shared pool of Rust threads (`pool::WorkerPool`).
//!
//! Observations are flat `f32` buffers written into caller-provided slices
//! (no allocation on the hot path); `atari_like` is the Atari substitute
//! (pixel rendering, frame stack, sticky actions, episodic lives).

pub mod atari_like;
pub mod batched;
pub mod cartpole;
pub mod catch;
pub mod chain;
pub mod gridworld;
pub mod pool;

pub use batched::{BatchedEnv, StepTicket};
pub use pool::{BatchTicket, WorkerPool};

use crate::util::rng::Xoshiro256;

/// One transition's results (the observation is written separately).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepResult {
    pub reward: f32,
    /// True if this step *ended* an episode (the returned observation is
    /// then the first observation of a fresh episode — auto-reset).
    pub done: bool,
}

/// A host-side environment. Implementations must be deterministic given the
/// RNG stream passed at construction.
pub trait Environment: Send {
    /// Flat observation size (what the exported programs expect).
    fn obs_dim(&self) -> usize;
    fn num_actions(&self) -> usize;

    /// Start a new episode; write the initial observation into `obs`.
    fn reset(&mut self, obs: &mut [f32]);

    /// Step with `action`; write the *next* observation into `obs`
    /// (auto-reset: on `done`, `obs` is the fresh episode's first frame).
    fn step(&mut self, action: usize, obs: &mut [f32]) -> StepResult;
}

/// Every environment kind `make_env`/`make_factory` accepts (what the CLI,
/// config validation and benches enumerate).
pub const ENV_KINDS: &[&str] = &["catch", "gridworld", "cartpole", "chain", "atari_like"];

/// Fail fast on an unknown environment kind — `SebulbaConfig::validate`
/// calls this so a typo'd `--env` errors at config time instead of
/// panicking inside a worker thread.
pub fn validate_kind(kind: &str) -> anyhow::Result<()> {
    if ENV_KINDS.contains(&kind) {
        Ok(())
    } else {
        anyhow::bail!("unknown environment {kind:?} (known: {ENV_KINDS:?})")
    }
}

fn build_env(kind: &str, rng: Xoshiro256) -> Option<Box<dyn Environment>> {
    Some(match kind {
        "catch" => Box::new(catch::Catch::new(10, 5, rng)),
        "gridworld" => Box::new(gridworld::GridWorld::new(8, 50, rng)),
        "cartpole" => Box::new(cartpole::CartPole::new(rng)),
        "chain" => Box::new(chain::Chain::new(10, rng)),
        "atari_like" => Box::new(atari_like::AtariLike::new(
            atari_like::Config::default(),
            rng,
        )),
        _ => return None,
    })
}

/// Environment constructors by name (used by the CLI and benches).
pub fn make_env(kind: &str, seed: u64) -> anyhow::Result<Box<dyn Environment>> {
    let rng = Xoshiro256::from_stream(seed, 0x517);
    build_env(kind, rng).ok_or_else(|| anyhow::anyhow!("unknown environment {kind:?} (known: {ENV_KINDS:?})"))
}

/// The environment factory type used by `BatchedEnv` (one env per slot).
pub type EnvFactory = Box<dyn Fn(usize) -> Box<dyn Environment> + Send + Sync>;

/// Factory for `kind`, deriving each slot's RNG stream from `seed`.
/// The kind is validated here, once, so the per-slot closure cannot panic
/// inside a worker thread.
pub fn make_factory(kind: &'static str, seed: u64) -> anyhow::Result<EnvFactory> {
    validate_kind(kind)?;
    Ok(Box::new(move |slot| {
        let rng = Xoshiro256::from_stream(seed, 0x9E00 + slot as u64);
        build_env(kind, rng).expect("kind validated at factory construction")
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_listed_kind_constructs() {
        for kind in ENV_KINDS {
            validate_kind(kind).unwrap();
            let mut env = make_env(kind, 3).unwrap();
            let mut obs = vec![0.0; env.obs_dim()];
            env.reset(&mut obs);
            let factory = make_factory(kind, 3).unwrap();
            let env2 = factory(0);
            assert_eq!(env2.obs_dim(), env.obs_dim());
        }
    }

    #[test]
    fn unknown_kind_is_an_error_not_a_panic() {
        assert!(validate_kind("nope").is_err());
        assert!(make_env("nope", 0).is_err());
        assert!(make_factory("nope", 0).is_err());
    }
}
