//! Payload codecs for the pod-to-pod frames: trajectory shard bundles and
//! versioned parameter snapshots (DESIGN.md §15).
//!
//! The trajectory codec preserves the arena's shard-major column layout
//! (DESIGN.md §11): a bundle is encoded as its geometry header followed by
//! the five whole columns, each written as one contiguous block, and
//! decoded by rebuilding an `Arc`-shared [`TrajArena`] with
//! [`TrajArena::from_columns`] and re-slicing it into zero-copy
//! [`TrajShard`] views — the receiving learner sees exactly the shards the
//! sending actor queued, without a per-step or per-shard copy on either
//! side.
//!
//! Decoding is hostile-input safe in the same way the checkpoint reader is:
//! every slice is length-prefixed, lengths are validated against the
//! remaining buffer before allocation, arena geometry is re-validated by
//! `from_columns`, and trailing bytes are rejected.

use std::sync::Arc;

use crate::coordinator::sharder;
use crate::coordinator::trajectory::{TrajArena, TrajShard};

use super::error::TransportError;

// -- primitive buffer accessors ----------------------------------------------

/// Accumulates one frame payload. Mirrors the checkpoint `SectionWriter`
/// but stays in the transport's error domain.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed `u64` slice (used for `obs_shape`).
    pub fn put_u64s(&mut self, vs: &[u64]) {
        self.put_u64(vs.len() as u64);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Length-prefixed `f32` column, written as one contiguous block.
    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.put_u64(vs.len() as u64);
        self.buf.reserve(vs.len() * 4);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Length-prefixed `i32` column, written as one contiguous block.
    pub fn put_i32s(&mut self, vs: &[i32]) {
        self.put_u64(vs.len() as u64);
        self.buf.reserve(vs.len() * 4);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Cursor over one frame payload with hostile-length guards: every length
/// prefix is validated against the remaining bytes *before* allocating.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
    context: &'static str,
}

impl<'a> WireReader<'a> {
    pub fn new(context: &'static str, buf: &'a [u8]) -> Self {
        Self { buf, pos: 0, context }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], TransportError> {
        if self.buf.len() - self.pos < n {
            return Err(TransportError::Truncated { context: self.context });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u64(&mut self) -> Result<u64, TransportError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `u64` that must fit a `usize` (geometry fields).
    pub fn dim(&mut self) -> Result<usize, TransportError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| TransportError::Corrupt {
            context: self.context,
            detail: format!("dimension {v} does not fit usize"),
        })
    }

    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize, TransportError> {
        let n = self.dim()?;
        match n.checked_mul(elem_bytes) {
            Some(total) if total <= self.buf.len() - self.pos => Ok(n),
            _ => Err(TransportError::Truncated { context: self.context }),
        }
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>, TransportError> {
        let n = self.len_prefix(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, TransportError> {
        let n = self.len_prefix(4)?;
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn i32s(&mut self) -> Result<Vec<i32>, TransportError> {
        let n = self.len_prefix(4)?;
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Every payload byte must be consumed — trailing bytes are a codec
    /// bug or corruption, never ignorable.
    pub fn done(&self) -> Result<(), TransportError> {
        if self.pos != self.buf.len() {
            return Err(TransportError::Corrupt {
                context: self.context,
                detail: format!("{} trailing bytes", self.buf.len() - self.pos),
            });
        }
        Ok(())
    }
}

// -- parameter snapshots ------------------------------------------------------

/// Encode a versioned parameter snapshot (learner → actor pods).
pub fn encode_params(version: u64, params: &[f32]) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.put_u64(version);
    w.put_f32s(params);
    w.finish()
}

/// Decode a versioned parameter snapshot.
pub fn decode_params(buf: &[u8]) -> Result<(u64, Vec<f32>), TransportError> {
    let mut r = WireReader::new("param-snapshot", buf);
    let version = r.u64()?;
    let params = r.f32s()?;
    r.done()?;
    Ok((version, params))
}

// -- elastic membership handshake ---------------------------------------------

/// Encode a `Join` request payload (actor → learner): the joiner's
/// topology fingerprint, so the learner can refuse a pod built from a
/// different geometry before admitting it into the data path.
pub fn encode_join(fingerprint: u64) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.put_u64(fingerprint);
    w.finish()
}

/// Decode a `Join` request payload.
pub fn decode_join(buf: &[u8]) -> Result<u64, TransportError> {
    let mut r = WireReader::new("join-request", buf);
    let fingerprint = r.u64()?;
    r.done()?;
    Ok(fingerprint)
}

/// What the learner grants an admitted pod: its membership identity. The
/// `Hello` reply to a `Join` carries this as `encode_admit` (the static
/// handshake keeps its original 8-byte pod-index payload, so the elastic
/// and static protocols stay byte-distinguishable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Admission {
    /// Monotone pod index — never reused across the run, so the actor-id
    /// range derived from it is never reused either.
    pub pod_index: usize,
    /// First actor id of this pod's id range (`pod_index * threads_per_pod`).
    pub actor_id_base: usize,
    /// Membership epoch at admission.
    pub epoch: u64,
    /// Beacon interval the learner expects; the actor sends `Heartbeat`
    /// at a fraction of this so one delayed beacon is not an eviction.
    pub heartbeat_ms: u64,
}

/// Encode an admission grant (learner → actor, `Hello` payload in elastic
/// mode).
pub fn encode_admit(a: &Admission) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.put_u64(a.pod_index as u64);
    w.put_u64(a.actor_id_base as u64);
    w.put_u64(a.epoch);
    w.put_u64(a.heartbeat_ms);
    w.finish()
}

/// Decode an admission grant.
pub fn decode_admit(buf: &[u8]) -> Result<Admission, TransportError> {
    let mut r = WireReader::new("admission", buf);
    let pod_index = r.dim()?;
    let actor_id_base = r.dim()?;
    let epoch = r.u64()?;
    let heartbeat_ms = r.u64()?;
    r.done()?;
    Ok(Admission { pod_index, actor_id_base, epoch, heartbeat_ms })
}

// -- trajectory bundles -------------------------------------------------------

/// Encode one actor window's shard bundle. The bundle must be the complete
/// shard set of one arena, in shard order — exactly what the actor's
/// `EnvPoolSource` pushes (`sharder::shard(&arena)`), so the whole window
/// serializes as five contiguous column writes.
pub fn encode_bundle(shards: &[TrajShard]) -> Result<Vec<u8>, TransportError> {
    let first = shards.first().ok_or(TransportError::Corrupt {
        context: "traj-bundle",
        detail: "empty shard bundle".to_string(),
    })?;
    let arena = first.arena();
    if shards.len() != arena.num_shards
        || shards
            .iter()
            .enumerate()
            .any(|(i, s)| s.index() != i || !Arc::ptr_eq(s.arena(), arena))
    {
        return Err(TransportError::Corrupt {
            context: "traj-bundle",
            detail: format!(
                "bundle of {} shards does not cover its {}-shard arena in order",
                shards.len(),
                arena.num_shards
            ),
        });
    }
    let mut w = WireWriter::new();
    w.put_u64(arena.t_len as u64);
    w.put_u64(arena.batch as u64);
    w.put_u64s(&arena.obs_shape.iter().map(|&d| d as u64).collect::<Vec<_>>());
    w.put_u64(arena.num_actions as u64);
    w.put_u64(arena.num_shards as u64);
    w.put_u64(arena.param_version);
    w.put_u64(arena.actor_id as u64);
    w.put_f32s(&arena.obs);
    w.put_i32s(&arena.actions);
    w.put_f32s(&arena.rewards);
    w.put_f32s(&arena.discounts);
    w.put_f32s(&arena.behaviour_logits);
    Ok(w.finish())
}

/// Decode a shard bundle: rebuild the `Arc`-shared arena (geometry
/// re-validated by [`TrajArena::from_columns`]) and re-slice it into its
/// zero-copy shard views.
pub fn decode_bundle(buf: &[u8]) -> Result<Vec<TrajShard>, TransportError> {
    let mut r = WireReader::new("traj-bundle", buf);
    let t_len = r.dim()?;
    let batch = r.dim()?;
    let obs_shape: Vec<usize> = {
        let dims = r.u64s()?;
        let mut out = Vec::with_capacity(dims.len());
        for d in dims {
            out.push(usize::try_from(d).map_err(|_| TransportError::Corrupt {
                context: "traj-bundle",
                detail: format!("obs dim {d} does not fit usize"),
            })?);
        }
        out
    };
    let num_actions = r.dim()?;
    let num_shards = r.dim()?;
    let param_version = r.u64()?;
    let actor_id = r.dim()?;
    let obs = r.f32s()?;
    let actions = r.i32s()?;
    let rewards = r.f32s()?;
    let discounts = r.f32s()?;
    let behaviour_logits = r.f32s()?;
    r.done()?;
    let arena = TrajArena::from_columns(
        t_len,
        batch,
        &obs_shape,
        num_actions,
        num_shards,
        obs,
        actions,
        rewards,
        discounts,
        behaviour_logits,
        param_version,
        actor_id,
    )
    .map_err(|e| TransportError::Corrupt {
        context: "traj-bundle",
        detail: format!("{e:#}"),
    })?;
    Ok(sharder::shard(&arena))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trajectory::TrajectoryBuilder;

    fn make_bundle(t: usize, b: usize, d: usize, a: usize, n: usize) -> Vec<TrajShard> {
        let mut builder = TrajectoryBuilder::new(t, b, &[d], a, n);
        for ti in 0..t {
            let obs: Vec<f32> = (0..b * d).map(|i| (ti * 100 + i) as f32 * 0.5).collect();
            let actions: Vec<i32> = (0..b).map(|i| (ti + i) as i32).collect();
            let logits: Vec<f32> = (0..b * a).map(|i| (ti * 3 + i) as f32 * 0.1).collect();
            let rewards: Vec<f32> = (0..b).map(|i| i as f32 - 1.0).collect();
            let discounts = vec![0.99; b];
            builder.push_step(&obs, &actions, &logits, &rewards, &discounts).unwrap();
        }
        let final_obs = vec![0.25; b * d];
        let arena = builder.finish(&final_obs, 7, 2).unwrap();
        sharder::shard(&arena)
    }

    #[test]
    fn bundle_roundtrips_bit_exactly() {
        let bundle = make_bundle(3, 6, 2, 3, 3);
        let bytes = encode_bundle(&bundle).unwrap();
        let back = decode_bundle(&bytes).unwrap();
        assert_eq!(back.len(), bundle.len());
        for (a, b) in bundle.iter().zip(&back) {
            assert_eq!(a.index(), b.index());
            assert_eq!(a.obs(), b.obs());
            assert_eq!(a.actions(), b.actions());
            assert_eq!(a.rewards(), b.rewards());
            assert_eq!(a.discounts(), b.discounts());
            assert_eq!(a.behaviour_logits(), b.behaviour_logits());
            assert_eq!(a.param_version(), b.param_version());
            assert_eq!(a.actor_id(), b.actor_id());
        }
        // the decoded shards share one rebuilt arena, zero-copy
        assert!(Arc::ptr_eq(back[0].arena(), back[1].arena()));
    }

    #[test]
    fn params_roundtrip() {
        let params: Vec<f32> = (0..100).map(|i| i as f32 * 0.125 - 3.0).collect();
        let bytes = encode_params(42, &params);
        let (v, back) = decode_params(&bytes).unwrap();
        assert_eq!(v, 42);
        assert_eq!(back, params);
    }

    #[test]
    fn join_and_admit_roundtrip_and_reject_truncation() {
        let bytes = encode_join(0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(decode_join(&bytes).unwrap(), 0xDEAD_BEEF_CAFE_F00D);
        assert!(matches!(
            decode_join(&bytes[..bytes.len() - 1]),
            Err(TransportError::Truncated { .. })
        ));
        let mut extra = bytes;
        extra.push(0);
        assert!(matches!(decode_join(&extra), Err(TransportError::Corrupt { .. })));

        let grant =
            Admission { pod_index: 7, actor_id_base: 14, epoch: 9, heartbeat_ms: 250 };
        let bytes = encode_admit(&grant);
        assert_eq!(decode_admit(&bytes).unwrap(), grant);
        assert!(matches!(
            decode_admit(&bytes[..bytes.len() - 3]),
            Err(TransportError::Truncated { .. })
        ));
        let mut extra = bytes;
        extra.push(1);
        assert!(matches!(decode_admit(&extra), Err(TransportError::Corrupt { .. })));
    }

    #[test]
    fn partial_or_reordered_bundles_are_rejected_at_encode() {
        let mut bundle = make_bundle(2, 4, 1, 2, 2);
        bundle.swap(0, 1);
        assert!(matches!(
            encode_bundle(&bundle),
            Err(TransportError::Corrupt { .. })
        ));
        let partial = make_bundle(2, 4, 1, 2, 2).split_off(1);
        assert!(matches!(
            encode_bundle(&partial),
            Err(TransportError::Corrupt { .. })
        ));
        assert!(encode_bundle(&[]).is_err());
    }

    #[test]
    fn inconsistent_geometry_is_a_typed_corrupt_error() {
        let bundle = make_bundle(2, 4, 1, 2, 2);
        let mut bytes = encode_bundle(&bundle).unwrap();
        // grow the declared batch: column sizes no longer match the geometry
        bytes[8..16].copy_from_slice(&8u64.to_le_bytes());
        assert!(matches!(
            decode_bundle(&bytes),
            Err(TransportError::Truncated { .. }) | Err(TransportError::Corrupt { .. })
        ));
    }

    #[test]
    fn trailing_payload_bytes_are_rejected() {
        let bundle = make_bundle(2, 4, 1, 2, 2);
        let mut bytes = encode_bundle(&bundle).unwrap();
        bytes.push(0xAB);
        assert!(matches!(
            decode_bundle(&bytes),
            Err(TransportError::Corrupt { .. })
        ));
    }
}
