//! The learner-side membership registry for elastic DistSebulba runs
//! (DESIGN.md §16). Pure bookkeeping — no connections, no threads — so the
//! epoch rules are unit- and property-testable in isolation:
//!
//! - the epoch counter is monotone: every admission and every departure
//!   bumps it by exactly one, and nothing else touches it;
//! - pod indices are monotone and never reused, so the actor-id range
//!   derived from an index (`pod_index * threads_per_pod ..`) is never
//!   reused either — shards from a dead pod's old ids can never be
//!   mistaken for a later joiner's;
//! - departure is idempotent per pod: departing a pod that already left
//!   (or never existed) is a no-op that does *not* bump the epoch, which
//!   lets the eviction monitor and the connection receiver race to retire
//!   the same member safely.

use std::collections::BTreeMap;

/// Why a member left. Carried through to the log line and (for evictions
/// below the floor) the fail-closed error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Departure {
    /// The pod sent a `Leave` frame: graceful, never trips fail-closed
    /// accounting differently — but the log distinguishes it.
    Leave,
    /// The learner gave up on the pod (missed heartbeats, dead
    /// connection, protocol violation).
    Evicted { reason: String },
}

/// One admitted pod's identity: everything the `Hello` admission grant
/// carries, plus the peer address for diagnostics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PodSlot {
    /// Monotone admission index — doubles as the pod's wire identity.
    pub pod_index: usize,
    /// Peer address as reported by the transport at accept time.
    pub peer: String,
    /// First actor id of this pod's range (`pod_index * threads_per_pod`).
    pub actor_id_base: usize,
    /// Membership epoch at the moment of admission.
    pub epoch_joined: u64,
}

/// The registry proper. Owned by the learner's control thread; data
/// threads see it behind a mutex.
#[derive(Debug)]
pub struct Membership {
    /// Actor threads per pod — the stride between consecutive pods'
    /// actor-id ranges.
    threads_per_pod: usize,
    epoch: u64,
    next_pod: usize,
    active: BTreeMap<usize, PodSlot>,
    joined: u64,
    departed: u64,
}

impl Membership {
    pub fn new(threads_per_pod: usize) -> Self {
        Self {
            threads_per_pod: threads_per_pod.max(1),
            epoch: 0,
            next_pod: 0,
            active: BTreeMap::new(),
            joined: 0,
            departed: 0,
        }
    }

    /// Admit a new pod: bump the epoch, hand out the next (never-reused)
    /// pod index and its actor-id range.
    pub fn admit(&mut self, peer: &str) -> PodSlot {
        self.epoch += 1;
        let pod_index = self.next_pod;
        self.next_pod += 1;
        self.joined += 1;
        let slot = PodSlot {
            pod_index,
            peer: peer.to_string(),
            actor_id_base: pod_index * self.threads_per_pod,
            epoch_joined: self.epoch,
        };
        self.active.insert(pod_index, slot.clone());
        slot
    }

    /// Retire a member: bump the epoch and return its slot. Idempotent —
    /// a pod that is not active is a no-op returning `None` (no epoch
    /// bump), so the monitor and a receiver can both report the same
    /// death.
    pub fn depart(&mut self, pod: usize, why: &Departure) -> Option<PodSlot> {
        let slot = self.active.remove(&pod)?;
        self.epoch += 1;
        self.departed += 1;
        match why {
            Departure::Leave => {
                log::info!("membership: pod {pod} ({}) left at epoch {}", slot.peer, self.epoch)
            }
            Departure::Evicted { reason } => log::warn!(
                "membership: pod {pod} ({}) evicted at epoch {}: {reason}",
                slot.peer,
                self.epoch
            ),
        }
        Some(slot)
    }

    /// Current epoch: bumped by every admission and every departure.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    pub fn is_active(&self, pod: usize) -> bool {
        self.active.contains_key(&pod)
    }

    /// Total pods ever admitted.
    pub fn joined(&self) -> u64 {
        self.joined
    }

    /// Total pods ever departed (Leave + evictions).
    pub fn departed(&self) -> u64 {
        self.departed
    }

    pub fn active(&self) -> impl Iterator<Item = &PodSlot> {
        self.active.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admissions_hand_out_monotone_ids_and_disjoint_ranges() {
        let mut m = Membership::new(3);
        let a = m.admit("pod-a");
        let b = m.admit("pod-b");
        assert_eq!((a.pod_index, a.actor_id_base, a.epoch_joined), (0, 0, 1));
        assert_eq!((b.pod_index, b.actor_id_base, b.epoch_joined), (1, 3, 2));
        assert_eq!(m.epoch(), 2);
        assert_eq!(m.active_count(), 2);
        assert_eq!(m.joined(), 2);
    }

    #[test]
    fn departures_bump_the_epoch_and_never_recycle_indices() {
        let mut m = Membership::new(2);
        let a = m.admit("pod-a");
        m.admit("pod-b");
        let gone = m.depart(a.pod_index, &Departure::Leave).unwrap();
        assert_eq!(gone.pod_index, 0);
        assert_eq!(m.epoch(), 3);
        assert!(!m.is_active(0));
        assert_eq!(m.departed(), 1);
        // the next joiner gets a fresh index past every previous one
        let c = m.admit("pod-c");
        assert_eq!(c.pod_index, 2);
        assert_eq!(c.actor_id_base, 4);
        assert_eq!(m.epoch(), 4);
    }

    #[test]
    fn departing_a_retired_or_unknown_pod_is_a_no_op() {
        let mut m = Membership::new(1);
        let a = m.admit("pod-a");
        assert!(m.depart(a.pod_index, &Departure::Evicted { reason: "t".into() }).is_some());
        let epoch = m.epoch();
        assert!(m.depart(a.pod_index, &Departure::Leave).is_none());
        assert!(m.depart(99, &Departure::Leave).is_none());
        assert_eq!(m.epoch(), epoch, "no-op departures must not bump the epoch");
    }
}
