//! The real-socket transport: TCP with length-prefixed CRC-framed messages
//! (DESIGN.md §15).
//!
//! Robustness contract: `connect` retries with linear backoff up to a
//! bounded attempt budget and returns a typed
//! [`TransportError::ConnectFailed`] / [`ConnectTimeout`] when the budget
//! is spent; every read carries the socket read timeout so a stalled peer
//! surfaces as [`TransportError::ReadTimeout`] instead of a hang; `accept`
//! polls a nonblocking listener against its own deadline for the same
//! reason.
//!
//! [`ConnectTimeout`]: TransportError::ConnectTimeout

use std::io::ErrorKind;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::error::TransportError;
use super::frame::{read_frame, write_frame, FrameKind};
use super::{ConnectOpts, Connection, Listener, Transport};

/// TCP transport. `read_timeout` applies to every `recv` on connections it
/// creates (both dialed and accepted); `accept_timeout` bounds how long a
/// listener waits for the next pod to arrive.
#[derive(Clone, Debug)]
pub struct TcpTransport {
    pub read_timeout: Duration,
    pub accept_timeout: Duration,
}

impl Default for TcpTransport {
    fn default() -> Self {
        Self {
            read_timeout: Duration::from_secs(5),
            accept_timeout: Duration::from_secs(30),
        }
    }
}

fn resolve(addr: &str) -> Result<SocketAddr, TransportError> {
    addr.to_socket_addrs()
        .map_err(|e| TransportError::ConnectFailed {
            addr: addr.to_string(),
            attempts: 0,
            last: format!("address did not resolve: {e}"),
        })?
        .next()
        .ok_or_else(|| TransportError::ConnectFailed {
            addr: addr.to_string(),
            attempts: 0,
            last: "address resolved to nothing".to_string(),
        })
}

impl Transport for TcpTransport {
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, TransportError> {
        let inner = TcpListener::bind(addr)?;
        // Nonblocking + poll: a plain `accept()` has no timeout, and "never
        // a hang" includes waiting for pods that will never come.
        inner.set_nonblocking(true)?;
        let local = inner.local_addr()?.to_string();
        Ok(Box::new(TcpPodListener {
            inner,
            local,
            read_timeout: self.read_timeout,
            accept_timeout: self.accept_timeout,
        }))
    }

    fn connect(
        &self,
        addr: &str,
        opts: &ConnectOpts,
    ) -> Result<Box<dyn Connection>, TransportError> {
        let sock = resolve(addr)?;
        let started = Instant::now();
        let mut last = String::new();
        for attempt in 1..=opts.attempts.max(1) {
            match TcpStream::connect_timeout(&sock, opts.connect_timeout) {
                Ok(stream) => return Ok(Box::new(TcpConn::new(stream, self.read_timeout)?)),
                Err(e) => {
                    if e.kind() == ErrorKind::TimedOut {
                        return Err(TransportError::ConnectTimeout {
                            addr: addr.to_string(),
                            waited: started.elapsed(),
                        });
                    }
                    last = e.to_string();
                }
            }
            if attempt < opts.attempts.max(1) {
                // Linear backoff keeps the total bounded and predictable:
                // sum = backoff * attempts * (attempts + 1) / 2.
                std::thread::sleep(opts.backoff * attempt);
            }
        }
        Err(TransportError::ConnectFailed {
            addr: addr.to_string(),
            attempts: opts.attempts.max(1),
            last,
        })
    }
}

struct TcpPodListener {
    inner: TcpListener,
    local: String,
    read_timeout: Duration,
    accept_timeout: Duration,
}

impl Listener for TcpPodListener {
    fn accept(&mut self) -> Result<Box<dyn Connection>, TransportError> {
        let deadline = Instant::now() + self.accept_timeout;
        loop {
            match self.inner.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    return Ok(Box::new(TcpConn::new(stream, self.read_timeout)?));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(TransportError::ReadTimeout { waited: self.accept_timeout });
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(TransportError::Io(e)),
            }
        }
    }

    fn local_addr(&self) -> String {
        self.local.clone()
    }
}

/// One framed TCP connection. Reader and writer halves are independently
/// locked clones of the same socket, so a receiver thread can block in
/// `recv` while the publisher thread `send`s.
struct TcpConn {
    read: Mutex<TcpStream>,
    write: Mutex<TcpStream>,
    peer: String,
    read_timeout: Duration,
}

impl TcpConn {
    fn new(stream: TcpStream, read_timeout: Duration) -> Result<Self, TransportError> {
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(read_timeout))?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".to_string());
        let read = stream.try_clone()?;
        Ok(Self {
            read: Mutex::new(read),
            write: Mutex::new(stream),
            peer,
            read_timeout,
        })
    }
}

impl Connection for TcpConn {
    fn send(&self, kind: FrameKind, payload: &[u8]) -> Result<u64, TransportError> {
        let mut w = self.write.lock().unwrap();
        write_frame(&mut *w, kind, payload)
    }

    fn recv(&self) -> Result<(FrameKind, Vec<u8>, u64), TransportError> {
        let mut r = self.read.lock().unwrap();
        read_frame(&mut *r).map_err(|e| match e {
            // stamp the configured window into the idle-timeout variant
            TransportError::ReadTimeout { .. } => {
                TransportError::ReadTimeout { waited: self.read_timeout }
            }
            other => other,
        })
    }

    fn close(&self) {
        if let Ok(w) = self.write.lock() {
            let _ = w.shutdown(Shutdown::Both);
        }
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}
