//! The typed wire-transport error. The contract mirrors the TensorBus
//! poisoning discipline (DESIGN.md §10) over the wire: every blocking call
//! has a timeout, every failure is a variant a caller can match on, and
//! nothing is silently dropped — a dead peer surfaces as `Closed` (or a
//! `ReadTimeout` if it stalled without closing), never as a hang.

use std::fmt;
use std::io;
use std::time::Duration;

/// Everything that can go wrong on the wire, as a typed error. Framing
/// variants (`BadMagic` … `CrcMismatch`) mirror [`CheckpointError`]'s
/// corruption taxonomy so the two decode paths fail the same way.
///
/// [`CheckpointError`]: crate::checkpoint::CheckpointError
#[derive(Debug)]
pub enum TransportError {
    /// An I/O error outside the timeout/close taxonomy below.
    Io(io::Error),
    /// Every connect attempt failed (refused, unreachable, …); carries the
    /// attempt count so "bounded retry" is visible in the message.
    ConnectFailed { addr: String, attempts: u32, last: String },
    /// The connect deadline elapsed before the peer accepted.
    ConnectTimeout { addr: String, waited: Duration },
    /// No frame arrived within the read timeout. Benign between frames
    /// (the receiver loop re-checks its stop flag and retries); fatal if
    /// the caller was owed a reply.
    ReadTimeout { waited: Duration },
    /// The peer closed the connection (clean EOF or reset).
    Closed,
    /// The byte stream ended inside a frame.
    Truncated { context: &'static str },
    /// The frame did not start with the wire magic — misaligned stream or
    /// a stranger on the port.
    BadMagic { found: [u8; 4] },
    /// A frame from a newer (or corrupted) wire format.
    UnsupportedVersion { found: u8 },
    /// An unknown frame kind byte.
    BadKind { found: u8 },
    /// Frame checksum mismatch: the payload was damaged in flight.
    CrcMismatch { stored: u32, computed: u32 },
    /// Declared payload length exceeds the sanity cap — a hostile or
    /// garbage length prefix must not drive allocation.
    FrameTooLarge { len: u64, max: u64 },
    /// The frame decoded but its payload is inconsistent (bad geometry,
    /// column size mismatch, trailing bytes, …).
    Corrupt { context: &'static str, detail: String },
    /// The peer broke the connection-setup protocol (wrong first frame,
    /// bad hello payload).
    Handshake { detail: String },
    /// A live peer vanished mid-run. The one constructor for every
    /// lost-pod path ([`TransportError::peer_lost`]) so the message always
    /// names both the pod index and the peer address.
    PeerLost { pod: usize, peer: String, detail: String },
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Io(e) => write!(f, "transport I/O error: {e}"),
            TransportError::ConnectFailed { addr, attempts, last } => write!(
                f,
                "connecting to {addr} failed after {attempts} attempts (last error: {last})"
            ),
            TransportError::ConnectTimeout { addr, waited } => {
                write!(f, "connecting to {addr} timed out after {waited:?}")
            }
            TransportError::ReadTimeout { waited } => {
                write!(f, "no frame within the {waited:?} read timeout")
            }
            TransportError::Closed => write!(f, "connection closed by peer"),
            TransportError::Truncated { context } => {
                write!(f, "stream ended inside a frame ({context})")
            }
            TransportError::BadMagic { found } => {
                write!(f, "bad frame magic {found:02x?} (misaligned stream?)")
            }
            TransportError::UnsupportedVersion { found } => {
                write!(f, "unsupported wire format version {found}")
            }
            TransportError::BadKind { found } => write!(f, "unknown frame kind {found:#04x}"),
            TransportError::CrcMismatch { stored, computed } => write!(
                f,
                "frame CRC mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            TransportError::FrameTooLarge { len, max } => {
                write!(f, "declared frame length {len} exceeds the {max}-byte cap")
            }
            TransportError::Corrupt { context, detail } => {
                write!(f, "corrupt {context} payload: {detail}")
            }
            TransportError::Handshake { detail } => write!(f, "handshake violation: {detail}"),
            TransportError::PeerLost { pod, peer, detail } => {
                write!(f, "lost actor pod {pod} at {peer}: {detail}")
            }
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for TransportError {
    fn from(e: io::Error) -> Self {
        TransportError::Io(e)
    }
}

impl TransportError {
    /// True for the one benign variant: an idle read window expiring. The
    /// receiver loops re-check their stop flag on this and retry; every
    /// other variant is a real failure.
    pub fn is_idle_timeout(&self) -> bool {
        matches!(self, TransportError::ReadTimeout { .. })
    }

    /// True when the peer is gone (clean close or reset) — the expected
    /// end-of-run signal after a shutdown frame.
    pub fn is_closed(&self) -> bool {
        matches!(self, TransportError::Closed)
    }

    /// The unified lost-peer constructor: every path that loses a live pod
    /// mid-run goes through here so the diagnostic always carries both the
    /// pod index and the peer address (ISSUE 9 satellite — some paths used
    /// to name only the pod).
    pub fn peer_lost(pod: usize, peer: impl Into<String>, detail: impl fmt::Display) -> Self {
        TransportError::PeerLost { pod, peer: peer.into(), detail: detail.to_string() }
    }
}
