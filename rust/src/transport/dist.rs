//! Multi-pod Sebulba: one experiment as a learner pod plus K actor-pod
//! processes, glued by the [`Transport`] seam (DESIGN.md §15), with an
//! optional epoch-based elastic membership control plane (DESIGN.md §16).
//!
//! The decomposition keeps the in-memory coordinator's parts and replaces
//! exactly one seam with the wire:
//!
//! ```text
//!   actor pod k                              learner pod
//!   ┌──────────────────────────┐             ┌───────────────────────────┐
//!   │ actor threads → queue ───┼─ TrajBundle ┼→ receiver k → queue       │
//!   │       ▲                  │   frames    │     (one per actor pod)   │
//!   │  ParamStore ← subscriber ┼←─ Params ───┼─ publisher ← ParamStore   │
//!   └──────────────────────────┘   frames    │       ▲                   │
//!                                            │  learner thread (grad →   │
//!                                            │  reduce → apply → publish)│
//!                                            └───────────────────────────┘
//! ```
//!
//! * Actor pods run the unmodified [`spawn_actor`] threads against a local
//!   [`BoundedQueue`]; a forwarder thread drains it and ships each
//!   [`ShardBundle`] as one `TrajBundle` frame (shard-major columns,
//!   [`super::wire`]).
//! * The learner pod runs the unmodified [`learner_main`] (via the guarded
//!   spawn) against its local queue; per-connection receiver threads feed
//!   it, and a publisher thread broadcasts every published parameter
//!   version as a `Params` frame ([`ParamStore::wait_newer`] pub/sub).
//! * **Static handshake** (the default): the learner accepts exactly
//!   `actor_pods` connections and greets each with a `Hello` frame
//!   (payload: the pod's index, u64 LE) followed by one `Params` frame
//!   carrying the version-0 snapshot — every pod starts from bit-identical
//!   parameters, which is what makes the two-process `updates=1` run
//!   bit-identical to the in-memory one (the oracle in
//!   `rust/tests/transport.rs`).
//! * **Elastic handshake** (`--elastic`): the actor speaks first with a
//!   `Join` frame carrying its topology fingerprint; the learner's control
//!   thread verifies it, admits the pod through the [`Membership`]
//!   registry (monotone epoch, never-reused pod indices and actor-id
//!   ranges) and replies `Hello` carrying the [`Admission`] grant plus a
//!   `Params` frame with the *current* snapshot — a late joiner starts
//!   from the newest published version, not v0. Actors beacon `Heartbeat`
//!   frames; a monitor thread evicts members whose beacon goes quiet, and
//!   the run fails closed the moment active membership drops below
//!   `--min-actor-pods`. With membership that happens to never change, the
//!   elastic run is bit-identical to the static one: the first pod is
//!   always admitted before the learner can finish update 1 (no data can
//!   arrive before an admission), so it is seeded with version 0 exactly
//!   like the static greeting.
//! * Teardown: whoever stops first says so. The learner broadcasts a
//!   `Shutdown` frame when its update budget is spent; an actor pod whose
//!   threads die sends `Shutdown` up (or `Leave`, if it is departing
//!   gracefully) so the learner is never left waiting on a producer that
//!   will not come back. A connection that drops without the frame is a
//!   surfaced [`TransportError::peer_lost`] error, never a silent stall —
//!   the TensorBus poisoning discipline (DESIGN.md §10) extended over the
//!   wire.
//!
//! Distributed runs deliberately mirror the in-memory coordinator's plain
//! path only: `replicas == 1` per pod, and checkpoint/restore specs are
//! rejected with a typed error rather than half-honoured. Fault plans are
//! accepted only on elastic runs and only for pod-level faults
//! (kill/hang/leave/delayed-join) — thread-level faults still need the
//! single-process lockstep machinery of DESIGN.md §13.
//!
//! [`learner_main`]: crate::coordinator::learner

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::actor::{spawn_actor, ActorConfig, ShardBundle};
use crate::coordinator::collective::GradientBus;
use crate::coordinator::learner::{LearnerConfig, LearnerHandles};
use crate::coordinator::param_store::{ParamStore, SubscriberSet};
use crate::coordinator::queue::BoundedQueue;
use crate::coordinator::sebulba::{join_pod_threads, spawn_guarded_learner, Sebulba};
use crate::coordinator::stats::RunStats;
use crate::coordinator::SebulbaConfig;
use crate::envs::{make_factory, EnvFactory, WorkerPool};
use crate::experiment::{
    ActorLearnerDetail, Arch, Detail, PodRole, Report, RunSpec, Runner, Topology,
};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{DeviceHandle, Pod};
use crate::testkit::FaultPlan;

use super::error::TransportError;
use super::frame::FrameKind;
use super::membership::{Departure, Membership, PodSlot};
use super::tcp::TcpTransport;
use super::wire::{
    decode_admit, decode_bundle, decode_join, decode_params, encode_admit, encode_bundle,
    encode_join, encode_params, Admission,
};
use super::{ConnectOpts, Connection, Transport};

/// How long the learner-side publisher parks in [`ParamStore::wait_newer`]
/// per wait: long enough to sleep between updates, short enough to notice
/// the stop flag promptly at teardown.
const PUBLISH_POLL: Duration = Duration::from_millis(50);

/// How long a joining actor pod waits for its admission grant. Much longer
/// than the per-read idle timeout because the learner may legitimately
/// park a join (the control thread is busy, or a delayed-admission fault
/// is staged); the actor keeps re-arming idle timeouts until this budget
/// is spent.
const JOIN_REPLY_TIMEOUT: Duration = Duration::from_secs(120);

/// FNV-1a over the geometry fields that must agree between a joiner and
/// the learner for the joiner's shards to be usable. A mismatched
/// fingerprint is rejected at admission — before the pod can feed the
/// learner garbage-shaped bundles.
fn topology_fingerprint(cfg: &SebulbaConfig) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in [
        cfg.actor_cores as u64,
        cfg.learner_cores as u64,
        cfg.threads_per_actor_core as u64,
        cfg.actor_batch as u64,
        cfg.pipeline_stages as u64,
        cfg.unroll as u64,
        cfg.micro_batches as u64,
        cfg.total_updates as u64,
        cfg.seed as u64,
    ] {
        h ^= v;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Everything the learner's control plane tracks per run, behind one lock
/// so admission, eviction and heartbeat stamping see a consistent view.
struct PlaneInner {
    membership: Membership,
    conns: BTreeMap<usize, Arc<dyn Connection>>,
    last_heard: BTreeMap<usize, Instant>,
}

/// The learner-side elastic control plane: the [`Membership`] registry,
/// the live connections keyed by pod index, heartbeat stamps, and the
/// epoch-aware [`SubscriberSet`] the publisher broadcasts to. Shared by
/// the control thread (admissions), the monitor thread (evictions), the
/// per-pod receivers (departures) and the publisher (broadcast targets).
struct ControlPlane {
    inner: Mutex<PlaneInner>,
    subscribers: SubscriberSet,
    stats: Arc<RunStats>,
}

impl ControlPlane {
    fn new(threads_per_pod: usize, stats: Arc<RunStats>) -> Self {
        Self {
            inner: Mutex::new(PlaneInner {
                membership: Membership::new(threads_per_pod),
                conns: BTreeMap::new(),
                last_heard: BTreeMap::new(),
            }),
            subscribers: SubscriberSet::new(),
            stats,
        }
    }

    /// Admit a joiner: registry entry, live connection, heartbeat stamp,
    /// publisher subscription, stats — atomically under the plane lock.
    fn admit(&self, peer: &str, conn: Arc<dyn Connection>) -> PodSlot {
        let mut g = self.inner.lock().unwrap();
        let slot = g.membership.admit(peer);
        g.conns.insert(slot.pod_index, conn);
        g.last_heard.insert(slot.pod_index, Instant::now());
        self.subscribers.register(slot.pod_index, slot.epoch_joined);
        self.stats.record_membership(
            g.membership.joined(),
            g.membership.departed(),
            g.membership.epoch(),
        );
        slot
    }

    /// Retire a member; returns its slot and how many pods remain active.
    /// Idempotent (the monitor and a receiver can race to report the same
    /// death), and closes the connection *outside* the lock.
    fn depart(&self, pod: usize, why: &Departure) -> Option<(PodSlot, usize)> {
        let (slot, conn, remaining) = {
            let mut g = self.inner.lock().unwrap();
            let slot = g.membership.depart(pod, why)?;
            let conn = g.conns.remove(&pod);
            g.last_heard.remove(&pod);
            self.subscribers.retire(pod);
            self.stats.record_membership(
                g.membership.joined(),
                g.membership.departed(),
                g.membership.epoch(),
            );
            (slot, conn, g.membership.active_count())
        };
        if let Some(c) = conn {
            c.close();
        }
        Some((slot, remaining))
    }

    /// Stamp a liveness signal (any frame counts, not just heartbeats).
    fn heard(&self, pod: usize) {
        let mut g = self.inner.lock().unwrap();
        if let Some(t) = g.last_heard.get_mut(&pod) {
            *t = Instant::now();
        }
    }

    /// Members whose last signal is older than `timeout`.
    fn overdue(&self, timeout: Duration) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        let now = Instant::now();
        g.last_heard
            .iter()
            .filter(|(_, t)| now.duration_since(**t) > timeout)
            .map(|(p, _)| *p)
            .collect()
    }

    /// Total pods ever admitted.
    fn joined(&self) -> u64 {
        self.inner.lock().unwrap().membership.joined()
    }

    /// Snapshot of the live broadcast fan-out — taken so the publisher
    /// never sends while holding the plane lock.
    fn broadcast_targets(&self) -> Vec<(usize, Arc<dyn Connection>)> {
        let g = self.inner.lock().unwrap();
        self.subscribers
            .active()
            .into_iter()
            .filter_map(|p| g.conns.get(&p).map(|c| (p, c.clone())))
            .collect()
    }

    /// Take every remaining connection (final teardown).
    fn drain_conns(&self) -> Vec<Arc<dyn Connection>> {
        let mut g = self.inner.lock().unwrap();
        std::mem::take(&mut g.conns).into_values().collect()
    }
}

/// Fail closed: if a departure dropped active membership below the
/// `--min-actor-pods` floor, surface a peer-lost error naming the pod and
/// stop the run. Above the floor the run degrades gracefully and this is
/// a no-op.
fn enforce_floor(
    slot: &PodSlot,
    active: usize,
    min_pods: usize,
    detail: &str,
    wire_errs: &Mutex<Vec<String>>,
    stop: &AtomicBool,
    queue: &BoundedQueue<ShardBundle>,
) {
    if active >= min_pods || stop.load(Ordering::Relaxed) {
        return;
    }
    wire_errs.lock().unwrap().push(
        TransportError::peer_lost(
            slot.pod_index,
            slot.peer.clone(),
            format!(
                "{detail}; {active} active pod(s) is below the --min-actor-pods \
                 floor of {min_pods}"
            ),
        )
        .to_string(),
    );
    stop.store(true, Ordering::Relaxed);
    queue.shutdown();
}

/// The elastic per-member receiver: drains one admitted pod's frames into
/// the learner queue, stamps liveness, and retires the member on `Leave`,
/// protocol violation or connection loss — enforcing the membership floor
/// on every departure.
#[allow(clippy::too_many_arguments)]
fn spawn_elastic_receiver(
    slot: PodSlot,
    conn: Arc<dyn Connection>,
    plane: Arc<ControlPlane>,
    queue: Arc<BoundedQueue<ShardBundle>>,
    stop: Arc<AtomicBool>,
    stats: Arc<RunStats>,
    wire_errs: Arc<Mutex<Vec<String>>>,
    min_pods: usize,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("dist-recv-{}", slot.pod_index))
        .spawn(move || {
            let pod = slot.pod_index;
            let retire = |why: Departure, detail: &str| {
                if let Some((gone, active)) = plane.depart(pod, &why) {
                    enforce_floor(&gone, active, min_pods, detail, &wire_errs, &stop, &queue);
                }
            };
            loop {
                match conn.recv() {
                    Ok((FrameKind::TrajBundle, payload, n)) => {
                        stats.record_wire_rx(n);
                        plane.heard(pod);
                        match decode_bundle(&payload) {
                            Ok(shards) => {
                                if let Some(first) = shards.first() {
                                    stats.env_frames.add(first.arena().frames() as u64);
                                    stats.trajectories.fetch_add(1, Ordering::Relaxed);
                                }
                                if queue.push(shards).is_err() {
                                    return; // queue shut: learner done
                                }
                            }
                            Err(e) => {
                                let why = format!("bad trajectory frame: {e}");
                                retire(Departure::Evicted { reason: why.clone() }, &why);
                                return;
                            }
                        }
                    }
                    Ok((FrameKind::Heartbeat, _, n)) => {
                        stats.record_wire_rx(n);
                        plane.heard(pod);
                    }
                    Ok((FrameKind::Leave, _, n)) => {
                        stats.record_wire_rx(n);
                        retire(Departure::Leave, "left gracefully");
                        return;
                    }
                    Ok((FrameKind::Shutdown, _, n)) => {
                        stats.record_wire_rx(n);
                        if !stop.load(Ordering::Relaxed) {
                            let why = "shut down mid-run".to_string();
                            retire(Departure::Evicted { reason: why.clone() }, &why);
                        }
                        return;
                    }
                    Ok((kind, _, n)) => {
                        stats.record_wire_rx(n);
                        let why = format!("unexpected {kind:?} frame");
                        retire(Departure::Evicted { reason: why.clone() }, &why);
                        return;
                    }
                    Err(e) if e.is_idle_timeout() => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                    }
                    Err(e) => {
                        if !(stop.load(Ordering::Relaxed) && e.is_closed()) {
                            let why = format!("connection lost: {e}");
                            retire(Departure::Evicted { reason: why.clone() }, &why);
                        }
                        return;
                    }
                }
            }
        })
        .expect("spawn dist receiver")
}

/// Receive with patience: keep re-arming the transport's idle timeout
/// until `patience` is spent. The admission reply can legitimately take
/// much longer than one read window (a parked join), and that must not
/// surface as a dead learner.
fn recv_admission(
    conn: &dyn Connection,
    patience: Duration,
) -> Result<(FrameKind, Vec<u8>, u64), TransportError> {
    let start = Instant::now();
    loop {
        match conn.recv() {
            Err(e) if e.is_idle_timeout() && start.elapsed() < patience => continue,
            other => return other,
        }
    }
}

/// One Sebulba experiment split across processes: a learner pod (listens,
/// learns, publishes params) or an actor pod (connects, acts, ships
/// trajectories), depending on [`PodRole`]. Both sides are handed the same
/// workload + topology, so the geometry (shard counts, batch shapes,
/// program names) agrees by construction.
pub struct DistSebulba {
    /// The workload — identical on every pod of the experiment.
    pub workload: Sebulba,
    /// Which half of the experiment this process runs.
    pub role: PodRole,
    /// Learner role: address to listen on (e.g. `127.0.0.1:7070`).
    pub listen: String,
    /// Actor role: the learner pod's address to connect to.
    pub connect: String,
    /// Static learner role: how many actor pods to accept before training
    /// starts. Ignored by elastic runs, where membership is dynamic.
    pub actor_pods: usize,
    /// The pipe. Defaults to [`TcpTransport`]; tests inject
    /// [`super::LoopbackTransport`] to run all pods in one process.
    pub transport: Arc<dyn Transport>,
    /// Dial budget for the actor role (bounded retry + backoff).
    pub connect_opts: ConnectOpts,
    /// Epoch-based membership (DESIGN.md §16): pods join and leave mid-run
    /// instead of being fixed at startup.
    pub elastic: bool,
    /// Elastic learner: fail closed the moment active membership drops
    /// below this floor.
    pub min_actor_pods: usize,
    /// Elastic: the heartbeat window. Actors beacon at a third of it; the
    /// learner evicts a member silent for longer than the whole window.
    pub heartbeat: Duration,
}

impl DistSebulba {
    /// The learner pod of an experiment with `actor_pods` actor pods.
    pub fn learner(workload: Sebulba, listen: &str, actor_pods: usize) -> Self {
        Self {
            workload,
            role: PodRole::Learner,
            listen: listen.to_string(),
            connect: String::new(),
            actor_pods,
            transport: Arc::new(TcpTransport::default()),
            connect_opts: ConnectOpts::default(),
            elastic: false,
            min_actor_pods: 1,
            heartbeat: Duration::from_millis(1000),
        }
    }

    /// One actor pod, dialing the learner at `connect`.
    pub fn actor(workload: Sebulba, connect: &str) -> Self {
        Self {
            workload,
            role: PodRole::Actor,
            listen: String::new(),
            connect: connect.to_string(),
            actor_pods: 0,
            transport: Arc::new(TcpTransport::default()),
            connect_opts: ConnectOpts::default(),
            elastic: false,
            min_actor_pods: 1,
            heartbeat: Duration::from_millis(1000),
        }
    }

    /// Swap the pipe (tests: loopback; production: TCP, the default).
    pub fn with_transport(mut self, transport: Arc<dyn Transport>) -> Self {
        self.transport = transport;
        self
    }

    /// Switch this pod to elastic membership: joins are accepted whenever
    /// they arrive, departures are tolerated down to `min_actor_pods`, and
    /// liveness is policed by `heartbeat`.
    pub fn with_elastic(mut self, min_actor_pods: usize, heartbeat: Duration) -> Self {
        self.elastic = true;
        self.min_actor_pods = min_actor_pods;
        self.heartbeat = heartbeat;
        self
    }

    fn resolved(&self, topo: &Topology) -> Result<SebulbaConfig> {
        let cfg = self.workload.resolved(topo);
        cfg.validate()?;
        ensure!(
            cfg.replicas == 1,
            "distributed runs need replicas == 1 per pod (got {}); scale out \
             with more actor pods instead",
            cfg.replicas
        );
        Ok(cfg)
    }

    /// Learner-pod setup shared by the static and elastic paths: programs,
    /// initial params/optimiser state, and the busy-time baseline.
    fn learner_setup(
        &self,
        pod: &mut Pod,
        cfg: &SebulbaConfig,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f64>)> {
        let grad = cfg.grad_program();
        let apply = cfg.apply_program();
        let init = cfg.init_program();
        let learner_ids: Vec<usize> = (0..cfg.learner_cores).collect();
        pod.load_program(&grad, &learner_ids).with_context(|| format!("loading {grad}"))?;
        pod.load_program(&apply, &[0])?;
        pod.load_program(&init, &[0])?;

        let busy0: Vec<f64> = (0..cfg.learner_cores)
            .map(|cid| Ok(pod.core(cid)?.busy_seconds()))
            .collect::<Result<_>>()?;

        let (params0, opt0) = match self.workload.warm_start.clone() {
            Some((p, o)) => (p, o),
            None => {
                let outs = pod
                    .core(0)?
                    .execute(&init, vec![HostTensor::scalar_i32(cfg.seed as i32)])?;
                (outs[0].clone().into_f32()?, outs[1].clone().into_f32()?)
            }
        };
        Ok((params0, opt0, busy0))
    }

    /// The learner-pod report, assembled identically by both paths.
    #[allow(clippy::too_many_arguments)]
    fn learner_report(
        pod: &mut Pod,
        cfg: &SebulbaConfig,
        stats: &RunStats,
        queue: &BoundedQueue<ShardBundle>,
        busy0: &[f64],
        t_start: Instant,
        final_params: Vec<f32>,
        final_opt_state: Vec<f32>,
    ) -> Result<Report> {
        let elapsed = t_start.elapsed().as_secs_f64();
        let mut learner_busy = 0.0;
        let mut critical_path: f64 = 1e-12;
        for cid in 0..cfg.learner_cores {
            let busy = pod.core(cid)?.busy_seconds() - busy0[cid];
            learner_busy += busy;
            critical_path = critical_path.max(busy);
        }
        critical_path = critical_path.max(stats.learner_active_max_seconds());
        let frames = stats.env_frames.frames();
        log::info!("dist-learner done: {}", stats.summary());
        Ok(Report {
            arch: Arch::Sebulba,
            steps: frames,
            updates: stats.updates.load(Ordering::Relaxed),
            elapsed,
            throughput: frames as f64 / elapsed.max(1e-12),
            projected_throughput: frames as f64 / critical_path,
            final_params,
            detail: Detail::ActorLearner(ActorLearnerDetail {
                mean_staleness: stats.mean_staleness(),
                mean_episode_reward: stats.mean_episode_reward(),
                episodes: stats.episodes.load(Ordering::Relaxed),
                last_loss: stats.last_loss(),
                // the acting half lives in other processes; its busy time
                // is reported by the actor pods themselves
                actor_busy_seconds: 0.0,
                learner_busy_seconds: learner_busy,
                actor_infer_seconds: 0.0,
                actor_env_step_seconds: 0.0,
                actor_loop_seconds: 0.0,
                actor_overlap_seconds: 0.0,
                learner_grad_seconds: stats.learner_grad_seconds(),
                learner_collective_seconds: stats.learner_collective_seconds(),
                learner_apply_seconds: stats.learner_apply_seconds(),
                learner_active_seconds: stats.learner_active_seconds(),
                learner_overlap_seconds: stats.learner_overlap_seconds(),
                queue_push_block_seconds: queue.push_block_seconds(),
                queue_pop_block_seconds: queue.pop_block_seconds(),
                infer_calls: stats.infer_calls(),
                grad_calls: stats.grad_calls(),
                apply_calls: stats.apply_calls(),
                env_step_calls: stats.env_step_calls(),
                pods_joined: stats.pods_joined.load(Ordering::Relaxed),
                pods_evicted: stats.pods_evicted.load(Ordering::Relaxed),
                membership_epoch: stats.membership_epoch.load(Ordering::Relaxed),
                join_param_version: 0,
                final_opt_state,
            }),
        })
    }

    /// Resolve the learner's verdict against the wire log, lost-peer
    /// context first: a learner that died because the floor was breached
    /// should say which pod was lost, not just "queue shut down".
    fn resolve_learner_errors(
        learner_res: Result<Option<(Vec<f32>, Vec<f32>)>>,
        wire_errs: &Mutex<Vec<String>>,
        params0: Vec<f32>,
        opt0: Vec<f32>,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        {
            let errs = wire_errs.lock().unwrap();
            if !errs.is_empty() {
                let msg = format!(
                    "distributed run lost {} actor pod(s): {}",
                    errs.len(),
                    errs.join("; ")
                );
                return Err(match learner_res {
                    Err(le) => le.context(msg),
                    Ok(_) => anyhow!(msg),
                });
            }
        }
        Ok(match learner_res? {
            Some(out) => out,
            None => (params0, opt0),
        })
    }

    // ---- learner pod (static membership) ---------------------------------

    fn run_learner_pod(&self, pod: &mut Pod, topo: &Topology) -> Result<Report> {
        let cfg = self.resolved(topo)?;
        topo.validate_for_role(PodRole::Learner, pod.n_cores())?;
        ensure!(self.actor_pods >= 1, "learner pod needs at least one actor pod");
        ensure!(!self.listen.is_empty(), "learner pod needs a listen address");

        let (params0, opt0, busy0) = self.learner_setup(pod, &cfg)?;

        let stats = Arc::new(RunStats::new());
        let stop = Arc::new(AtomicBool::new(false));
        let bus = Arc::new(GradientBus::new(1));
        let store = Arc::new(ParamStore::new(params0.clone()));
        let queue = Arc::new(BoundedQueue::<ShardBundle>::new(cfg.queue_capacity));
        let queues = vec![queue.clone()];

        // ---- accept + handshake ------------------------------------------
        let mut listener = self
            .transport
            .listen(&self.listen)
            .with_context(|| format!("listening on {}", self.listen))?;
        log::info!(
            "dist-learner[{}]: listening on {}, waiting for {} actor pod(s)",
            cfg.agent,
            listener.local_addr(),
            self.actor_pods
        );
        let hello0 = encode_params(store.version(), &params0);
        let mut conns: Vec<Arc<dyn Connection>> = Vec::with_capacity(self.actor_pods);
        for pod_index in 0..self.actor_pods {
            let conn: Arc<dyn Connection> = Arc::from(
                listener
                    .accept()
                    .with_context(|| format!("waiting for actor pod {pod_index}"))?,
            );
            // Hello stamps the pod's index (actor ids and RNG streams derive
            // from it); the initial Params frame makes every pod start from
            // bit-identical version-0 parameters.
            let n = conn
                .send(FrameKind::Hello, &(pod_index as u64).to_le_bytes())
                .with_context(|| format!("greeting actor pod {pod_index}"))?;
            stats.record_wire_tx(n);
            let n = conn
                .send(FrameKind::Params, &hello0)
                .with_context(|| format!("seeding actor pod {pod_index} with params"))?;
            stats.record_wire_tx(n);
            log::info!("dist-learner: actor pod {pod_index} joined from {}", conn.peer());
            conns.push(conn);
        }

        // ---- per-connection receivers ------------------------------------
        // Any exit before the stop flag is set means that pod will never
        // produce again: surface it and shut the queue so the learner
        // drains instead of waiting forever ("never a silent drop").
        let wire_errs: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let mut recv_joins = Vec::with_capacity(conns.len());
        for (i, conn) in conns.iter().enumerate() {
            let conn = conn.clone();
            let queue = queue.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            let errs = wire_errs.clone();
            recv_joins.push(
                std::thread::Builder::new()
                    .name(format!("dist-recv-{i}"))
                    .spawn(move || {
                        let fail = |detail: String| {
                            errs.lock()
                                .unwrap()
                                .push(TransportError::peer_lost(i, conn.peer(), detail).to_string());
                            stop.store(true, Ordering::Relaxed);
                            queue.shutdown();
                        };
                        loop {
                            match conn.recv() {
                                Ok((FrameKind::TrajBundle, payload, n)) => {
                                    stats.record_wire_rx(n);
                                    let shards = match decode_bundle(&payload) {
                                        Ok(s) => s,
                                        Err(e) => {
                                            fail(format!("bad trajectory frame: {e}"));
                                            return;
                                        }
                                    };
                                    if let Some(first) = shards.first() {
                                        stats.env_frames.add(first.arena().frames() as u64);
                                        stats.trajectories.fetch_add(1, Ordering::Relaxed);
                                    }
                                    if queue.push(shards).is_err() {
                                        return; // queue shut: learner done
                                    }
                                }
                                Ok((FrameKind::Shutdown, _, n)) => {
                                    stats.record_wire_rx(n);
                                    if !stop.load(Ordering::Relaxed) {
                                        fail("shut down before the learner finished".to_string());
                                    }
                                    return;
                                }
                                Ok((kind, _, n)) => {
                                    stats.record_wire_rx(n);
                                    fail(format!("unexpected {kind:?} frame"));
                                    return;
                                }
                                Err(e) if e.is_idle_timeout() => {
                                    if stop.load(Ordering::Relaxed) {
                                        return;
                                    }
                                }
                                Err(e) => {
                                    if !(stop.load(Ordering::Relaxed) && e.is_closed()) {
                                        fail(format!("connection lost: {e}"));
                                    }
                                    return;
                                }
                            }
                        }
                    })
                    .expect("spawn dist receiver"),
            );
        }

        // ---- publisher ---------------------------------------------------
        // Every version the learner publishes goes to every actor pod as
        // one Params frame. Send failures are left to that connection's
        // receiver to surface (it sees the same dead socket).
        let publish_join = {
            let store = store.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("dist-publish".to_string())
                .spawn(move || {
                    let mut last = store.version();
                    while !stop.load(Ordering::Relaxed) {
                        if let Some(snap) = store.wait_newer(last, PUBLISH_POLL) {
                            last = snap.version;
                            let payload = encode_params(snap.version, &snap.params);
                            for c in &conns {
                                if let Ok(n) = c.send(FrameKind::Params, &payload) {
                                    stats.record_wire_tx(n);
                                }
                            }
                        }
                    }
                })
                .expect("spawn dist publisher")
        };

        // ---- the unmodified learner --------------------------------------
        let lcfg = LearnerConfig {
            replica_id: 0,
            grad_program: cfg.grad_program(),
            apply_program: cfg.apply_program(),
            shards_per_round: cfg.learner_cores,
            total_updates: cfg.total_updates,
            pipeline: cfg.learner_pipeline,
            checkpoint: None,
            fault: None,
            start_round: 0,
        };
        let cores: Vec<DeviceHandle> =
            (0..cfg.learner_cores).map(|i| pod.core(i)).collect::<Result<_>>()?;
        let handles = LearnerHandles {
            cores,
            store: store.clone(),
            queue: queue.clone(),
            stats: stats.clone(),
            bus: bus.clone(),
        };
        let t_start = Instant::now();
        let learner_join = spawn_guarded_learner(
            "dist-learner-0".to_string(),
            lcfg,
            handles,
            opt0.clone(),
            stop.clone(),
            queues.clone(),
            bus.clone(),
        );

        // ---- teardown ----------------------------------------------------
        // join_pod_threads sets the stop flag and shuts queue + bus on every
        // path; the wire teardown runs regardless of the learner's verdict
        // so actor pods always hear a Shutdown frame instead of a vanishing
        // peer.
        let learner_res =
            join_pod_threads("dist", &stop, &queues, &bus, vec![learner_join], Vec::new());
        for c in &conns {
            if let Ok(n) = c.send(FrameKind::Shutdown, &[]) {
                stats.record_wire_tx(n);
            }
        }
        let _ = publish_join.join();
        for j in recv_joins {
            let _ = j.join();
        }
        for c in &conns {
            c.close();
        }
        let (final_params, final_opt_state) =
            Self::resolve_learner_errors(learner_res, &wire_errs, params0, opt0)?;

        Self::learner_report(
            pod,
            &cfg,
            &stats,
            &queue,
            &busy0,
            t_start,
            final_params,
            final_opt_state,
        )
    }

    // ---- learner pod (elastic membership) --------------------------------

    fn run_learner_pod_elastic(
        &self,
        pod: &mut Pod,
        topo: &Topology,
        fault: Option<&FaultPlan>,
    ) -> Result<Report> {
        let cfg = self.resolved(topo)?;
        topo.validate_for_role(PodRole::Learner, pod.n_cores())?;
        ensure!(!self.listen.is_empty(), "learner pod needs a listen address");
        ensure!(self.min_actor_pods >= 1, "--min-actor-pods must be at least 1");
        ensure!(!self.heartbeat.is_zero(), "--heartbeat-ms must be at least 1");

        let (params0, opt0, busy0) = self.learner_setup(pod, &cfg)?;

        let stats = Arc::new(RunStats::new());
        let stop = Arc::new(AtomicBool::new(false));
        let bus = Arc::new(GradientBus::new(1));
        let store = Arc::new(ParamStore::new(params0.clone()));
        let queue = Arc::new(BoundedQueue::<ShardBundle>::new(cfg.queue_capacity));
        let queues = vec![queue.clone()];
        let wire_errs: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

        let threads_per_pod = cfg.actor_cores * cfg.threads_per_actor_core;
        let plane = Arc::new(ControlPlane::new(threads_per_pod, stats.clone()));
        let fingerprint = topology_fingerprint(&cfg);
        let heartbeat = self.heartbeat;
        let min_pods = self.min_actor_pods;

        let mut listener = self
            .transport
            .listen(&self.listen)
            .with_context(|| format!("listening on {}", self.listen))?;
        let listen_addr = listener.local_addr();
        log::info!(
            "dist-learner[{}]: elastic, listening on {listen_addr} \
             (min_actor_pods={min_pods}, heartbeat={heartbeat:?})",
            cfg.agent,
        );

        // Receiver handles accumulate as pods join; the teardown joins
        // whatever is there once the control thread has exited.
        let recv_joins: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        // ---- control thread: accept → verify → (maybe park) → admit -----
        let control_join = {
            let plane = plane.clone();
            let stats = stats.clone();
            let store = store.clone();
            let stop = stop.clone();
            let queue = queue.clone();
            let errs = wire_errs.clone();
            let recv_joins = recv_joins.clone();
            let delay = fault.and_then(|f| f.delay_admit);
            std::thread::Builder::new()
                .name("dist-control".to_string())
                .spawn(move || {
                    let mut ordinal: usize = 0; // admissions so far
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let conn: Arc<dyn Connection> = match listener.accept() {
                            Ok(c) => Arc::from(c),
                            Err(e) if e.is_idle_timeout() => {
                                // A run that never hears a single join is a
                                // misconfiguration, not something to wait
                                // out forever.
                                if plane.joined() == 0 && !stop.load(Ordering::Relaxed) {
                                    errs.lock().unwrap().push(
                                        "no actor pod joined within the accept window"
                                            .to_string(),
                                    );
                                    stop.store(true, Ordering::Relaxed);
                                    queue.shutdown();
                                    break;
                                }
                                continue;
                            }
                            Err(e) => {
                                if !stop.load(Ordering::Relaxed) {
                                    errs.lock().unwrap().push(format!("accepting a joiner: {e}"));
                                    stop.store(true, Ordering::Relaxed);
                                    queue.shutdown();
                                }
                                break;
                            }
                        };
                        if stop.load(Ordering::Relaxed) {
                            conn.close(); // the teardown self-dial, or a too-late joiner
                            break;
                        }
                        // -- Join: the joiner speaks first ----------------
                        let fp = match conn.recv() {
                            Ok((FrameKind::Join, payload, n)) => {
                                stats.record_wire_rx(n);
                                match decode_join(&payload) {
                                    Ok(fp) => fp,
                                    Err(e) => {
                                        log::warn!(
                                            "dist-control: bad join from {}: {e}",
                                            conn.peer()
                                        );
                                        conn.close();
                                        continue;
                                    }
                                }
                            }
                            Ok((kind, _, _)) => {
                                log::warn!(
                                    "dist-control: expected a join from {}, got {kind:?}",
                                    conn.peer()
                                );
                                conn.close();
                                continue;
                            }
                            Err(e) => {
                                log::warn!(
                                    "dist-control: joiner {} dropped during the handshake: {e}",
                                    conn.peer()
                                );
                                conn.close();
                                continue;
                            }
                        };
                        if fp != fingerprint {
                            log::warn!(
                                "dist-control: rejecting {}: topology fingerprint {fp:#018x} \
                                 does not match ours {fingerprint:#018x}",
                                conn.peer()
                            );
                            conn.close();
                            continue;
                        }
                        // -- staged delayed admission (tests) -------------
                        if delay.map_or(false, |pf| pf.pod == ordinal) {
                            let round = delay.unwrap().round;
                            log::info!(
                                "dist-control: parking joiner {} until {round} update(s) \
                                 finish (injected fault)",
                                conn.peer()
                            );
                            while stats.updates.load(Ordering::Relaxed) < round
                                && !stop.load(Ordering::Relaxed)
                            {
                                std::thread::sleep(Duration::from_millis(5));
                            }
                            if stop.load(Ordering::Relaxed) {
                                conn.close();
                                break;
                            }
                        }
                        // -- admit ----------------------------------------
                        let slot = plane.admit(&conn.peer(), conn.clone());
                        ordinal += 1;
                        let grant = Admission {
                            pod_index: slot.pod_index,
                            actor_id_base: slot.actor_id_base,
                            epoch: slot.epoch_joined,
                            heartbeat_ms: heartbeat.as_millis() as u64,
                        };
                        let snap = store.latest();
                        let greeted = conn
                            .send(FrameKind::Hello, &encode_admit(&grant))
                            .and_then(|n| {
                                stats.record_wire_tx(n);
                                conn.send(
                                    FrameKind::Params,
                                    &encode_params(snap.version, &snap.params),
                                )
                            })
                            .map(|n| stats.record_wire_tx(n));
                        if let Err(e) = greeted {
                            let why = format!("died during the admission handshake: {e}");
                            if let Some((gone, active)) = plane
                                .depart(slot.pod_index, &Departure::Evicted { reason: why.clone() })
                            {
                                enforce_floor(
                                    &gone, active, min_pods, &why, &errs, &stop, &queue,
                                );
                            }
                            continue;
                        }
                        log::info!(
                            "dist-learner: admitted pod {} from {} at epoch {} (params v{})",
                            slot.pod_index,
                            slot.peer,
                            slot.epoch_joined,
                            snap.version
                        );
                        recv_joins.lock().unwrap().push(spawn_elastic_receiver(
                            slot,
                            conn,
                            plane.clone(),
                            queue.clone(),
                            stop.clone(),
                            stats.clone(),
                            errs.clone(),
                            min_pods,
                        ));
                    }
                })
                .expect("spawn dist control")
        };

        // ---- monitor thread: evict members whose beacon went quiet -------
        let monitor_join = {
            let plane = plane.clone();
            let stop = stop.clone();
            let queue = queue.clone();
            let errs = wire_errs.clone();
            std::thread::Builder::new()
                .name("dist-monitor".to_string())
                .spawn(move || {
                    let tick = (heartbeat / 4)
                        .min(Duration::from_millis(100))
                        .max(Duration::from_millis(5));
                    while !stop.load(Ordering::Relaxed) {
                        std::thread::sleep(tick);
                        for pod in plane.overdue(heartbeat) {
                            let why = format!("no heartbeat within {heartbeat:?}");
                            if let Some((gone, active)) =
                                plane.depart(pod, &Departure::Evicted { reason: why.clone() })
                            {
                                enforce_floor(
                                    &gone, active, min_pods, &why, &errs, &stop, &queue,
                                );
                            }
                        }
                    }
                })
                .expect("spawn dist monitor")
        };

        // ---- publisher: broadcast to the current membership --------------
        let publish_join = {
            let store = store.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            let plane = plane.clone();
            std::thread::Builder::new()
                .name("dist-publish".to_string())
                .spawn(move || {
                    let mut last = store.version();
                    while !stop.load(Ordering::Relaxed) {
                        if let Some(snap) = store.wait_newer(last, PUBLISH_POLL) {
                            last = snap.version;
                            let payload = encode_params(snap.version, &snap.params);
                            for (_pod, c) in plane.broadcast_targets() {
                                if let Ok(n) = c.send(FrameKind::Params, &payload) {
                                    stats.record_wire_tx(n);
                                }
                            }
                        }
                    }
                })
                .expect("spawn dist publisher")
        };

        // ---- the unmodified learner --------------------------------------
        // Spawned immediately: it parks in queue.pop() until the first
        // admitted pod produces, so admission always precedes update 1.
        let lcfg = LearnerConfig {
            replica_id: 0,
            grad_program: cfg.grad_program(),
            apply_program: cfg.apply_program(),
            shards_per_round: cfg.learner_cores,
            total_updates: cfg.total_updates,
            pipeline: cfg.learner_pipeline,
            checkpoint: None,
            fault: None,
            start_round: 0,
        };
        let cores: Vec<DeviceHandle> =
            (0..cfg.learner_cores).map(|i| pod.core(i)).collect::<Result<_>>()?;
        let handles = LearnerHandles {
            cores,
            store: store.clone(),
            queue: queue.clone(),
            stats: stats.clone(),
            bus: bus.clone(),
        };
        let t_start = Instant::now();
        let learner_join = spawn_guarded_learner(
            "dist-learner-0".to_string(),
            lcfg,
            handles,
            opt0.clone(),
            stop.clone(),
            queues.clone(),
            bus.clone(),
        );

        // ---- teardown ----------------------------------------------------
        let learner_res =
            join_pod_threads("dist", &stop, &queues, &bus, vec![learner_join], Vec::new());
        // The control thread may be parked in a blocking accept with no
        // stop check; a self-dial is the portable way to wake it (the
        // bounded accept timeout is the fallback).
        if let Ok(c) = self.transport.connect(
            &listen_addr,
            &ConnectOpts {
                connect_timeout: Duration::from_millis(500),
                attempts: 1,
                backoff: Duration::ZERO,
            },
        ) {
            c.close();
        }
        let _ = control_join.join();
        let _ = monitor_join.join();
        for (_pod, c) in plane.broadcast_targets() {
            if let Ok(n) = c.send(FrameKind::Shutdown, &[]) {
                stats.record_wire_tx(n);
            }
        }
        let _ = publish_join.join();
        let receivers: Vec<JoinHandle<()>> =
            std::mem::take(&mut *recv_joins.lock().unwrap());
        for j in receivers {
            let _ = j.join();
        }
        for c in plane.drain_conns() {
            c.close();
        }
        let (final_params, final_opt_state) =
            Self::resolve_learner_errors(learner_res, &wire_errs, params0, opt0)?;

        Self::learner_report(
            pod,
            &cfg,
            &stats,
            &queue,
            &busy0,
            t_start,
            final_params,
            final_opt_state,
        )
    }

    // ---- actor pod -------------------------------------------------------

    fn run_actor_pod(
        &self,
        pod: &mut Pod,
        topo: &Topology,
        fault: Option<&FaultPlan>,
    ) -> Result<Report> {
        let cfg = self.resolved(topo)?;
        topo.validate_for_role(PodRole::Actor, pod.n_cores())?;
        ensure!(!self.connect.is_empty(), "actor pod needs a learner address to connect to");
        ensure!(
            self.workload.warm_start.is_none(),
            "actor pods take their parameters from the learner pod; warm_start \
             belongs on the learner"
        );

        let conn: Arc<dyn Connection> = Arc::from(
            self.transport
                .connect(&self.connect, &self.connect_opts)
                .with_context(|| format!("connecting to learner pod at {}", self.connect))?,
        );

        // ---- handshake ---------------------------------------------------
        // Static: the learner speaks first (Hello with our index + v0
        // params). Elastic: we speak first (Join with our topology
        // fingerprint) and the Hello carries the full admission grant and
        // the learner's *current* params.
        let stats = Arc::new(RunStats::new());
        let (pod_index, join_epoch, join_version, heartbeat_ms, store) = if self.elastic {
            let n = conn
                .send(FrameKind::Join, &encode_join(topology_fingerprint(&cfg)))
                .context("sending the join request")?;
            stats.record_wire_tx(n);
            let (kind, payload, n) = recv_admission(conn.as_ref(), JOIN_REPLY_TIMEOUT)
                .context("waiting for the admission grant")?;
            stats.record_wire_rx(n);
            ensure!(
                kind == FrameKind::Hello,
                "handshake: expected an admission hello, got {kind:?}"
            );
            let grant = decode_admit(&payload).context("admission grant")?;
            ensure!(grant.heartbeat_ms >= 1, "admission grant carries a zero heartbeat window");
            let (kind, payload, n) = conn.recv().context("waiting for the initial parameters")?;
            stats.record_wire_rx(n);
            ensure!(kind == FrameKind::Params, "handshake: expected a params frame, got {kind:?}");
            let (version, params) = decode_params(&payload).context("initial parameters")?;
            let store = Arc::new(ParamStore::with_version(params, version));
            log::info!(
                "dist-actor[{}]: admitted as pod {} at epoch {} (params v{version}, \
                 heartbeat {}ms)",
                cfg.agent,
                grant.pod_index,
                grant.epoch,
                grant.heartbeat_ms
            );
            (grant.pod_index, grant.epoch, version, Some(grant.heartbeat_ms), store)
        } else {
            let (kind, payload, n) = conn.recv().context("waiting for the learner's hello")?;
            stats.record_wire_rx(n);
            ensure!(
                kind == FrameKind::Hello && payload.len() == 8,
                "handshake: expected a hello frame with a pod index, got {kind:?} \
                 with {} payload bytes",
                payload.len()
            );
            let pod_index = u64::from_le_bytes(payload.try_into().unwrap()) as usize;
            let (kind, payload, n) = conn.recv().context("waiting for the initial parameters")?;
            stats.record_wire_rx(n);
            ensure!(kind == FrameKind::Params, "handshake: expected a params frame, got {kind:?}");
            let (version, params) = decode_params(&payload).context("initial parameters")?;
            let store = Arc::new(ParamStore::with_version(params, version));
            log::info!(
                "dist-actor[{}]: joined as pod {pod_index} (params v{version}, {} floats)",
                cfg.agent,
                store.latest().params.len()
            );
            (pod_index, 0, 0, None, store)
        };

        // ---- local acting state ------------------------------------------
        let agent = pod.manifest.agent(&cfg.agent)?.clone();
        let infer = cfg.infer_program();
        let actor_ids: Vec<usize> = (0..cfg.actor_cores).collect();
        pod.load_program(&infer, &actor_ids).with_context(|| format!("loading {infer}"))?;
        let busy0: Vec<f64> = (0..cfg.actor_cores)
            .map(|cid| Ok(pod.core(cid)?.busy_seconds()))
            .collect::<Result<_>>()?;

        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(BoundedQueue::<ShardBundle>::new(cfg.queue_capacity));
        let factory: Arc<EnvFactory> = Arc::new(make_factory(cfg.env_kind, cfg.seed));
        let pool = WorkerPool::new(cfg.env_workers);
        let wire_errs: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        // A hang fault mutes the heartbeat thread too — the pod must look
        // dead to the learner, not merely idle.
        let muted = Arc::new(AtomicBool::new(false));

        // ---- heartbeat beacon (elastic only) -----------------------------
        let hb_join = heartbeat_ms.map(|hb_ms| {
            let conn = conn.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            let muted = muted.clone();
            std::thread::Builder::new()
                .name("dist-heartbeat".to_string())
                .spawn(move || {
                    // A third of the eviction window: two beacons can be
                    // lost or late before the learner gives up on us.
                    let interval = Duration::from_millis((hb_ms / 3).max(1));
                    while !stop.load(Ordering::Relaxed) {
                        let mut left = interval;
                        while !left.is_zero() && !stop.load(Ordering::Relaxed) {
                            let slice = left.min(Duration::from_millis(50));
                            std::thread::sleep(slice);
                            left = left.saturating_sub(slice);
                        }
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        if muted.load(Ordering::Relaxed) {
                            continue;
                        }
                        match conn.send(FrameKind::Heartbeat, &[]) {
                            Ok(n) => stats.record_wire_tx(n),
                            Err(_) => break, // dead socket: the subscriber surfaces it
                        }
                    }
                })
                .expect("spawn dist heartbeat")
        });

        // ---- subscriber: installs published params, hears Shutdown -------
        let sub_join = {
            let conn = conn.clone();
            let store = store.clone();
            let stop = stop.clone();
            let queue = queue.clone();
            let stats = stats.clone();
            let errs = wire_errs.clone();
            std::thread::Builder::new()
                .name("dist-subscribe".to_string())
                .spawn(move || {
                    loop {
                        match conn.recv() {
                            Ok((FrameKind::Params, payload, n)) => {
                                stats.record_wire_rx(n);
                                match decode_params(&payload) {
                                    // install() ignores stale or duplicate
                                    // versions, so reordered frames are safe
                                    Ok((v, p)) => {
                                        store.install(p, v);
                                    }
                                    Err(e) => {
                                        errs.lock().unwrap().push(format!(
                                            "bad params frame from learner: {e}"
                                        ));
                                        break;
                                    }
                                }
                            }
                            Ok((FrameKind::Shutdown, _, n)) => {
                                stats.record_wire_rx(n);
                                break; // learner finished: clean teardown
                            }
                            Ok((kind, _, n)) => {
                                stats.record_wire_rx(n);
                                errs.lock()
                                    .unwrap()
                                    .push(format!("unexpected {kind:?} frame from learner"));
                                break;
                            }
                            Err(e) if e.is_idle_timeout() => {
                                if stop.load(Ordering::Relaxed) {
                                    break;
                                }
                            }
                            Err(e) => {
                                if !(stop.load(Ordering::Relaxed) && e.is_closed()) {
                                    errs.lock()
                                        .unwrap()
                                        .push(format!("learner pod connection lost: {e}"));
                                }
                                break;
                            }
                        }
                    }
                    // Whatever ended the subscription ends the pod: stop the
                    // actors and shut the queue so every thread unwinds.
                    stop.store(true, Ordering::Relaxed);
                    queue.shutdown();
                })
                .expect("spawn dist subscriber")
        };

        // ---- forwarder: local queue → TrajBundle frames ------------------
        // Pod-level faults fire here, between windows: the forwarder is the
        // one thread that knows how many windows this pod has shipped.
        let kill_at = fault.and_then(|f| f.kill_pod).filter(|pf| pf.pod == pod_index);
        let hang_at = fault.and_then(|f| f.hang_pod).filter(|pf| pf.pod == pod_index);
        let leave_at = fault.and_then(|f| f.leave_pod).filter(|pf| pf.pod == pod_index);
        let fwd_join = {
            let conn = conn.clone();
            let queue = queue.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            let errs = wire_errs.clone();
            let muted = muted.clone();
            std::thread::Builder::new()
                .name("dist-forward".to_string())
                .spawn(move || {
                    let mut sent: u64 = 0;
                    // Faulted exits skip the goodbye: the learner must see a
                    // vanished/silent/departed peer, not an orderly shutdown.
                    let mut goodbye = true;
                    loop {
                        if kill_at.map_or(false, |pf| sent >= pf.round) {
                            errs.lock().unwrap().push(format!(
                                "injected fault: actor pod {pod_index} killed after \
                                 {sent} window(s)"
                            ));
                            conn.close();
                            stop.store(true, Ordering::Relaxed);
                            queue.shutdown();
                            goodbye = false;
                            break;
                        }
                        if hang_at.map_or(false, |pf| sent >= pf.round) {
                            log::info!(
                                "injected fault: actor pod {pod_index} hanging after \
                                 {sent} window(s)"
                            );
                            muted.store(true, Ordering::Relaxed);
                            goodbye = false;
                            break; // conn stays open; the learner must evict us
                        }
                        if leave_at.map_or(false, |pf| sent >= pf.round) {
                            log::info!(
                                "injected fault: actor pod {pod_index} leaving after \
                                 {sent} window(s)"
                            );
                            if let Ok(n) = conn.send(FrameKind::Leave, &[]) {
                                stats.record_wire_tx(n);
                            }
                            stop.store(true, Ordering::Relaxed);
                            queue.shutdown();
                            goodbye = false;
                            break;
                        }
                        let bundle = match queue.pop() {
                            Ok(b) => b,
                            Err(_) => break, // queue shut: teardown
                        };
                        let payload = match encode_bundle(&bundle) {
                            Ok(p) => p,
                            Err(e) => {
                                errs.lock()
                                    .unwrap()
                                    .push(format!("encoding trajectory bundle: {e}"));
                                stop.store(true, Ordering::Relaxed);
                                queue.shutdown();
                                break;
                            }
                        };
                        match conn.send(FrameKind::TrajBundle, &payload) {
                            Ok(n) => {
                                stats.record_wire_tx(n);
                                sent += 1;
                            }
                            Err(e) => {
                                if !stop.load(Ordering::Relaxed) {
                                    errs.lock().unwrap().push(format!(
                                        "sending trajectory to learner: {e}"
                                    ));
                                }
                                stop.store(true, Ordering::Relaxed);
                                queue.shutdown();
                                break;
                            }
                        }
                    }
                    // Best-effort goodbye: tells the learner this pod will
                    // never produce again (prematurely, that is an error on
                    // the learner's side — exactly the contract we want).
                    if goodbye {
                        if let Ok(n) = conn.send(FrameKind::Shutdown, &[]) {
                            stats.record_wire_tx(n);
                        }
                    }
                })
                .expect("spawn dist forwarder")
        };

        // ---- the unmodified actor threads --------------------------------
        // Actor ids are globally unique across pods (the admission grant's
        // id base — or pod_index * threads_per_pod, the same thing — offsets
        // the local id), so every thread draws a distinct RNG stream exactly
        // as its in-memory counterpart would; elastic pod indices are never
        // reused, so neither are id ranges.
        let threads_per_pod = cfg.actor_cores * cfg.threads_per_actor_core;
        let actor_id_base = pod_index * threads_per_pod;
        let t_start = Instant::now();
        let mut actor_joins = Vec::with_capacity(threads_per_pod);
        for ac in 0..cfg.actor_cores {
            let core = pod.core(ac)?;
            for th in 0..cfg.threads_per_actor_core {
                let local = ac * cfg.threads_per_actor_core + th;
                let acfg = ActorConfig {
                    actor_id: actor_id_base + local,
                    batch: cfg.actor_batch,
                    pipeline_stages: cfg.pipeline_stages,
                    unroll: cfg.unroll,
                    discount: cfg.discount,
                    num_shards: cfg.learner_cores * cfg.micro_batches,
                    infer_program: infer.clone(),
                    obs_shape: agent.obs_shape.clone(),
                    num_actions: agent.num_actions,
                    seed: cfg.seed,
                    copy_path: cfg.copy_path,
                    checkpoint: None,
                };
                actor_joins.push(spawn_actor(
                    acfg,
                    core.clone(),
                    factory.clone(),
                    pool.clone(),
                    store.clone(),
                    queue.clone(),
                    stats.clone(),
                    stop.clone(),
                ));
            }
        }

        // ---- join: actors first (they exit when the queue shuts) ---------
        let mut actor_err: Option<anyhow::Error> = None;
        for j in actor_joins {
            match j.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if actor_err.is_none() {
                        actor_err = Some(e.context("dist actor thread failed"));
                    }
                    // a dead actor thread ends the pod: unblock the rest and
                    // let the forwarder's Shutdown frame tell the learner
                    stop.store(true, Ordering::Relaxed);
                    queue.shutdown();
                }
                Err(_) => {
                    if actor_err.is_none() {
                        actor_err = Some(anyhow::anyhow!("dist actor thread panicked"));
                    }
                    stop.store(true, Ordering::Relaxed);
                    queue.shutdown();
                }
            }
        }
        queue.shutdown(); // idempotent: guarantees the forwarder unblocks
        let _ = fwd_join.join();
        let _ = sub_join.join();
        if let Some(j) = hb_join {
            let _ = j.join();
        }
        conn.close();
        if let Some(e) = actor_err {
            return Err(e);
        }
        {
            let errs = wire_errs.lock().unwrap();
            if !errs.is_empty() {
                bail!("actor pod {pod_index} wire failure: {}", errs.join("; "));
            }
        }

        // ---- report ------------------------------------------------------
        let elapsed = t_start.elapsed().as_secs_f64();
        let mut actor_busy = 0.0;
        let mut critical_path: f64 = 1e-12;
        for cid in 0..cfg.actor_cores {
            let busy = pod.core(cid)?.busy_seconds() - busy0[cid];
            actor_busy += busy;
            critical_path = critical_path.max(busy);
        }
        let frames = stats.env_frames.frames();
        let snap = store.latest();
        log::info!("dist-actor {pod_index} done: {}", stats.summary());
        Ok(Report {
            arch: Arch::Sebulba,
            steps: frames,
            // updates = parameter versions observed from the learner
            updates: snap.version,
            elapsed,
            throughput: frames as f64 / elapsed.max(1e-12),
            projected_throughput: frames as f64 / critical_path,
            final_params: snap.params.as_ref().clone(),
            detail: Detail::ActorLearner(ActorLearnerDetail {
                mean_staleness: stats.mean_staleness(),
                mean_episode_reward: stats.mean_episode_reward(),
                episodes: stats.episodes.load(Ordering::Relaxed),
                last_loss: stats.last_loss(),
                actor_busy_seconds: actor_busy,
                // the learning half lives in the learner pod's report
                learner_busy_seconds: 0.0,
                actor_infer_seconds: stats.actor_infer_seconds(),
                actor_env_step_seconds: stats.actor_env_seconds(),
                actor_loop_seconds: stats.actor_loop_seconds(),
                actor_overlap_seconds: stats.actor_overlap_seconds(),
                learner_grad_seconds: 0.0,
                learner_collective_seconds: 0.0,
                learner_apply_seconds: 0.0,
                learner_active_seconds: 0.0,
                learner_overlap_seconds: 0.0,
                queue_push_block_seconds: queue.push_block_seconds(),
                queue_pop_block_seconds: queue.pop_block_seconds(),
                infer_calls: stats.infer_calls(),
                grad_calls: stats.grad_calls(),
                apply_calls: stats.apply_calls(),
                env_step_calls: stats.env_step_calls(),
                pods_joined: 0,
                pods_evicted: 0,
                membership_epoch: join_epoch,
                join_param_version: join_version,
                final_opt_state: Vec::new(),
            }),
        })
    }
}

impl Runner for DistSebulba {
    fn arch(&self) -> Arch {
        Arch::Sebulba
    }

    fn run_checkpointed(&self, pod: &mut Pod, topo: &Topology, spec: &RunSpec) -> Result<Report> {
        let pod_faults_ok = spec
            .fault
            .as_ref()
            .map_or(true, |f| f.is_empty() || (self.elastic && f.pod_faults_only()));
        ensure!(
            spec.checkpoint.is_none() && spec.restore_from.is_none() && pod_faults_ok,
            "distributed runs do not support checkpoint/restore/fault injection \
             beyond pod-level fault plans on elastic runs; run thread-level \
             faults single-process"
        );
        let fault = spec.fault.clone().filter(|f| !f.is_empty());
        match self.role {
            PodRole::Learner => {
                if self.elastic {
                    self.run_learner_pod_elastic(pod, topo, fault.as_ref())
                } else {
                    self.run_learner_pod(pod, topo)
                }
            }
            PodRole::Actor => self.run_actor_pod(pod, topo, fault.as_ref()),
            PodRole::Colocated => bail!(
                "DistSebulba needs --role learner or --role actor; colocated runs \
                 use the in-memory Sebulba runner"
            ),
        }
    }
}
