//! Multi-pod Sebulba: one experiment as a learner pod plus K actor-pod
//! processes, glued by the [`Transport`] seam (DESIGN.md §15).
//!
//! The decomposition keeps the in-memory coordinator's parts and replaces
//! exactly one seam with the wire:
//!
//! ```text
//!   actor pod k                              learner pod
//!   ┌──────────────────────────┐             ┌───────────────────────────┐
//!   │ actor threads → queue ───┼─ TrajBundle ┼→ receiver k → queue       │
//!   │       ▲                  │   frames    │     (one per actor pod)   │
//!   │  ParamStore ← subscriber ┼←─ Params ───┼─ publisher ← ParamStore   │
//!   └──────────────────────────┘   frames    │       ▲                   │
//!                                            │  learner thread (grad →   │
//!                                            │  reduce → apply → publish)│
//!                                            └───────────────────────────┘
//! ```
//!
//! * Actor pods run the unmodified [`spawn_actor`] threads against a local
//!   [`BoundedQueue`]; a forwarder thread drains it and ships each
//!   [`ShardBundle`] as one `TrajBundle` frame (shard-major columns,
//!   [`super::wire`]).
//! * The learner pod runs the unmodified [`learner_main`] (via the guarded
//!   spawn) against its local queue; per-connection receiver threads feed
//!   it, and a publisher thread broadcasts every published parameter
//!   version as a `Params` frame ([`ParamStore::wait_newer`] pub/sub).
//! * Handshake: the learner accepts K connections and greets each with a
//!   `Hello` frame (payload: the pod's index, u64 LE) followed by one
//!   `Params` frame carrying the version-0 snapshot — every pod starts
//!   from bit-identical parameters, which is what makes the two-process
//!   `updates=1` run bit-identical to the in-memory one (the oracle in
//!   `rust/tests/transport.rs`).
//! * Teardown: whoever stops first says so. The learner broadcasts a
//!   `Shutdown` frame when its update budget is spent; an actor pod whose
//!   threads die sends `Shutdown` up so the learner is never left waiting
//!   on a producer that will not come back. A connection that drops
//!   without the frame is a surfaced error, never a silent stall — the
//!   TensorBus poisoning discipline (DESIGN.md §10) extended over the
//!   wire.
//!
//! Distributed v1 deliberately mirrors the in-memory coordinator's plain
//! path only: `replicas == 1` per pod, and checkpoint/restore/fault specs
//! are rejected with a typed error rather than half-honoured.
//!
//! [`learner_main`]: crate::coordinator::learner

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::actor::{spawn_actor, ActorConfig, ShardBundle};
use crate::coordinator::collective::GradientBus;
use crate::coordinator::learner::{LearnerConfig, LearnerHandles};
use crate::coordinator::param_store::ParamStore;
use crate::coordinator::queue::BoundedQueue;
use crate::coordinator::sebulba::{join_pod_threads, spawn_guarded_learner, Sebulba};
use crate::coordinator::stats::RunStats;
use crate::coordinator::SebulbaConfig;
use crate::envs::{make_factory, EnvFactory, WorkerPool};
use crate::experiment::{
    ActorLearnerDetail, Arch, Detail, PodRole, Report, RunSpec, Runner, Topology,
};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{DeviceHandle, Pod};

use super::frame::FrameKind;
use super::tcp::TcpTransport;
use super::wire::{decode_bundle, decode_params, encode_bundle, encode_params};
use super::{ConnectOpts, Connection, Transport};

/// How long the learner-side publisher parks in [`ParamStore::wait_newer`]
/// per wait: long enough to sleep between updates, short enough to notice
/// the stop flag promptly at teardown.
const PUBLISH_POLL: Duration = Duration::from_millis(50);

/// One Sebulba experiment split across processes: a learner pod (listens,
/// learns, publishes params) or an actor pod (connects, acts, ships
/// trajectories), depending on [`PodRole`]. Both sides are handed the same
/// workload + topology, so the geometry (shard counts, batch shapes,
/// program names) agrees by construction.
pub struct DistSebulba {
    /// The workload — identical on every pod of the experiment.
    pub workload: Sebulba,
    /// Which half of the experiment this process runs.
    pub role: PodRole,
    /// Learner role: address to listen on (e.g. `127.0.0.1:7070`).
    pub listen: String,
    /// Actor role: the learner pod's address to connect to.
    pub connect: String,
    /// Learner role: how many actor pods to accept before training starts.
    pub actor_pods: usize,
    /// The pipe. Defaults to [`TcpTransport`]; tests inject
    /// [`super::LoopbackTransport`] to run all pods in one process.
    pub transport: Arc<dyn Transport>,
    /// Dial budget for the actor role (bounded retry + backoff).
    pub connect_opts: ConnectOpts,
}

impl DistSebulba {
    /// The learner pod of an experiment with `actor_pods` actor pods.
    pub fn learner(workload: Sebulba, listen: &str, actor_pods: usize) -> Self {
        Self {
            workload,
            role: PodRole::Learner,
            listen: listen.to_string(),
            connect: String::new(),
            actor_pods,
            transport: Arc::new(TcpTransport::default()),
            connect_opts: ConnectOpts::default(),
        }
    }

    /// One actor pod, dialing the learner at `connect`.
    pub fn actor(workload: Sebulba, connect: &str) -> Self {
        Self {
            workload,
            role: PodRole::Actor,
            listen: String::new(),
            connect: connect.to_string(),
            actor_pods: 0,
            transport: Arc::new(TcpTransport::default()),
            connect_opts: ConnectOpts::default(),
        }
    }

    /// Swap the pipe (tests: loopback; production: TCP, the default).
    pub fn with_transport(mut self, transport: Arc<dyn Transport>) -> Self {
        self.transport = transport;
        self
    }

    fn resolved(&self, topo: &Topology) -> Result<SebulbaConfig> {
        let cfg = self.workload.resolved(topo);
        cfg.validate()?;
        ensure!(
            cfg.replicas == 1,
            "distributed runs need replicas == 1 per pod (got {}); scale out \
             with more actor pods instead",
            cfg.replicas
        );
        Ok(cfg)
    }

    // ---- learner pod -----------------------------------------------------

    fn run_learner_pod(&self, pod: &mut Pod, topo: &Topology) -> Result<Report> {
        let cfg = self.resolved(topo)?;
        topo.validate_for_role(PodRole::Learner, pod.n_cores())?;
        ensure!(self.actor_pods >= 1, "learner pod needs at least one actor pod");
        ensure!(!self.listen.is_empty(), "learner pod needs a listen address");

        // Programs: this pod owns only the learner cores; local core ids
        // 0..learner_cores stand in for the in-memory pod's learner slice.
        let grad = cfg.grad_program();
        let apply = cfg.apply_program();
        let init = cfg.init_program();
        let learner_ids: Vec<usize> = (0..cfg.learner_cores).collect();
        pod.load_program(&grad, &learner_ids).with_context(|| format!("loading {grad}"))?;
        pod.load_program(&apply, &[0])?;
        pod.load_program(&init, &[0])?;

        let busy0: Vec<f64> = (0..cfg.learner_cores)
            .map(|cid| Ok(pod.core(cid)?.busy_seconds()))
            .collect::<Result<_>>()?;

        let (params0, opt0) = match self.workload.warm_start.clone() {
            Some((p, o)) => (p, o),
            None => {
                let outs = pod
                    .core(0)?
                    .execute(&init, vec![HostTensor::scalar_i32(cfg.seed as i32)])?;
                (outs[0].clone().into_f32()?, outs[1].clone().into_f32()?)
            }
        };

        let stats = Arc::new(RunStats::new());
        let stop = Arc::new(AtomicBool::new(false));
        let bus = Arc::new(GradientBus::new(1));
        let store = Arc::new(ParamStore::new(params0.clone()));
        let queue = Arc::new(BoundedQueue::<ShardBundle>::new(cfg.queue_capacity));
        let queues = vec![queue.clone()];

        // ---- accept + handshake ------------------------------------------
        let mut listener = self
            .transport
            .listen(&self.listen)
            .with_context(|| format!("listening on {}", self.listen))?;
        log::info!(
            "dist-learner[{}]: listening on {}, waiting for {} actor pod(s)",
            cfg.agent,
            listener.local_addr(),
            self.actor_pods
        );
        let hello0 = encode_params(store.version(), &params0);
        let mut conns: Vec<Arc<dyn Connection>> = Vec::with_capacity(self.actor_pods);
        for pod_index in 0..self.actor_pods {
            let conn: Arc<dyn Connection> = Arc::from(
                listener
                    .accept()
                    .with_context(|| format!("waiting for actor pod {pod_index}"))?,
            );
            // Hello stamps the pod's index (actor ids and RNG streams derive
            // from it); the initial Params frame makes every pod start from
            // bit-identical version-0 parameters.
            let n = conn
                .send(FrameKind::Hello, &(pod_index as u64).to_le_bytes())
                .with_context(|| format!("greeting actor pod {pod_index}"))?;
            stats.record_wire_tx(n);
            let n = conn
                .send(FrameKind::Params, &hello0)
                .with_context(|| format!("seeding actor pod {pod_index} with params"))?;
            stats.record_wire_tx(n);
            log::info!("dist-learner: actor pod {pod_index} joined from {}", conn.peer());
            conns.push(conn);
        }

        // ---- per-connection receivers ------------------------------------
        // Any exit before the stop flag is set means that pod will never
        // produce again: surface it and shut the queue so the learner
        // drains instead of waiting forever ("never a silent drop").
        let wire_errs: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let mut recv_joins = Vec::with_capacity(conns.len());
        for (i, conn) in conns.iter().enumerate() {
            let conn = conn.clone();
            let queue = queue.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            let errs = wire_errs.clone();
            recv_joins.push(
                std::thread::Builder::new()
                    .name(format!("dist-recv-{i}"))
                    .spawn(move || {
                        let mut fail = |msg: String| {
                            errs.lock().unwrap().push(msg);
                            stop.store(true, Ordering::Relaxed);
                            queue.shutdown();
                        };
                        loop {
                            match conn.recv() {
                                Ok((FrameKind::TrajBundle, payload, n)) => {
                                    stats.record_wire_rx(n);
                                    let shards = match decode_bundle(&payload) {
                                        Ok(s) => s,
                                        Err(e) => {
                                            fail(format!(
                                                "actor pod {i}: bad trajectory frame: {e}"
                                            ));
                                            return;
                                        }
                                    };
                                    if let Some(first) = shards.first() {
                                        stats.env_frames.add(first.arena().frames() as u64);
                                        stats.trajectories.fetch_add(1, Ordering::Relaxed);
                                    }
                                    if queue.push(shards).is_err() {
                                        return; // queue shut: learner done
                                    }
                                }
                                Ok((FrameKind::Shutdown, _, n)) => {
                                    stats.record_wire_rx(n);
                                    if !stop.load(Ordering::Relaxed) {
                                        fail(format!(
                                            "actor pod {i} shut down before the learner finished"
                                        ));
                                    }
                                    return;
                                }
                                Ok((kind, _, n)) => {
                                    stats.record_wire_rx(n);
                                    fail(format!("actor pod {i}: unexpected {kind:?} frame"));
                                    return;
                                }
                                Err(e) if e.is_idle_timeout() => {
                                    if stop.load(Ordering::Relaxed) {
                                        return;
                                    }
                                }
                                Err(e) => {
                                    if !(stop.load(Ordering::Relaxed) && e.is_closed()) {
                                        fail(format!("actor pod {i} connection lost: {e}"));
                                    }
                                    return;
                                }
                            }
                        }
                    })
                    .expect("spawn dist receiver"),
            );
        }

        // ---- publisher ---------------------------------------------------
        // Every version the learner publishes goes to every actor pod as
        // one Params frame. Send failures are left to that connection's
        // receiver to surface (it sees the same dead socket).
        let publish_join = {
            let store = store.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("dist-publish".to_string())
                .spawn(move || {
                    let mut last = store.version();
                    while !stop.load(Ordering::Relaxed) {
                        if let Some(snap) = store.wait_newer(last, PUBLISH_POLL) {
                            last = snap.version;
                            let payload = encode_params(snap.version, &snap.params);
                            for c in &conns {
                                if let Ok(n) = c.send(FrameKind::Params, &payload) {
                                    stats.record_wire_tx(n);
                                }
                            }
                        }
                    }
                })
                .expect("spawn dist publisher")
        };

        // ---- the unmodified learner --------------------------------------
        let lcfg = LearnerConfig {
            replica_id: 0,
            grad_program: grad,
            apply_program: apply,
            shards_per_round: cfg.learner_cores,
            total_updates: cfg.total_updates,
            pipeline: cfg.learner_pipeline,
            checkpoint: None,
            fault: None,
            start_round: 0,
        };
        let cores: Vec<DeviceHandle> =
            (0..cfg.learner_cores).map(|i| pod.core(i)).collect::<Result<_>>()?;
        let handles = LearnerHandles {
            cores,
            store: store.clone(),
            queue: queue.clone(),
            stats: stats.clone(),
            bus: bus.clone(),
        };
        let t_start = Instant::now();
        let learner_join = spawn_guarded_learner(
            "dist-learner-0".to_string(),
            lcfg,
            handles,
            opt0.clone(),
            stop.clone(),
            queues.clone(),
            bus.clone(),
        );

        // ---- teardown ----------------------------------------------------
        // join_pod_threads sets the stop flag and shuts queue + bus on every
        // path; the wire teardown runs regardless of the learner's verdict
        // so actor pods always hear a Shutdown frame instead of a vanishing
        // peer.
        let learner_res =
            join_pod_threads("dist", &stop, &queues, &bus, vec![learner_join], Vec::new());
        for c in &conns {
            if let Ok(n) = c.send(FrameKind::Shutdown, &[]) {
                stats.record_wire_tx(n);
            }
        }
        let _ = publish_join.join();
        for j in recv_joins {
            let _ = j.join();
        }
        for c in &conns {
            c.close();
        }
        let (final_params, final_opt_state) = match learner_res? {
            Some(out) => out,
            None => (params0, opt0),
        };
        {
            let errs = wire_errs.lock().unwrap();
            if !errs.is_empty() {
                bail!(
                    "distributed run lost {} actor pod(s): {}",
                    errs.len(),
                    errs.join("; ")
                );
            }
        }

        // ---- report ------------------------------------------------------
        let elapsed = t_start.elapsed().as_secs_f64();
        let mut learner_busy = 0.0;
        let mut critical_path: f64 = 1e-12;
        for cid in 0..cfg.learner_cores {
            let busy = pod.core(cid)?.busy_seconds() - busy0[cid];
            learner_busy += busy;
            critical_path = critical_path.max(busy);
        }
        critical_path = critical_path.max(stats.learner_active_max_seconds());
        let frames = stats.env_frames.frames();
        log::info!("dist-learner done: {}", stats.summary());
        Ok(Report {
            arch: Arch::Sebulba,
            steps: frames,
            updates: stats.updates.load(Ordering::Relaxed),
            elapsed,
            throughput: frames as f64 / elapsed.max(1e-12),
            projected_throughput: frames as f64 / critical_path,
            final_params,
            detail: Detail::ActorLearner(ActorLearnerDetail {
                mean_staleness: stats.mean_staleness(),
                mean_episode_reward: stats.mean_episode_reward(),
                episodes: stats.episodes.load(Ordering::Relaxed),
                last_loss: stats.last_loss(),
                // the acting half lives in other processes; its busy time
                // is reported by the actor pods themselves
                actor_busy_seconds: 0.0,
                learner_busy_seconds: learner_busy,
                actor_infer_seconds: 0.0,
                actor_env_step_seconds: 0.0,
                actor_loop_seconds: 0.0,
                actor_overlap_seconds: 0.0,
                learner_grad_seconds: stats.learner_grad_seconds(),
                learner_collective_seconds: stats.learner_collective_seconds(),
                learner_apply_seconds: stats.learner_apply_seconds(),
                learner_active_seconds: stats.learner_active_seconds(),
                learner_overlap_seconds: stats.learner_overlap_seconds(),
                queue_push_block_seconds: queue.push_block_seconds(),
                queue_pop_block_seconds: queue.pop_block_seconds(),
                final_opt_state,
            }),
        })
    }

    // ---- actor pod -------------------------------------------------------

    fn run_actor_pod(&self, pod: &mut Pod, topo: &Topology) -> Result<Report> {
        let cfg = self.resolved(topo)?;
        topo.validate_for_role(PodRole::Actor, pod.n_cores())?;
        ensure!(!self.connect.is_empty(), "actor pod needs a learner address to connect to");
        ensure!(
            self.workload.warm_start.is_none(),
            "actor pods take their parameters from the learner pod; warm_start \
             belongs on the learner"
        );

        let conn: Arc<dyn Connection> = Arc::from(
            self.transport
                .connect(&self.connect, &self.connect_opts)
                .with_context(|| format!("connecting to learner pod at {}", self.connect))?,
        );

        // ---- handshake: Hello (pod index) then the initial Params --------
        let stats = Arc::new(RunStats::new());
        let (kind, payload, n) = conn.recv().context("waiting for the learner's hello")?;
        stats.record_wire_rx(n);
        ensure!(
            kind == FrameKind::Hello && payload.len() == 8,
            "handshake: expected a hello frame with a pod index, got {kind:?} \
             with {} payload bytes",
            payload.len()
        );
        let pod_index = u64::from_le_bytes(payload.try_into().unwrap()) as usize;
        let (kind, payload, n) = conn.recv().context("waiting for the initial parameters")?;
        stats.record_wire_rx(n);
        ensure!(kind == FrameKind::Params, "handshake: expected a params frame, got {kind:?}");
        let (version, params) = decode_params(&payload).context("initial parameters")?;
        let store = Arc::new(ParamStore::with_version(params, version));
        log::info!(
            "dist-actor[{}]: joined as pod {pod_index} (params v{version}, {} floats)",
            cfg.agent,
            store.latest().params.len()
        );

        // ---- local acting state ------------------------------------------
        let agent = pod.manifest.agent(&cfg.agent)?.clone();
        let infer = cfg.infer_program();
        let actor_ids: Vec<usize> = (0..cfg.actor_cores).collect();
        pod.load_program(&infer, &actor_ids).with_context(|| format!("loading {infer}"))?;
        let busy0: Vec<f64> = (0..cfg.actor_cores)
            .map(|cid| Ok(pod.core(cid)?.busy_seconds()))
            .collect::<Result<_>>()?;

        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(BoundedQueue::<ShardBundle>::new(cfg.queue_capacity));
        let factory: Arc<EnvFactory> = Arc::new(make_factory(cfg.env_kind, cfg.seed));
        let pool = WorkerPool::new(cfg.env_workers);
        let wire_errs: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

        // ---- subscriber: installs published params, hears Shutdown -------
        let sub_join = {
            let conn = conn.clone();
            let store = store.clone();
            let stop = stop.clone();
            let queue = queue.clone();
            let stats = stats.clone();
            let errs = wire_errs.clone();
            std::thread::Builder::new()
                .name("dist-subscribe".to_string())
                .spawn(move || {
                    loop {
                        match conn.recv() {
                            Ok((FrameKind::Params, payload, n)) => {
                                stats.record_wire_rx(n);
                                match decode_params(&payload) {
                                    // install() ignores stale or duplicate
                                    // versions, so reordered frames are safe
                                    Ok((v, p)) => {
                                        store.install(p, v);
                                    }
                                    Err(e) => {
                                        errs.lock().unwrap().push(format!(
                                            "bad params frame from learner: {e}"
                                        ));
                                        break;
                                    }
                                }
                            }
                            Ok((FrameKind::Shutdown, _, n)) => {
                                stats.record_wire_rx(n);
                                break; // learner finished: clean teardown
                            }
                            Ok((kind, _, n)) => {
                                stats.record_wire_rx(n);
                                errs.lock()
                                    .unwrap()
                                    .push(format!("unexpected {kind:?} frame from learner"));
                                break;
                            }
                            Err(e) if e.is_idle_timeout() => {
                                if stop.load(Ordering::Relaxed) {
                                    break;
                                }
                            }
                            Err(e) => {
                                if !(stop.load(Ordering::Relaxed) && e.is_closed()) {
                                    errs.lock()
                                        .unwrap()
                                        .push(format!("learner pod connection lost: {e}"));
                                }
                                break;
                            }
                        }
                    }
                    // Whatever ended the subscription ends the pod: stop the
                    // actors and shut the queue so every thread unwinds.
                    stop.store(true, Ordering::Relaxed);
                    queue.shutdown();
                })
                .expect("spawn dist subscriber")
        };

        // ---- forwarder: local queue → TrajBundle frames ------------------
        let fwd_join = {
            let conn = conn.clone();
            let queue = queue.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            let errs = wire_errs.clone();
            std::thread::Builder::new()
                .name("dist-forward".to_string())
                .spawn(move || {
                    loop {
                        let bundle = match queue.pop() {
                            Ok(b) => b,
                            Err(_) => break, // queue shut: teardown
                        };
                        let payload = match encode_bundle(&bundle) {
                            Ok(p) => p,
                            Err(e) => {
                                errs.lock()
                                    .unwrap()
                                    .push(format!("encoding trajectory bundle: {e}"));
                                stop.store(true, Ordering::Relaxed);
                                queue.shutdown();
                                break;
                            }
                        };
                        match conn.send(FrameKind::TrajBundle, &payload) {
                            Ok(n) => stats.record_wire_tx(n),
                            Err(e) => {
                                if !stop.load(Ordering::Relaxed) {
                                    errs.lock().unwrap().push(format!(
                                        "sending trajectory to learner: {e}"
                                    ));
                                }
                                stop.store(true, Ordering::Relaxed);
                                queue.shutdown();
                                break;
                            }
                        }
                    }
                    // Best-effort goodbye: tells the learner this pod will
                    // never produce again (prematurely, that is an error on
                    // the learner's side — exactly the contract we want).
                    if let Ok(n) = conn.send(FrameKind::Shutdown, &[]) {
                        stats.record_wire_tx(n);
                    }
                })
                .expect("spawn dist forwarder")
        };

        // ---- the unmodified actor threads --------------------------------
        // Actor ids are globally unique across pods (pod_index offsets the
        // local id), so every thread draws a distinct RNG stream exactly as
        // its in-memory counterpart would.
        let threads_per_pod = cfg.actor_cores * cfg.threads_per_actor_core;
        let t_start = Instant::now();
        let mut actor_joins = Vec::with_capacity(threads_per_pod);
        for ac in 0..cfg.actor_cores {
            let core = pod.core(ac)?;
            for th in 0..cfg.threads_per_actor_core {
                let local = ac * cfg.threads_per_actor_core + th;
                let acfg = ActorConfig {
                    actor_id: pod_index * threads_per_pod + local,
                    batch: cfg.actor_batch,
                    pipeline_stages: cfg.pipeline_stages,
                    unroll: cfg.unroll,
                    discount: cfg.discount,
                    num_shards: cfg.learner_cores * cfg.micro_batches,
                    infer_program: infer.clone(),
                    obs_shape: agent.obs_shape.clone(),
                    num_actions: agent.num_actions,
                    seed: cfg.seed,
                    copy_path: cfg.copy_path,
                    checkpoint: None,
                };
                actor_joins.push(spawn_actor(
                    acfg,
                    core.clone(),
                    factory.clone(),
                    pool.clone(),
                    store.clone(),
                    queue.clone(),
                    stats.clone(),
                    stop.clone(),
                ));
            }
        }

        // ---- join: actors first (they exit when the queue shuts) ---------
        let mut actor_err: Option<anyhow::Error> = None;
        for j in actor_joins {
            match j.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if actor_err.is_none() {
                        actor_err = Some(e.context("dist actor thread failed"));
                    }
                    // a dead actor thread ends the pod: unblock the rest and
                    // let the forwarder's Shutdown frame tell the learner
                    stop.store(true, Ordering::Relaxed);
                    queue.shutdown();
                }
                Err(_) => {
                    if actor_err.is_none() {
                        actor_err = Some(anyhow::anyhow!("dist actor thread panicked"));
                    }
                    stop.store(true, Ordering::Relaxed);
                    queue.shutdown();
                }
            }
        }
        queue.shutdown(); // idempotent: guarantees the forwarder unblocks
        let _ = fwd_join.join();
        let _ = sub_join.join();
        conn.close();
        if let Some(e) = actor_err {
            return Err(e);
        }
        {
            let errs = wire_errs.lock().unwrap();
            if !errs.is_empty() {
                bail!("actor pod {pod_index} wire failure: {}", errs.join("; "));
            }
        }

        // ---- report ------------------------------------------------------
        let elapsed = t_start.elapsed().as_secs_f64();
        let mut actor_busy = 0.0;
        let mut critical_path: f64 = 1e-12;
        for cid in 0..cfg.actor_cores {
            let busy = pod.core(cid)?.busy_seconds() - busy0[cid];
            actor_busy += busy;
            critical_path = critical_path.max(busy);
        }
        let frames = stats.env_frames.frames();
        let snap = store.latest();
        log::info!("dist-actor {pod_index} done: {}", stats.summary());
        Ok(Report {
            arch: Arch::Sebulba,
            steps: frames,
            // updates = parameter versions observed from the learner
            updates: snap.version,
            elapsed,
            throughput: frames as f64 / elapsed.max(1e-12),
            projected_throughput: frames as f64 / critical_path,
            final_params: snap.params.as_ref().clone(),
            detail: Detail::ActorLearner(ActorLearnerDetail {
                mean_staleness: stats.mean_staleness(),
                mean_episode_reward: stats.mean_episode_reward(),
                episodes: stats.episodes.load(Ordering::Relaxed),
                last_loss: stats.last_loss(),
                actor_busy_seconds: actor_busy,
                // the learning half lives in the learner pod's report
                learner_busy_seconds: 0.0,
                actor_infer_seconds: stats.actor_infer_seconds(),
                actor_env_step_seconds: stats.actor_env_seconds(),
                actor_loop_seconds: stats.actor_loop_seconds(),
                actor_overlap_seconds: stats.actor_overlap_seconds(),
                learner_grad_seconds: 0.0,
                learner_collective_seconds: 0.0,
                learner_apply_seconds: 0.0,
                learner_active_seconds: 0.0,
                learner_overlap_seconds: 0.0,
                queue_push_block_seconds: queue.push_block_seconds(),
                queue_pop_block_seconds: queue.pop_block_seconds(),
                final_opt_state: Vec::new(),
            }),
        })
    }
}

impl Runner for DistSebulba {
    fn arch(&self) -> Arch {
        Arch::Sebulba
    }

    fn run_checkpointed(&self, pod: &mut Pod, topo: &Topology, spec: &RunSpec) -> Result<Report> {
        ensure!(
            spec.is_plain(),
            "distributed runs do not support checkpoint/restore/fault injection \
             yet; run those single-process"
        );
        match self.role {
            PodRole::Learner => self.run_learner_pod(pod, topo),
            PodRole::Actor => self.run_actor_pod(pod, topo),
            PodRole::Colocated => bail!(
                "DistSebulba needs --role learner or --role actor; colocated runs \
                 use the in-memory Sebulba runner"
            ),
        }
    }
}
