//! The in-process transport: channel-backed connections that still move
//! *encoded frame bytes* (DESIGN.md §15).
//!
//! Loopback exists for two reasons. First, it lets the distributed runner
//! be tested (and bit-exactness-pinned against the in-memory coordinator)
//! without sockets. Second — and this is deliberate — it does **not**
//! shortcut the codec: every `send` runs `encode_frame` and every `recv`
//! runs `decode_frame`, so a loopback run exercises exactly the bytes a
//! TCP run puts on the wire. Only the pipe differs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::error::TransportError;
use super::frame::{decode_frame, encode_frame, FrameKind};
use super::{ConnectOpts, Connection, Listener, Transport};

type FrameBytes = Vec<u8>;

/// Shared address book: listeners register under a name, connects look the
/// name up and push their half of a crossed channel pair through it.
/// Clone-cheap — every pod thread in a test shares one transport.
#[derive(Clone)]
pub struct LoopbackTransport {
    addrs: Arc<Mutex<HashMap<String, mpsc::Sender<LoopConn>>>>,
    read_timeout: Duration,
    accept_timeout: Duration,
}

impl Default for LoopbackTransport {
    fn default() -> Self {
        Self::new()
    }
}

impl LoopbackTransport {
    pub fn new() -> Self {
        Self {
            addrs: Arc::default(),
            read_timeout: Duration::from_secs(5),
            accept_timeout: Duration::from_secs(30),
        }
    }
}

impl Transport for LoopbackTransport {
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, TransportError> {
        let (tx, rx) = mpsc::channel();
        let mut addrs = self.addrs.lock().unwrap();
        if addrs.contains_key(addr) {
            return Err(TransportError::Io(std::io::Error::new(
                std::io::ErrorKind::AddrInUse,
                format!("loopback address {addr:?} already has a listener"),
            )));
        }
        addrs.insert(addr.to_string(), tx);
        Ok(Box::new(LoopListener {
            rx,
            addr: addr.to_string(),
            accept_timeout: self.accept_timeout,
        }))
    }

    fn connect(
        &self,
        addr: &str,
        opts: &ConnectOpts,
    ) -> Result<Box<dyn Connection>, TransportError> {
        let attempts = opts.attempts.max(1);
        for attempt in 1..=attempts {
            let registered = self.addrs.lock().unwrap().get(addr).cloned();
            if let Some(accept_tx) = registered {
                let (c2s_tx, c2s_rx) = mpsc::channel::<FrameBytes>();
                let (s2c_tx, s2c_rx) = mpsc::channel::<FrameBytes>();
                let server_side = LoopConn::new(s2c_tx, c2s_rx, self.read_timeout, addr);
                if accept_tx.send(server_side).is_ok() {
                    return Ok(Box::new(LoopConn::new(
                        c2s_tx,
                        s2c_rx,
                        self.read_timeout,
                        addr,
                    )));
                }
                // listener dropped between lookup and send: fall through to retry
            }
            if attempt < attempts {
                std::thread::sleep(opts.backoff * attempt);
            }
        }
        Err(TransportError::ConnectFailed {
            addr: addr.to_string(),
            attempts,
            last: "no loopback listener at this address".to_string(),
        })
    }
}

struct LoopListener {
    rx: mpsc::Receiver<LoopConn>,
    addr: String,
    accept_timeout: Duration,
}

impl Listener for LoopListener {
    fn accept(&mut self) -> Result<Box<dyn Connection>, TransportError> {
        match self.rx.recv_timeout(self.accept_timeout) {
            Ok(conn) => Ok(Box::new(conn)),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(TransportError::ReadTimeout { waited: self.accept_timeout })
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(TransportError::Closed),
        }
    }

    fn local_addr(&self) -> String {
        self.addr.clone()
    }
}

/// One half of a crossed channel pair. Frames travel as encoded bytes;
/// closing drops our sender (the peer's receiver disconnects → `Closed`)
/// and flips a flag so our own blocked `recv` also returns promptly.
struct LoopConn {
    tx: Mutex<Option<mpsc::Sender<FrameBytes>>>,
    rx: Mutex<mpsc::Receiver<FrameBytes>>,
    closed: Arc<AtomicBool>,
    read_timeout: Duration,
    peer: String,
}

impl LoopConn {
    fn new(
        tx: mpsc::Sender<FrameBytes>,
        rx: mpsc::Receiver<FrameBytes>,
        read_timeout: Duration,
        peer: &str,
    ) -> Self {
        Self {
            tx: Mutex::new(Some(tx)),
            rx: Mutex::new(rx),
            closed: Arc::new(AtomicBool::new(false)),
            read_timeout,
            peer: peer.to_string(),
        }
    }
}

impl Connection for LoopConn {
    fn send(&self, kind: FrameKind, payload: &[u8]) -> Result<u64, TransportError> {
        let bytes = encode_frame(kind, payload);
        let n = bytes.len() as u64;
        let guard = self.tx.lock().unwrap();
        match guard.as_ref() {
            Some(tx) => tx.send(bytes).map_err(|_| TransportError::Closed)?,
            None => return Err(TransportError::Closed),
        }
        Ok(n)
    }

    fn recv(&self) -> Result<(FrameKind, Vec<u8>, u64), TransportError> {
        if self.closed.load(Ordering::Acquire) {
            return Err(TransportError::Closed);
        }
        let rx = self.rx.lock().unwrap();
        // Poll in short slices so a local `close()` interrupts a blocked
        // recv instead of waiting out the full window.
        let deadline = Instant::now() + self.read_timeout;
        loop {
            let slice = Duration::from_millis(20)
                .min(deadline.saturating_duration_since(Instant::now()));
            match rx.recv_timeout(slice) {
                Ok(bytes) => {
                    let n = bytes.len() as u64;
                    let (kind, payload) = decode_frame(&bytes)?;
                    return Ok((kind, payload, n));
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => return Err(TransportError::Closed),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if self.closed.load(Ordering::Acquire) {
                        return Err(TransportError::Closed);
                    }
                    if Instant::now() >= deadline {
                        return Err(TransportError::ReadTimeout { waited: self.read_timeout });
                    }
                }
            }
        }
    }

    fn close(&self) {
        self.closed.store(true, Ordering::Release);
        *self.tx.lock().unwrap() = None;
    }

    fn peer(&self) -> String {
        format!("loopback:{}", self.peer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_moves_real_frames() {
        let t = LoopbackTransport::new();
        let mut l = t.listen("podA").unwrap();
        let client = t.connect("podA", &ConnectOpts::default()).unwrap();
        let server = l.accept().unwrap();
        client.send(FrameKind::Params, b"hello").unwrap();
        let (kind, payload, n) = server.recv().unwrap();
        assert_eq!(kind, FrameKind::Params);
        assert_eq!(payload, b"hello");
        assert!(n > 5, "frame bytes include header + crc");
        // and the reverse direction
        server.send(FrameKind::Shutdown, &[]).unwrap();
        let (kind, payload, _) = client.recv().unwrap();
        assert_eq!(kind, FrameKind::Shutdown);
        assert!(payload.is_empty());
    }

    #[test]
    fn connect_without_listener_is_a_typed_bounded_failure() {
        let t = LoopbackTransport::new();
        let opts = ConnectOpts {
            attempts: 2,
            backoff: Duration::from_millis(1),
            ..ConnectOpts::default()
        };
        let err = t.connect("nowhere", &opts).unwrap_err();
        assert!(matches!(err, TransportError::ConnectFailed { attempts: 2, .. }), "{err}");
    }

    #[test]
    fn close_surfaces_as_closed_on_the_peer() {
        let t = LoopbackTransport::new();
        let mut l = t.listen("podB").unwrap();
        let client = t.connect("podB", &ConnectOpts::default()).unwrap();
        let server = l.accept().unwrap();
        client.close();
        assert!(server.recv().unwrap_err().is_closed());
        assert!(client.send(FrameKind::Params, b"x").unwrap_err().is_closed());
    }
}
