//! Length-prefixed, CRC-framed wire messages (DESIGN.md §15).
//!
//! Every message on a pod-to-pod connection is one frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic          "PDRW"
//! 4       1     format version (1)
//! 5       1    frame kind     (FrameKind)
//! 6       8     payload length (u64 LE, capped at MAX_FRAME_LEN)
//! 14      n     payload
//! 14+n    4     CRC32          (u32 LE, over bytes [4, 14+n) — everything
//!                              after the magic)
//! ```
//!
//! The CRC reuses the checkpoint format's IEEE implementation
//! ([`crate::checkpoint::format::crc32`]) so both persistence paths share
//! one checksum. Decoding is hostile-input safe: the length prefix is
//! capped before any allocation, a short buffer is a typed
//! [`TransportError::Truncated`], and a flipped byte lands in exactly one
//! of `BadMagic` / `UnsupportedVersion` / `BadKind` / `FrameTooLarge` /
//! `Truncated` / `CrcMismatch` (pinned by the proptests next to the
//! checkpoint fuzz suite).

use std::io::{Read, Write};

use crate::checkpoint::format::{crc32, crc32_update};

use super::error::TransportError;

/// First bytes of every frame; distinct from the checkpoint magic so a file
/// fed to the wire decoder (or vice versa) fails loudly on byte 0.
pub const WIRE_MAGIC: [u8; 4] = *b"PDRW";

/// Wire format version. Bump on any layout change; decoders reject other
/// versions with [`TransportError::UnsupportedVersion`].
pub const WIRE_VERSION: u8 = 1;

/// Bytes before the payload: magic + version + kind + length.
pub const HEADER_LEN: usize = 4 + 1 + 1 + 8;

/// Sanity cap on the declared payload length: a corrupt or hostile length
/// prefix must not drive a huge allocation. 1 GiB is far above any real
/// trajectory bundle on this testbed.
pub const MAX_FRAME_LEN: u64 = 1 << 30;

/// What a frame carries. The discriminants are the wire bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Connection setup: learner → actor, payload = the actor pod's
    /// assigned index (u64 LE).
    Hello = 1,
    /// A versioned parameter snapshot (learner → actors; `wire::encode_params`).
    Params = 2,
    /// One actor window's shard bundle (actor → learner; `wire::encode_bundle`).
    TrajBundle = 3,
    /// Orderly end-of-run; no payload. The sender closes right after.
    Shutdown = 4,
    /// Elastic admission request: actor → learner, payload =
    /// `wire::encode_join` (topology fingerprint). The learner answers
    /// with `Hello` carrying `wire::encode_admit`.
    Join = 5,
    /// Graceful departure: actor → learner, no payload. The member is
    /// retired (epoch bump) without tripping the fail-closed path.
    Leave = 6,
    /// Liveness beacon: actor → learner, no payload. Missing beacons past
    /// the heartbeat timeout evict the member.
    Heartbeat = 7,
}

impl FrameKind {
    pub fn from_u8(b: u8) -> Option<Self> {
        match b {
            1 => Some(FrameKind::Hello),
            2 => Some(FrameKind::Params),
            3 => Some(FrameKind::TrajBundle),
            4 => Some(FrameKind::Shutdown),
            5 => Some(FrameKind::Join),
            6 => Some(FrameKind::Leave),
            7 => Some(FrameKind::Heartbeat),
            _ => None,
        }
    }
}

/// Encode one frame into a fresh buffer. The payload is appended with a
/// single contiguous copy — column blocks serialized by `wire` stay one
/// memcpy end to end.
pub fn encode_frame(kind: FrameKind, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + 4);
    out.extend_from_slice(&WIRE_MAGIC);
    out.push(WIRE_VERSION);
    out.push(kind as u8);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    let crc = crc32(&out[4..]);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Decode one complete frame from `bytes`. Rejects trailing bytes — a
/// frame is a whole message, so extra bytes mean a framing bug.
pub fn decode_frame(bytes: &[u8]) -> Result<(FrameKind, Vec<u8>), TransportError> {
    if bytes.len() < 4 {
        return Err(TransportError::Truncated { context: "frame magic" });
    }
    if bytes[..4] != WIRE_MAGIC {
        let mut found = [0u8; 4];
        found.copy_from_slice(&bytes[..4]);
        return Err(TransportError::BadMagic { found });
    }
    if bytes.len() < HEADER_LEN {
        return Err(TransportError::Truncated { context: "frame header" });
    }
    if bytes[4] != WIRE_VERSION {
        return Err(TransportError::UnsupportedVersion { found: bytes[4] });
    }
    let kind = FrameKind::from_u8(bytes[5]).ok_or(TransportError::BadKind { found: bytes[5] })?;
    let len = u64::from_le_bytes(bytes[6..14].try_into().unwrap());
    if len > MAX_FRAME_LEN {
        return Err(TransportError::FrameTooLarge { len, max: MAX_FRAME_LEN });
    }
    let len = len as usize;
    let need = HEADER_LEN + len + 4;
    if bytes.len() < need {
        return Err(TransportError::Truncated { context: "frame payload" });
    }
    if bytes.len() > need {
        return Err(TransportError::Corrupt {
            context: "frame",
            detail: format!("{} trailing bytes after the frame", bytes.len() - need),
        });
    }
    let stored = u32::from_le_bytes(bytes[need - 4..need].try_into().unwrap());
    let computed = crc32(&bytes[4..need - 4]);
    if stored != computed {
        return Err(TransportError::CrcMismatch { stored, computed });
    }
    Ok((kind, bytes[HEADER_LEN..HEADER_LEN + len].to_vec()))
}

/// Write one frame to a stream. Returns the bytes written (for the wire
/// throughput counters).
pub fn write_frame<W: Write>(
    w: &mut W,
    kind: FrameKind,
    payload: &[u8],
) -> Result<u64, TransportError> {
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&WIRE_MAGIC);
    header[4] = WIRE_VERSION;
    header[5] = kind as u8;
    header[6..14].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    let crc = crc32_update(crc32_update(0xFFFF_FFFF, &header[4..]), payload) ^ 0xFFFF_FFFF;
    w.write_all(&header).map_err(map_write_err)?;
    w.write_all(payload).map_err(map_write_err)?;
    w.write_all(&crc.to_le_bytes()).map_err(map_write_err)?;
    w.flush().map_err(map_write_err)?;
    Ok(HEADER_LEN as u64 + payload.len() as u64 + 4)
}

/// Read one frame from a stream. Returns `(kind, payload, bytes_read)`.
///
/// Timeout semantics: a read timeout *before the first magic byte* is the
/// benign idle case ([`TransportError::ReadTimeout`], the caller re-checks
/// its stop flag and retries); EOF there is a clean [`TransportError::Closed`].
/// Once any frame byte has been consumed, EOF or timeout means the peer
/// died mid-message and surfaces as [`TransportError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<(FrameKind, Vec<u8>, u64), TransportError> {
    let mut magic = [0u8; 4];
    read_exact_at(r, &mut magic, true, "frame magic")?;
    if magic != WIRE_MAGIC {
        return Err(TransportError::BadMagic { found: magic });
    }
    let mut rest = [0u8; HEADER_LEN - 4];
    read_exact_at(r, &mut rest, false, "frame header")?;
    if rest[0] != WIRE_VERSION {
        return Err(TransportError::UnsupportedVersion { found: rest[0] });
    }
    let kind = FrameKind::from_u8(rest[1]).ok_or(TransportError::BadKind { found: rest[1] })?;
    let len = u64::from_le_bytes(rest[2..10].try_into().unwrap());
    if len > MAX_FRAME_LEN {
        return Err(TransportError::FrameTooLarge { len, max: MAX_FRAME_LEN });
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_at(r, &mut payload, false, "frame payload")?;
    let mut crc_buf = [0u8; 4];
    read_exact_at(r, &mut crc_buf, false, "frame crc")?;
    let stored = u32::from_le_bytes(crc_buf);
    let computed = crc32_update(crc32_update(0xFFFF_FFFF, &rest), &payload) ^ 0xFFFF_FFFF;
    if stored != computed {
        return Err(TransportError::CrcMismatch { stored, computed });
    }
    let total = HEADER_LEN as u64 + len + 4;
    Ok((kind, payload, total))
}

fn read_exact_at<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    frame_start: bool,
    context: &'static str,
) -> Result<(), TransportError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) => Err(match e.kind() {
            std::io::ErrorKind::UnexpectedEof if frame_start => TransportError::Closed,
            std::io::ErrorKind::UnexpectedEof => TransportError::Truncated { context },
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut if frame_start => {
                TransportError::ReadTimeout { waited: std::time::Duration::ZERO }
            }
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                TransportError::Truncated { context }
            }
            std::io::ErrorKind::ConnectionReset | std::io::ErrorKind::BrokenPipe
                if frame_start =>
            {
                TransportError::Closed
            }
            _ => TransportError::Io(e),
        }),
    }
}

fn map_write_err(e: std::io::Error) -> TransportError {
    match e.kind() {
        std::io::ErrorKind::BrokenPipe
        | std::io::ErrorKind::ConnectionReset
        | std::io::ErrorKind::ConnectionAborted
        | std::io::ErrorKind::UnexpectedEof => TransportError::Closed,
        _ => TransportError::Io(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrips_through_bytes_and_streams() {
        let payload: Vec<u8> = (0..=255).collect();
        let bytes = encode_frame(FrameKind::TrajBundle, &payload);
        let (kind, back) = decode_frame(&bytes).unwrap();
        assert_eq!(kind, FrameKind::TrajBundle);
        assert_eq!(back, payload);

        // streaming writer produces the identical byte sequence
        let mut streamed = Vec::new();
        let n = write_frame(&mut streamed, FrameKind::TrajBundle, &payload).unwrap();
        assert_eq!(streamed, bytes);
        assert_eq!(n as usize, bytes.len());

        let mut cursor = std::io::Cursor::new(&bytes);
        let (kind, back, read) = read_frame(&mut cursor).unwrap();
        assert_eq!(kind, FrameKind::TrajBundle);
        assert_eq!(back, payload);
        assert_eq!(read as usize, bytes.len());
    }

    #[test]
    fn empty_payload_frames_work() {
        let bytes = encode_frame(FrameKind::Shutdown, &[]);
        assert_eq!(bytes.len(), HEADER_LEN + 4);
        let (kind, payload) = decode_frame(&bytes).unwrap();
        assert_eq!(kind, FrameKind::Shutdown);
        assert!(payload.is_empty());
    }

    #[test]
    fn eof_between_frames_is_closed_not_truncated() {
        let mut empty = std::io::Cursor::new(Vec::<u8>::new());
        assert!(matches!(read_frame(&mut empty), Err(TransportError::Closed)));
        // ... but EOF inside a frame is a typed truncation
        let bytes = encode_frame(FrameKind::Params, b"abc");
        let mut cut = std::io::Cursor::new(bytes[..bytes.len() - 2].to_vec());
        assert!(matches!(
            read_frame(&mut cut),
            Err(TransportError::Truncated { .. })
        ));
    }

    #[test]
    fn hostile_length_is_capped_before_allocation() {
        let mut bytes = encode_frame(FrameKind::Params, b"xy");
        bytes[6..14].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            decode_frame(&bytes),
            Err(TransportError::FrameTooLarge { .. })
        ));
    }

    #[test]
    fn wrong_magic_version_kind_each_get_their_variant() {
        let good = encode_frame(FrameKind::Hello, b"p");
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(decode_frame(&bad), Err(TransportError::BadMagic { .. })));
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(matches!(
            decode_frame(&bad),
            Err(TransportError::UnsupportedVersion { found: 99 })
        ));
        let mut bad = good.clone();
        bad[5] = 0xEE;
        assert!(matches!(decode_frame(&bad), Err(TransportError::BadKind { found: 0xEE })));
        let mut bad = good;
        *bad.last_mut().unwrap() ^= 0x01;
        assert!(matches!(decode_frame(&bad), Err(TransportError::CrcMismatch { .. })));
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut bytes = encode_frame(FrameKind::Hello, b"p");
        bytes.push(0);
        assert!(matches!(decode_frame(&bytes), Err(TransportError::Corrupt { .. })));
    }
}
