//! The multi-pod transport seam (DESIGN.md §15).
//!
//! Everything above this module speaks [`Transport`] / [`Listener`] /
//! [`Connection`] — the seam the ROADMAP's "take TensorBus over the wire"
//! item carves under `coordinator/collective.rs`. Below it live two
//! interchangeable pipes:
//!
//! * [`loopback::LoopbackTransport`] — in-process channels that still move
//!   encoded frame bytes (the codec runs; only the pipe is fake);
//! * [`tcp::TcpTransport`] — real sockets, length-prefixed CRC-framed
//!   messages, connect/read timeouts with bounded retry + backoff.
//!
//! On top of the seam, [`dist::DistSebulba`] runs one Sebulba experiment as
//! a learner pod plus K actor-pod processes: trajectory bundles flow
//! actor→learner as [`frame::FrameKind::TrajBundle`] frames preserving the
//! arena's shard-major layout ([`wire`]), and versioned parameters flow
//! learner→actors as [`frame::FrameKind::Params`] frames with
//! `latest_if_newer` pub/sub semantics.
//!
//! The robustness contract is uniform: every blocking call has a timeout,
//! every failure is a [`TransportError`] variant, and a dead peer
//! propagates — never a hang, never a silent drop (the TensorBus poisoning
//! discipline of DESIGN.md §10, extended over the wire).

pub mod dist;
pub mod error;
pub mod frame;
pub mod loopback;
pub mod membership;
pub mod tcp;
pub mod wire;

pub use dist::DistSebulba;
pub use error::TransportError;
pub use frame::FrameKind;
pub use loopback::LoopbackTransport;
pub use membership::{Departure, Membership, PodSlot};
pub use tcp::TcpTransport;

use std::time::Duration;

/// Dial-side knobs: how long one connect attempt may take, how many
/// attempts the budget allows, and the (linear) backoff between them.
#[derive(Clone, Debug)]
pub struct ConnectOpts {
    pub connect_timeout: Duration,
    /// Total attempt budget — retry is bounded by construction.
    pub attempts: u32,
    /// Backoff between attempts grows linearly: `backoff * attempt`.
    pub backoff: Duration,
}

impl Default for ConnectOpts {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(1),
            attempts: 10,
            backoff: Duration::from_millis(50),
        }
    }
}

/// A pipe factory: bind a listener or dial a peer. Implementations are
/// cheap to clone/share across pod threads.
pub trait Transport: Send + Sync {
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, TransportError>;
    fn connect(&self, addr: &str, opts: &ConnectOpts)
        -> Result<Box<dyn Connection>, TransportError>;
}

/// An accept loop with a deadline: waiting for a pod that never comes is a
/// typed [`TransportError::ReadTimeout`], not a hang.
pub trait Listener: Send {
    fn accept(&mut self) -> Result<Box<dyn Connection>, TransportError>;
    fn local_addr(&self) -> String;
}

/// One framed, bidirectional pod-to-pod connection. `send`/`recv` take
/// `&self` so a receiver thread can block in `recv` while another thread
/// `send`s (TCP backs this with independently locked socket clones). Both
/// return the frame's wire size for the throughput counters.
pub trait Connection: Send + Sync {
    fn send(&self, kind: FrameKind, payload: &[u8]) -> Result<u64, TransportError>;
    /// Blocks up to the transport's read timeout; an expired idle window is
    /// `TransportError::ReadTimeout` (retry after re-checking stop flags).
    fn recv(&self) -> Result<(FrameKind, Vec<u8>, u64), TransportError>;
    /// Close both directions; the peer's next `recv` sees `Closed`.
    fn close(&self);
    fn peer(&self) -> String;
}
