//! `podracer` CLI: train Anakin / Sebulba / MuZero agents from the terminal.
//!
//! ```text
//! podracer anakin   [--agent anakin_catch] [--cores 4] [--outer-iters 20] [--mode bundled|psum]
//!                   [--driver threaded|serial]
//! podracer sebulba  [--agent seb_catch] [--env catch] [--actor-cores 2] [--learner-cores 2]
//!                   [--batch 32] [--pipeline-stages 2] [--unroll 20] [--updates 100]
//!                   [--replicas 1] [--threads 2] [--data-path arena|copy]
//! podracer muzero   [--updates 20] [--simulations 16]
//! podracer info     # list artifacts & agents
//! ```

use anyhow::Result;
use podracer::anakin::{Anakin, AnakinConfig, Driver, Mode};
use podracer::coordinator::{Sebulba, SebulbaConfig};
use podracer::runtime::Pod;
use podracer::search::{run_muzero, MuZeroRunConfig};
use podracer::util::cli::Args;

fn main() {
    podracer::util::logging::init();
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn env_kind_static(name: &str) -> &'static str {
    match name {
        "catch" => "catch",
        "gridworld" => "gridworld",
        "cartpole" => "cartpole",
        "chain" => "chain",
        "atari_like" => "atari_like",
        _ => "catch",
    }
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    let artifacts = podracer::artifacts_dir();
    match cmd {
        "anakin" => {
            let cfg = AnakinConfig {
                agent: args.get_str("agent", "anakin_catch"),
                cores: args.get_usize("cores", 4)?,
                outer_iters: args.get_u64("outer-iters", 20)?,
                mode: if args.get_str("mode", "bundled") == "psum" {
                    Mode::Psum
                } else {
                    Mode::Bundled
                },
                driver: match args.get_str("driver", "threaded").as_str() {
                    "threaded" => Driver::Threaded,
                    "serial" => Driver::Serial,
                    other => anyhow::bail!("--driver expects threaded|serial, got {other:?}"),
                },
                seed: args.get_u64("seed", 7)?,
            };
            let report = Anakin::run(&artifacts, &cfg)?;
            println!(
                "anakin: steps={} updates={} elapsed={:.2}s sps={:.0} projected_sps={:.0}",
                report.steps, report.updates, report.elapsed, report.sps, report.projected_sps
            );
            println!(
                "  replica schedule: device={:.2}s host={:.2}s collective={:.2}s hidden_by_overlap={:.2}s busy_max={:.2}s",
                report.replica_device_seconds,
                report.replica_host_seconds,
                report.replica_collective_seconds,
                report.replica_overlap_seconds,
                report.replica_busy_max_seconds
            );
            if let (Some(first), Some(last)) = (report.metrics.first(), report.metrics.last()) {
                println!(
                    "  reward: {:.3} -> {:.3} | loss: {:.4} -> {:.4}",
                    first[4], last[4], first[0], last[0]
                );
            }
            Ok(())
        }
        "sebulba" => {
            let cfg = SebulbaConfig {
                agent: args.get_str("agent", "seb_catch"),
                env_kind: env_kind_static(&args.get_str("env", "catch")),
                actor_cores: args.get_usize("actor-cores", 2)?,
                learner_cores: args.get_usize("learner-cores", 2)?,
                threads_per_actor_core: args.get_usize("threads", 2)?,
                actor_batch: args.get_usize("batch", 32)?,
                pipeline_stages: args.get_usize("pipeline-stages", 2)?,
                learner_pipeline: args.get_usize("learner-pipeline", 2)?,
                unroll: args.get_usize("unroll", 20)?,
                micro_batches: args.get_usize("micro-batches", 1)?,
                discount: args.get_f64("discount", 0.99)? as f32,
                queue_capacity: args.get_usize("queue", 4)?,
                env_workers: args.get_usize("env-workers", 2)?,
                replicas: args.get_usize("replicas", 1)?,
                total_updates: args.get_u64("updates", 100)?,
                seed: args.get_u64("seed", 42)?,
                copy_path: match args.get_str("data-path", "arena").as_str() {
                    "arena" => false,
                    "copy" => true,
                    other => anyhow::bail!("--data-path expects arena|copy, got {other:?}"),
                },
            };
            let report = Sebulba::run(&artifacts, &cfg)?;
            println!(
                "sebulba: frames={} updates={} elapsed={:.2}s fps={:.0} projected_fps={:.0}",
                report.frames, report.updates, report.elapsed, report.fps, report.projected_fps
            );
            println!(
                "  episodes={} mean_reward={:.3} staleness={:.2} last_loss={:.4}",
                report.episodes, report.mean_episode_reward, report.mean_staleness, report.last_loss
            );
            println!(
                "  actor pipeline: infer={:.2}s env_step={:.2}s hidden_by_overlap={:.2}s",
                report.actor_infer_seconds,
                report.actor_env_step_seconds,
                report.actor_overlap_seconds
            );
            println!(
                "  learner pipeline: grad={:.2}s collective={:.2}s apply={:.2}s hidden_by_overlap={:.2}s",
                report.learner_grad_seconds,
                report.learner_collective_seconds,
                report.learner_apply_seconds,
                report.learner_overlap_seconds
            );
            Ok(())
        }
        "muzero" => {
            let cfg = MuZeroRunConfig {
                agent: args.get_str("agent", "mz_catch"),
                env_kind: env_kind_static(&args.get_str("env", "catch")),
                actor_cores: args.get_usize("actor-cores", 2)?,
                learner_cores: args.get_usize("learner-cores", 2)?,
                threads_per_actor_core: args.get_usize("threads", 1)?,
                num_simulations: args.get_usize("simulations", 16)?,
                learner_pipeline: args.get_usize("learner-pipeline", 1)?,
                discount: args.get_f64("discount", 0.997)? as f32,
                queue_capacity: args.get_usize("queue", 4)?,
                env_workers: args.get_usize("env-workers", 2)?,
                replicas: args.get_usize("replicas", 1)?,
                total_updates: args.get_u64("updates", 20)?,
                seed: args.get_u64("seed", 11)?,
            };
            let mut pod = Pod::new(&artifacts, cfg.total_cores())?;
            let report = run_muzero(&mut pod, &cfg)?;
            println!(
                "muzero: frames={} updates={} elapsed={:.2}s fps={:.0} mean_reward={:.3}",
                report.frames, report.updates, report.elapsed, report.fps, report.mean_episode_reward
            );
            Ok(())
        }
        "info" => {
            let manifest = podracer::runtime::Manifest::load(&artifacts)?;
            println!("artifacts: {}", artifacts.display());
            println!("agents:");
            for (name, a) in &manifest.agents {
                println!(
                    "  {name}: kind={} params={} opt={} obs={:?} actions={}",
                    a.kind, a.param_size, a.opt_size, a.obs_shape, a.num_actions
                );
            }
            println!("programs: {}", manifest.programs.len());
            for name in manifest.programs.keys() {
                println!("  {name}");
            }
            Ok(())
        }
        _ => {
            println!(
                "usage: podracer <anakin|sebulba|muzero|info> [--flags]\n\
                 run `podracer info` to list available agents/artifacts"
            );
            Ok(())
        }
    }
}
