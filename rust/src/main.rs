//! `podracer` CLI: train Anakin / Sebulba / MuZero agents from the terminal.
//!
//! ```text
//! podracer anakin   [--agent anakin_catch] [--cores 4] [--outer-iters 20] [--mode bundled|psum]
//!                   [--driver threaded|serial]
//! podracer sebulba  [--agent seb_catch] [--env catch] [--actor-cores 2] [--learner-cores 2]
//!                   [--batch 32] [--pipeline-stages 2] [--unroll 20] [--updates 100]
//!                   [--replicas 1] [--threads 2] [--data-path arena|copy]
//!                   multi-pod (DESIGN.md §15): [--pods 3] [--role learner|actor]
//!                   [--listen 127.0.0.1:7070] [--connect 127.0.0.1:7070]
//! podracer muzero   [--env catch] [--updates 20] [--simulations 16]
//! podracer serve    [--agent seb_catch] [--env catch] [--batch 8] [--pipeline-stages 1]
//!                   [--queue 8] [--sessions 8] [--steps 40] [--swap-every 100]
//! podracer plan     [--arch sebulba] [--env catch] [--pod-cores 4] [--calibrate] [--measure]
//!                   # ranked feasible topologies from the cost model (DESIGN.md §17)
//! podracer league   [--agent seb_catch] [--players 4] [--rounds 1] [--concurrency 1]
//!                   # round-robin self-play over shared pods
//! podracer info     # list artifacts & agents
//!
//! all training subcommands also take the elasticity knobs (DESIGN.md §13):
//!                   [--checkpoint-every N] [--checkpoint-path run.ckpt]
//!                   [--restore run.ckpt]
//! the planner knobs: [--topology auto] [--pod-cores 4] [--cost-model artifacts/cost_model.json]
//! and machine-readable reports: [--report-json report.json]
//! ```
//!
//! Every architecture goes through one declarative path
//! (`experiment::Experiment::from_args` — DESIGN.md §12): the subcommand
//! parses to an `Arch`, the flags to a typed `Topology`/`EnvKind`/workload,
//! and the unified `Report` prints itself. `podracer serve` drives the
//! policy-serving frontend (DESIGN.md §14) through the same hard-error
//! flag parsing (`experiment::serve_from_args`); `podracer plan` and
//! `podracer league` route through `plan::cli` / `league::cli`. Unknown
//! subcommands, flag names and flag values all exit nonzero with a
//! diagnostic (`podracer help` shows usage).

use anyhow::{Context, Result};
use podracer::experiment::{Arch, Experiment};
use podracer::util::cli::Args;
use podracer::util::json::Json;

fn main() {
    podracer::util::logging::init();
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

/// Write a report's JSON form when `--report-json <path>` was given. A
/// bare flag is a hard error — never a silently skipped report.
fn write_report_json(args: &Args, json: &Json) -> Result<()> {
    let Some(path) = args.flags.get("report-json") else {
        return Ok(());
    };
    if path.is_empty() || path == "true" {
        anyhow::bail!("--report-json expects a file path");
    }
    std::fs::write(path, format!("{json}\n")).with_context(|| format!("writing {path}"))
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "anakin" | "sebulba" | "muzero" => {
            let arch: Arch = cmd.parse()?;
            let report = Experiment::from_args(arch, args)?.run()?;
            println!("{}", report.summary());
            write_report_json(args, &report.to_json())
        }
        "serve" => {
            let cfg = podracer::experiment::serve_from_args(args)?;
            let report = podracer::serve::run(&podracer::artifacts_dir(), &cfg)?;
            println!("{}", report.summary(&cfg.agent));
            write_report_json(args, &report.to_json())
        }
        "plan" => podracer::plan::cli::run(args),
        "league" => podracer::league::cli::run(args),
        "info" => {
            let artifacts = podracer::artifacts_dir();
            let manifest = podracer::runtime::Manifest::load(&artifacts)?;
            println!("artifacts: {}", artifacts.display());
            println!("agents:");
            for (name, a) in &manifest.agents {
                println!(
                    "  {name}: kind={} params={} opt={} obs={:?} actions={}",
                    a.kind, a.param_size, a.opt_size, a.obs_shape, a.num_actions
                );
            }
            println!("programs: {}", manifest.programs.len());
            for name in manifest.programs.keys() {
                println!("  {name}");
            }
            Ok(())
        }
        "help" => {
            println!(
                "usage: podracer <anakin|sebulba|muzero|serve|plan|league|info> [--flags]\n\
                 run `podracer info` to list available agents/artifacts"
            );
            Ok(())
        }
        other => {
            // unknown subcommands are hard errors like unknown flags are —
            // a typo'd CI step must not exit 0 having trained nothing
            anyhow::bail!(
                "unknown command {other:?} (valid: anakin, sebulba, muzero, serve, plan, \
                 league, info, help)"
            )
        }
    }
}
