//! Injectable fault plans for the elastic-pod resilience tests
//! (DESIGN.md §13). A `FaultPlan` rides into a run through
//! `experiment::RunSpec` and fires at well-defined seams:
//!
//! * **kill-replica** — the learner thread of replica `replica` errors out
//!   at the start of update round `round`, as if the process died. The run
//!   fails; the test restarts it from the last checkpoint and asserts the
//!   continuation is bit-identical to an uninterrupted run.
//! * **poison-queue** — the trajectory queue dies abruptly after N shard
//!   pushes (`BoundedQueue::poison_after_pushes`): every later push/pop is
//!   a typed `QueueError::Poisoned`, unlike the orderly drain of shutdown.
//! * **truncate-checkpoint** — the checkpoint file is cut to `len` bytes
//!   right after a successful save, so the next restore must surface a
//!   typed `CheckpointError::Truncated`, never a partial load.
//!
//! Plans are plain data; production paths check them only when one is
//! present, so a `FaultPlan::default()` run is fault-free.

/// Kill learner replica `replica` at the start of update round `round`
/// (0-based: round `r` is the one that would produce publish `r + 1`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillReplica {
    pub replica: usize,
    pub round: u64,
}

/// The full set of faults a test can schedule for one run. All fields are
/// independent; `default()` injects nothing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Fail a learner replica at a specific round.
    pub kill_replica: Option<KillReplica>,
    /// Poison the trajectory queue once this many shards were pushed.
    pub poison_queue_after: Option<u64>,
    /// Truncate the checkpoint file to this many bytes after each save.
    pub truncate_checkpoint_to: Option<u64>,
}

impl FaultPlan {
    /// Schedule a replica death at `(replica, round)`.
    pub fn kill_replica(replica: usize, round: u64) -> Self {
        Self { kill_replica: Some(KillReplica { replica, round }), ..Self::default() }
    }

    /// Schedule an abrupt queue death after `after_pushes` shard pushes.
    pub fn poison_queue(after_pushes: u64) -> Self {
        Self { poison_queue_after: Some(after_pushes), ..Self::default() }
    }

    /// Schedule checkpoint-file truncation to `len` bytes after each save.
    pub fn truncate_checkpoint(len: u64) -> Self {
        Self { truncate_checkpoint_to: Some(len), ..Self::default() }
    }

    /// True if the kill fault fires for this `(replica, round)`.
    pub fn should_kill(&self, replica: usize, round: u64) -> bool {
        self.kill_replica == Some(KillReplica { replica, round })
    }

    /// True if the plan injects nothing at all.
    pub fn is_empty(&self) -> bool {
        *self == Self::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert!(!p.should_kill(0, 0));
        assert_eq!(p.poison_queue_after, None);
        assert_eq!(p.truncate_checkpoint_to, None);
    }

    #[test]
    fn kill_fires_only_at_its_coordinates() {
        let p = FaultPlan::kill_replica(1, 3);
        assert!(!p.is_empty());
        assert!(p.should_kill(1, 3));
        assert!(!p.should_kill(0, 3));
        assert!(!p.should_kill(1, 2));
        assert!(!p.should_kill(1, 4));
    }

    #[test]
    fn constructors_set_one_fault_each() {
        assert_eq!(FaultPlan::poison_queue(5).poison_queue_after, Some(5));
        assert_eq!(FaultPlan::poison_queue(5).kill_replica, None);
        assert_eq!(FaultPlan::truncate_checkpoint(16).truncate_checkpoint_to, Some(16));
    }
}
