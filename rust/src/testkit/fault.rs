//! Injectable fault plans for the elastic-pod resilience tests
//! (DESIGN.md §13). A `FaultPlan` rides into a run through
//! `experiment::RunSpec` and fires at well-defined seams:
//!
//! * **kill-replica** — the learner thread of replica `replica` errors out
//!   at the start of update round `round`, as if the process died. The run
//!   fails; the test restarts it from the last checkpoint and asserts the
//!   continuation is bit-identical to an uninterrupted run.
//! * **poison-queue** — the trajectory queue dies abruptly after N shard
//!   pushes (`BoundedQueue::poison_after_pushes`): every later push/pop is
//!   a typed `QueueError::Poisoned`, unlike the orderly drain of shutdown.
//! * **truncate-checkpoint** — the checkpoint file is cut to `len` bytes
//!   right after a successful save, so the next restore must surface a
//!   typed `CheckpointError::Truncated`, never a partial load.
//!
//! Plans are plain data; production paths check them only when one is
//! present, so a `FaultPlan::default()` run is fault-free.

/// Kill learner replica `replica` at the start of update round `round`
/// (0-based: round `r` is the one that would produce publish `r + 1`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillReplica {
    pub replica: usize,
    pub round: u64,
}

/// A pod-level fault coordinate for elastic distributed runs
/// (DESIGN.md §16): which actor pod (by join ordinal — the order the
/// learner admits them, which for self-injected faults is the pod's own
/// membership index) and at which point in its run (`round` counts the
/// pod's completed trajectory windows, or for `delay_admit` the learner
/// update count to wait for).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PodFault {
    pub pod: usize,
    pub round: u64,
}

/// The full set of faults a test can schedule for one run. All fields are
/// independent; `default()` injects nothing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Fail a learner replica at a specific round.
    pub kill_replica: Option<KillReplica>,
    /// Poison the trajectory queue once this many shards were pushed.
    pub poison_queue_after: Option<u64>,
    /// Truncate the checkpoint file to this many bytes after each save.
    pub truncate_checkpoint_to: Option<u64>,
    /// Actor pod `pod` dies abruptly after `round` windows: connection
    /// dropped with no `Leave`, as if the process was killed.
    pub kill_pod: Option<PodFault>,
    /// Actor pod `pod` goes silent after `round` windows: stops sending
    /// bundles *and* heartbeats without closing, so the learner's only
    /// way out is the heartbeat-timeout eviction.
    pub hang_pod: Option<PodFault>,
    /// Actor pod `pod` departs gracefully (a `Leave` frame) after `round`
    /// windows.
    pub leave_pod: Option<PodFault>,
    /// Learner-side: park the `pod`-th join (0-based admission ordinal)
    /// until the learner has finished `round` updates, then admit it —
    /// the delayed-join fault for the late-joiner oracle.
    pub delay_admit: Option<PodFault>,
}

impl FaultPlan {
    /// Schedule a replica death at `(replica, round)`.
    pub fn kill_replica(replica: usize, round: u64) -> Self {
        Self { kill_replica: Some(KillReplica { replica, round }), ..Self::default() }
    }

    /// Schedule an abrupt queue death after `after_pushes` shard pushes.
    pub fn poison_queue(after_pushes: u64) -> Self {
        Self { poison_queue_after: Some(after_pushes), ..Self::default() }
    }

    /// Schedule checkpoint-file truncation to `len` bytes after each save.
    pub fn truncate_checkpoint(len: u64) -> Self {
        Self { truncate_checkpoint_to: Some(len), ..Self::default() }
    }

    /// Schedule an abrupt actor-pod death (no `Leave`) at `(pod, round)`.
    pub fn kill_pod(pod: usize, round: u64) -> Self {
        Self { kill_pod: Some(PodFault { pod, round }), ..Self::default() }
    }

    /// Schedule an actor pod going silent (no frames, no close) at
    /// `(pod, round)`.
    pub fn hang_pod(pod: usize, round: u64) -> Self {
        Self { hang_pod: Some(PodFault { pod, round }), ..Self::default() }
    }

    /// Schedule a graceful actor-pod departure at `(pod, round)`.
    pub fn leave_pod(pod: usize, round: u64) -> Self {
        Self { leave_pod: Some(PodFault { pod, round }), ..Self::default() }
    }

    /// Schedule the `pod`-th join to be parked until `round` learner
    /// updates have finished.
    pub fn delay_admit(pod: usize, round: u64) -> Self {
        Self { delay_admit: Some(PodFault { pod, round }), ..Self::default() }
    }

    /// True if the kill fault fires for this `(replica, round)`.
    pub fn should_kill(&self, replica: usize, round: u64) -> bool {
        self.kill_replica == Some(KillReplica { replica, round })
    }

    /// True if the plan carries any pod-level (elastic) fault.
    pub fn has_pod_faults(&self) -> bool {
        self.kill_pod.is_some()
            || self.hang_pod.is_some()
            || self.leave_pod.is_some()
            || self.delay_admit.is_some()
    }

    /// True if the plan carries *only* pod-level faults — the shape an
    /// elastic distributed run accepts (thread-level faults still need
    /// the single-process lockstep machinery of DESIGN.md §13).
    pub fn pod_faults_only(&self) -> bool {
        self.has_pod_faults()
            && self.kill_replica.is_none()
            && self.poison_queue_after.is_none()
            && self.truncate_checkpoint_to.is_none()
    }

    /// True if the plan injects nothing at all.
    pub fn is_empty(&self) -> bool {
        *self == Self::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert!(!p.should_kill(0, 0));
        assert_eq!(p.poison_queue_after, None);
        assert_eq!(p.truncate_checkpoint_to, None);
    }

    #[test]
    fn kill_fires_only_at_its_coordinates() {
        let p = FaultPlan::kill_replica(1, 3);
        assert!(!p.is_empty());
        assert!(p.should_kill(1, 3));
        assert!(!p.should_kill(0, 3));
        assert!(!p.should_kill(1, 2));
        assert!(!p.should_kill(1, 4));
    }

    #[test]
    fn constructors_set_one_fault_each() {
        assert_eq!(FaultPlan::poison_queue(5).poison_queue_after, Some(5));
        assert_eq!(FaultPlan::poison_queue(5).kill_replica, None);
        assert_eq!(FaultPlan::truncate_checkpoint(16).truncate_checkpoint_to, Some(16));
    }

    #[test]
    fn pod_faults_are_classified_apart_from_thread_faults() {
        let p = FaultPlan::kill_pod(1, 2);
        assert_eq!(p.kill_pod, Some(PodFault { pod: 1, round: 2 }));
        assert!(p.has_pod_faults() && p.pod_faults_only() && !p.is_empty());
        assert!(FaultPlan::hang_pod(0, 1).pod_faults_only());
        assert!(FaultPlan::leave_pod(0, 1).pod_faults_only());
        assert!(FaultPlan::delay_admit(1, 3).pod_faults_only());
        // thread-level faults are not pod faults, and a mixed plan is
        // not pod-faults-only
        assert!(!FaultPlan::kill_replica(0, 1).has_pod_faults());
        let mixed = FaultPlan { poison_queue_after: Some(2), ..FaultPlan::kill_pod(0, 1) };
        assert!(mixed.has_pod_faults() && !mixed.pod_faults_only());
        assert!(!FaultPlan::default().has_pod_faults());
        assert!(!FaultPlan::default().pod_faults_only());
    }
}
