//! Property-testing mini-framework (the vendored crate set has no proptest).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it retries with simpler inputs from the same generator
//! (shrink-lite: generators are size-parameterised, and the runner replays
//! at decreasing sizes) and reports the seed so the case can be replayed
//! deterministically.
//!
//! ```no_run
//! use podracer::testkit::{check, Gen};
//! check("sum is commutative", 100, |g| (g.usize(0, 100), g.usize(0, 100)),
//!       |&(a, b)| if a + b == b + a { Ok(()) } else { Err("nope".into()) });
//! ```

pub mod fault;

pub use fault::{FaultPlan, KillReplica, PodFault};

use crate::util::rng::Xoshiro256;

/// Size-aware generator context handed to generator closures.
pub struct Gen {
    rng: Xoshiro256,
    /// Size hint in [0.0, 1.0]; generators should scale ranges by it so the
    /// shrink pass can retry failures with smaller inputs.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Self { rng: Xoshiro256::new(seed), size }
    }

    /// Integer in [lo, hi] (inclusive), scaled toward `lo` at small sizes.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.next_below(span as u32 + 1) as usize
    }

    pub fn i32(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size).round() as u32;
        lo + self.rng.next_below(span + 1) as i32
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * (self.size as f32) * self.rng.next_f32()
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.size * self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len() as u32) as usize]
    }
}

/// Run a property over `cases` generated inputs. Panics with a replayable
/// seed + the failure message on the smallest failing size found.
pub fn check<T, G, P>(name: &str, cases: u64, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Gen) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let base_seed = match std::env::var("PODRACER_PROPTEST_SEED") {
        Ok(s) => s.parse().unwrap_or(0xC0FFEE),
        Err(_) => 0xC0FFEE,
    };
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed, 1.0);
        let input = gen(&mut g);
        if let Err(msg) = prop(&input) {
            // shrink-lite: replay the same seed at smaller sizes; report the
            // smallest size that still fails.
            let mut smallest = (1.0, format!("{input:?}"), msg);
            for &size in &[0.5, 0.25, 0.1, 0.02] {
                let mut g = Gen::new(seed, size);
                let small = gen(&mut g);
                if let Err(m) = prop(&small) {
                    smallest = (size, format!("{small:?}"), m);
                }
            }
            panic!(
                "property {name:?} failed (seed={seed:#x}, case={case}, size={}):\n  input: {}\n  error: {}\n  replay with PODRACER_PROPTEST_SEED={base_seed}",
                smallest.0, smallest.1, smallest.2
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 50, |g| (g.usize(0, 1000), g.usize(0, 1000)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |g| g.usize(0, 10), |_| Err("always fails".into()));
    }

    #[test]
    fn generator_respects_bounds() {
        let mut g = Gen::new(1, 1.0);
        for _ in 0..1000 {
            let v = g.usize(3, 17);
            assert!((3..=17).contains(&v));
            let f = g.f32(-2.0, 2.0);
            assert!((-2.0..=2.0).contains(&f));
            let i = g.i32(-5, 5);
            assert!((-5..=5).contains(&i));
        }
    }

    #[test]
    fn small_size_shrinks_ranges() {
        let mut g = Gen::new(2, 0.02);
        for _ in 0..100 {
            assert!(g.usize(0, 1000) <= 20);
        }
    }
}
