//! The two Anakin host schedules (DESIGN.md §10).
//!
//! [`run_serial`] is the single-thread reference: issue every core's call,
//! drain and convert in core order, tree-reduce on the driver thread,
//! re-distribute. [`run_threaded`] replicates the host too: one replica
//! thread per core ([`super::replica`]), the pmean on the `TensorBus`.
//! Both consume the same [`Setup`] (same program loading, same per-core
//! init, same pre-drawn seed table), so their final parameters are
//! bit-identical and any throughput gap is purely the host schedule.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::checkpoint::{
    core_env_section, expect_field, Checkpoint, CheckpointSpec, CoreEnvSection, MetaSection,
    StoreSection, META_SECTION, STORE_SECTION,
};
use crate::coordinator::collective::{all_reduce_mean, TensorBus};
use crate::coordinator::stats::RunStats;
use crate::experiment::{AnakinDetail, Arch, Detail, MetricRow, Report, RunSpec, Topology};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{DeviceHandle, Pod};

use super::replica::{self, ReplicaConfig};
use super::{Anakin, Mode};

/// One core's share of the replicated program state.
pub(super) struct CoreInit {
    pub core: DeviceHandle,
    pub params: HostTensor,
    pub opt: HostTensor,
    pub env_states: HostTensor,
}

/// Everything both drivers share: loaded programs, per-core init state, the
/// seed table, and the busy-time baseline `projected_sps` subtracts so a
/// reused pod does not charge this run with previous runs' device time.
pub(super) struct Setup {
    pub batch: usize,
    pub unroll: usize,
    pub iters: usize,
    pub bundled: String,
    pub psum_grad: String,
    pub apply: String,
    pub states: Vec<CoreInit>,
    /// `seeds[outer][core]` — drawn outer-major, core-minor from the run
    /// seed's 0xA11A stream, the exact order the serial driver always used,
    /// so both drivers consume identical program seeds.
    pub seeds: Vec<Vec<i32>>,
    pub cores: Vec<DeviceHandle>,
    pub busy0: Vec<f64>,
}

pub(super) fn prepare(pod: &mut Pod, run: &Anakin, cores: usize) -> Result<Setup> {
    anyhow::ensure!(cores >= 1, "need at least one core");
    anyhow::ensure!(pod.n_cores() >= cores, "pod too small");
    let agent = pod.manifest.agent(&run.agent)?.clone();
    let batch = agent.extra_usize("batch")?;
    let unroll = agent.extra_usize("unroll")?;
    let iters = agent.extra_usize("iters")?;

    let init = format!("{}_init", run.agent);
    let bundled = format!("{}_bundled", run.agent);
    let psum_grad = format!("{}_psum_grad", run.agent);
    let apply = format!("{}_apply", run.agent);
    let core_ids: Vec<usize> = (0..cores).collect();
    match run.mode {
        Mode::Bundled => pod.load_programs(&[init.as_str(), bundled.as_str()], &core_ids)?,
        Mode::Psum => {
            pod.load_programs(&[init.as_str(), psum_grad.as_str()], &core_ids)?;
            pod.load_program(&apply, &[0])?;
        }
    }
    let handles = pod.handles_for(&core_ids)?;
    let busy0 = handles.iter().map(|c| c.busy_seconds()).collect();

    // Per-core init: same parameters everywhere (core 0's), but each core
    // gets its own env-state batch from its own seed — the vmap'd env
    // batch is what differs across cores on a real pod too.
    let mut states = Vec::with_capacity(cores);
    let mut shared_params: Option<HostTensor> = None;
    let mut shared_opt: Option<HostTensor> = None;
    for (i, core) in handles.iter().enumerate() {
        let outs = core
            .execute(&init, vec![HostTensor::scalar_i32((run.seed + i as u64) as i32)])
            .with_context(|| format!("init on core {i}"))?;
        if shared_params.is_none() {
            shared_params = Some(outs[0].clone());
            shared_opt = Some(outs[1].clone());
        }
        states.push(CoreInit {
            core: core.clone(),
            params: shared_params.clone().unwrap(),
            opt: shared_opt.clone().unwrap(),
            env_states: outs[2].clone(),
        });
    }

    // One deterministic program seed per core per outer iteration, drawn up
    // front so both drivers (and every replica thread) see the same table.
    let mut rng = crate::util::rng::Xoshiro256::from_stream(run.seed, 0xA11A);
    let seeds: Vec<Vec<i32>> = (0..run.outer_iters)
        .map(|_| (0..cores).map(|_| rng.next_program_seed()).collect())
        .collect();

    Ok(Setup {
        batch,
        unroll,
        iters,
        bundled,
        psum_grad,
        apply,
        states,
        seeds,
        cores: handles,
        busy0,
    })
}

/// Load + validate an Anakin checkpoint and overwrite the prepared per-core
/// state with it. Returns the number of outer iterations already done.
/// Anakin stores the model once (every core holds identical params/opt
/// after each collective) plus one env-state tensor per core; the meta
/// `env` field is empty because the environments live in-graph.
fn apply_restore(
    path: &Path,
    run: &Anakin,
    topo: &Topology,
    states: &mut [CoreInit],
) -> Result<u64> {
    let ckpt = Checkpoint::load_for(path, Arch::Anakin, topo)
        .with_context(|| format!("restoring from {}", path.display()))?;
    let meta = MetaSection::decode(ckpt.section(META_SECTION)?)?;
    expect_field("agent", meta.agent.clone(), run.agent.clone())?;
    expect_field("seed", meta.seed, run.seed)?;
    expect_field("env", meta.env.clone(), String::new())?;
    let store = StoreSection::decode(ckpt.section(STORE_SECTION)?)?;
    expect_field("store version", store.version, meta.rounds_done)?;
    let p = HostTensor::f32(vec![store.params.len()], store.params)?;
    let o = HostTensor::f32(vec![store.opt.len()], store.opt)?;
    for (i, s) in states.iter_mut().enumerate() {
        let name = core_env_section(i);
        let ces = CoreEnvSection::decode(&name, ckpt.section(&name)?)?;
        let shape: Vec<usize> = ces.shape.iter().map(|&d| d as usize).collect();
        s.env_states = HostTensor::f32(shape, ces.data)
            .with_context(|| format!("rebuilding the restored {name} tensor"))?;
        s.params = p.clone();
        s.opt = o.clone();
    }
    Ok(meta.rounds_done)
}

/// Cross-replica checkpoint rendezvous. Each core deposits its env-state
/// section after finishing round `done`; the depositor that completes the
/// set writes the file (params/opt are identical on every core after the
/// round's collective, so any depositor may supply them). The `TensorBus`
/// collective at the next round is a barrier, so saves for successive
/// rounds cannot interleave. The serial driver uses the same type with all
/// deposits coming from the driver thread.
pub(super) struct AnakinCheckpoint {
    pub spec: CheckpointSpec,
    /// `rounds_done` is stamped with the round count at save time.
    meta: MetaSection,
    topology: Topology,
    n_cores: usize,
    /// Injected fault: cut the file to this length after each save.
    truncate_to: Option<u64>,
    pending: Mutex<BTreeMap<u64, BTreeMap<usize, CoreEnvSection>>>,
}

impl AnakinCheckpoint {
    pub(super) fn new(
        spec: CheckpointSpec,
        run: &Anakin,
        topo: &Topology,
        n_cores: usize,
        truncate_to: Option<u64>,
    ) -> Self {
        Self {
            spec,
            meta: MetaSection {
                agent: run.agent.clone(),
                seed: run.seed,
                env: String::new(),
                rounds_done: 0,
            },
            topology: topo.clone(),
            n_cores,
            truncate_to,
            pending: Mutex::new(BTreeMap::new()),
        }
    }

    /// Deposit core `core_id`'s state for round `done`; whoever completes
    /// the set saves atomically.
    pub(super) fn deposit(
        &self,
        core_id: usize,
        done: u64,
        params: &HostTensor,
        opt: &HostTensor,
        env_states: &HostTensor,
    ) -> Result<()> {
        let ces = CoreEnvSection {
            shape: env_states.shape.iter().map(|&d| d as u64).collect(),
            data: env_states.as_f32()?.to_vec(),
        };
        let complete = {
            let mut g = self.pending.lock().unwrap();
            let entry = g.entry(done).or_default();
            entry.insert(core_id, ces);
            if entry.len() == self.n_cores {
                g.remove(&done)
            } else {
                None
            }
        };
        let Some(core_sections) = complete else { return Ok(()) };
        let mut c = Checkpoint::new(Arch::Anakin, &self.topology);
        let mut meta = self.meta.clone();
        meta.rounds_done = done;
        c.insert(META_SECTION, meta.encode());
        c.insert(
            STORE_SECTION,
            StoreSection {
                params: params.as_f32()?.to_vec(),
                opt: opt.as_f32()?.to_vec(),
                version: done,
            }
            .encode(),
        );
        for (i, ces) in &core_sections {
            c.insert(&core_env_section(*i), ces.encode());
        }
        c.save(&self.spec.path)
            .with_context(|| format!("saving checkpoint to {}", self.spec.path.display()))?;
        if let Some(len) = self.truncate_to {
            let f = std::fs::OpenOptions::new()
                .write(true)
                .open(&self.spec.path)
                .context("truncate-checkpoint fault")?;
            f.set_len(len).context("truncate-checkpoint fault")?;
        }
        Ok(())
    }
}

/// Sum a bundled call's `[K, 5]` metric tensor into this core's partial
/// row (mean over the K in-graph updates; the cross-core mean happens when
/// partials combine).
pub(super) fn bundled_partial_row(m: &HostTensor) -> Result<MetricRow> {
    let v = m.as_f32()?;
    let k = (v.len() / 5).max(1);
    let mut row = [0.0f64; 5];
    for ki in 0..k {
        for j in 0..5 {
            row[j] += v[ki * 5 + j] as f64 / k as f64;
        }
    }
    Ok(row)
}

/// A psum call's `[5]` metric tensor as this core's partial row.
pub(super) fn psum_partial_row(m: &HostTensor) -> Result<MetricRow> {
    let v = m.as_f32()?;
    let mut row = [0.0f64; 5];
    for j in 0..5 {
        row[j] = v[j] as f64;
    }
    Ok(row)
}

#[allow(clippy::too_many_arguments)]
fn finish_report(
    run: &Anakin,
    n_cores: usize,
    setup_meta: (usize, usize, usize), // (batch, unroll, iters)
    outer_done: u64,
    cores: &[DeviceHandle],
    busy0: &[f64],
    stats: &RunStats,
    elapsed: f64,
    updates: u64,
    metrics: Vec<MetricRow>,
    final_params: Vec<f32>,
) -> Report {
    let (batch, unroll, iters) = setup_meta;
    let per_call = match run.mode {
        Mode::Bundled => batch * unroll * iters,
        Mode::Psum => batch * unroll,
    };
    // Steps executed *by this run*: a restored run counts only its own
    // outer iterations (the checkpointed ones were the previous run's).
    let steps = (per_call as u64) * outer_done * n_cores as u64;
    // Critical path: max per-core device busy *of this run* (the baseline
    // subtraction makes `projected_sps` honest on reused pods), lengthened
    // by the exposed replica schedule (DESIGN.md §10).
    let mut critical: f64 = 1e-12;
    for (core, b0) in cores.iter().zip(busy0) {
        critical = critical.max(core.busy_seconds() - b0);
    }
    critical = critical.max(stats.anakin_busy_max_seconds());
    Report {
        arch: Arch::Anakin,
        steps,
        updates,
        elapsed,
        throughput: steps as f64 / elapsed.max(1e-12),
        projected_throughput: steps as f64 / critical,
        final_params,
        detail: Detail::Anakin(AnakinDetail {
            metrics,
            replica_device_seconds: stats.anakin_device_seconds(),
            replica_host_seconds: stats.anakin_host_seconds(),
            replica_collective_seconds: stats.anakin_collective_seconds(),
            replica_active_seconds: stats.anakin_active_seconds(),
            replica_overlap_seconds: stats.anakin_overlap_seconds(),
            replica_busy_max_seconds: stats.anakin_busy_max_seconds(),
        }),
    }
}

/// The single-thread reference schedule. Drains cores in index order with
/// conversions interleaved (core i's convert runs while cores i+1.. still
/// compute), reduces with the deterministic tree, re-distributes. The
/// accounting records one pseudo-replica whose exposed device time is the
/// recv-blocked spans only, so `replica_overlap_seconds` is ~0 — the
/// serial schedule hides nothing *of its own*.
pub(super) fn run_serial(
    pod: &mut Pod,
    run: &Anakin,
    topo: &Topology,
    spec: &RunSpec,
) -> Result<Report> {
    let n_cores = topo.total_cores();
    let Setup { batch, unroll, iters, bundled, psum_grad, apply, mut states, seeds, cores, busy0 } =
        prepare(pod, run, n_cores)?;
    let start = match &spec.restore_from {
        Some(path) => apply_restore(path, run, topo, &mut states)?,
        None => 0,
    };
    let ck = spec.checkpoint.as_ref().map(|cs| {
        AnakinCheckpoint::new(
            cs.clone(),
            run,
            topo,
            n_cores,
            spec.fault.as_ref().and_then(|f| f.truncate_checkpoint_to),
        )
    });
    let stats = RunStats::new();
    let mut metrics_hist: Vec<MetricRow> = Vec::new();
    let mut updates = 0u64;
    let mut device_busy = Duration::ZERO;
    let mut host_busy = Duration::ZERO;
    let mut collective_busy = Duration::ZERO;
    let t0 = Instant::now();

    // A restored run consumes the tail of the same pre-drawn seed table the
    // original run would have — the continuation sees identical seeds.
    let skip = (start as usize).min(seeds.len());
    for (k, row_seeds) in seeds[skip..].iter().enumerate() {
        let round = start + k as u64;
        if let Some(f) = &spec.fault {
            // Serial twin of the per-replica kill: one thread drives every
            // core, so a kill on any of them takes the whole schedule down.
            if (0..n_cores).any(|i| f.should_kill(i, round)) {
                anyhow::bail!("injected fault: anakin driver killed at round {round}");
            }
        }
        match run.mode {
            Mode::Bundled => {
                let mut waits = Vec::with_capacity(n_cores);
                for (s, &seed) in states.iter().zip(row_seeds) {
                    waits.push(s.core.execute_async(
                        &bundled,
                        vec![
                            s.params.clone(),
                            s.opt.clone(),
                            s.env_states.clone(),
                            HostTensor::scalar_i32(seed),
                        ],
                    )?);
                }
                let mut row = [0.0f64; 5];
                let mut param_bufs = Vec::with_capacity(n_cores);
                let mut opt_bufs = Vec::with_capacity(n_cores);
                for (i, (s, rx)) in states.iter_mut().zip(waits).enumerate() {
                    let t_recv = Instant::now();
                    let mut outs = rx
                        .recv()
                        .map_err(|_| {
                            anyhow::anyhow!("anakin core {i} died executing {bundled}")
                        })?
                        .with_context(|| format!("bundled program on core {i}"))?;
                    device_busy += t_recv.elapsed();
                    let t_host = Instant::now();
                    let m = outs.swap_remove(3);
                    s.env_states = outs.swap_remove(2);
                    opt_bufs.push(outs.swap_remove(1).into_f32()?);
                    param_bufs.push(outs.swap_remove(0).into_f32()?);
                    let partial = bundled_partial_row(&m)?;
                    for j in 0..5 {
                        row[j] += partial[j] / n_cores as f64;
                    }
                    host_busy += t_host.elapsed();
                }
                // cross-core average (the driver-level pmean)
                let t_coll = Instant::now();
                all_reduce_mean(&mut param_bufs)?;
                all_reduce_mean(&mut opt_bufs)?;
                collective_busy += t_coll.elapsed();
                let t_host = Instant::now();
                let p = HostTensor::f32(vec![param_bufs[0].len()], param_bufs.swap_remove(0))?;
                let o = HostTensor::f32(vec![opt_bufs[0].len()], opt_bufs.swap_remove(0))?;
                for s in &mut states {
                    s.params = p.clone();
                    s.opt = o.clone();
                }
                host_busy += t_host.elapsed();
                metrics_hist.push(row);
                updates += iters as u64;
            }
            Mode::Psum => {
                let mut waits = Vec::with_capacity(n_cores);
                for (s, &seed) in states.iter().zip(row_seeds) {
                    waits.push(s.core.execute_async(
                        &psum_grad,
                        vec![
                            s.params.clone(),
                            s.opt.clone(),
                            s.env_states.clone(),
                            HostTensor::scalar_i32(seed),
                        ],
                    )?);
                }
                let mut grad_bufs = Vec::with_capacity(n_cores);
                let mut row = [0.0f64; 5];
                for (i, (s, rx)) in states.iter_mut().zip(waits).enumerate() {
                    let t_recv = Instant::now();
                    let mut outs = rx
                        .recv()
                        .map_err(|_| {
                            anyhow::anyhow!("anakin core {i} died executing {psum_grad}")
                        })?
                        .with_context(|| format!("psum_grad program on core {i}"))?;
                    device_busy += t_recv.elapsed();
                    let t_host = Instant::now();
                    let m = outs.swap_remove(2);
                    s.env_states = outs.swap_remove(1);
                    grad_bufs.push(outs.swap_remove(0).into_f32()?);
                    let partial = psum_partial_row(&m)?;
                    for j in 0..5 {
                        row[j] += partial[j] / n_cores as f64;
                    }
                    host_busy += t_host.elapsed();
                }
                // the psum: average gradients, apply once, broadcast
                let t_coll = Instant::now();
                all_reduce_mean(&mut grad_bufs)?;
                collective_busy += t_coll.elapsed();
                let grads = HostTensor::f32(vec![grad_bufs[0].len()], grad_bufs.swap_remove(0))?;
                let t_apply = Instant::now();
                let mut outs = states[0]
                    .core
                    .execute(&apply, vec![states[0].params.clone(), states[0].opt.clone(), grads])
                    .context("apply program on core 0")?;
                device_busy += t_apply.elapsed();
                let t_host = Instant::now();
                let o = outs.swap_remove(1);
                let p = outs.swap_remove(0);
                for s in &mut states {
                    s.params = p.clone();
                    s.opt = o.clone();
                }
                host_busy += t_host.elapsed();
                metrics_hist.push(row);
                updates += 1;
            }
        }
        if let Some(ck) = &ck {
            let done = round + 1;
            if ck.spec.due(done) {
                for (i, s) in states.iter().enumerate() {
                    ck.deposit(i, done, &s.params, &s.opt, &s.env_states)
                        .with_context(|| format!("checkpoint after round {done}"))?;
                }
            }
        }
    }

    let elapsed = t0.elapsed().as_secs_f64();
    stats.record_anakin_overlap(device_busy, collective_busy, host_busy, t0.elapsed());
    let final_params = states.swap_remove(0).params.into_f32()?;
    Ok(finish_report(
        run,
        n_cores,
        (batch, unroll, iters),
        seeds.len() as u64 - skip as u64,
        &cores,
        &busy0,
        &stats,
        elapsed,
        updates,
        metrics_hist,
        final_params,
    ))
}

/// The pod-of-threads schedule: one replica thread per core, the pmean on
/// the [`TensorBus`] (deterministic reduction order => bit-exact vs the
/// serial schedule), host conversion and metric accumulation parallel
/// across replicas and overlapping the next device call (DESIGN.md §10).
pub(super) fn run_threaded(
    pod: &mut Pod,
    run: &Anakin,
    topo: &Topology,
    spec: &RunSpec,
) -> Result<Report> {
    let n_cores = topo.total_cores();
    let Setup {
        batch,
        unroll,
        iters,
        bundled,
        psum_grad,
        apply,
        mut states,
        seeds,
        cores,
        busy0,
    } = prepare(pod, run, n_cores)?;
    let start = match &spec.restore_from {
        Some(path) => apply_restore(path, run, topo, &mut states)?,
        None => 0,
    };
    let ck = spec.checkpoint.as_ref().map(|cs| {
        Arc::new(AnakinCheckpoint::new(
            cs.clone(),
            run,
            topo,
            n_cores,
            spec.fault.as_ref().and_then(|f| f.truncate_checkpoint_to),
        ))
    });
    let skip = (start as usize).min(seeds.len());
    let stats = Arc::new(RunStats::new());
    let bus = Arc::new(TensorBus::new(n_cores));
    let t0 = Instant::now();

    let mut joins = Vec::with_capacity(n_cores);
    for (i, st) in states.into_iter().enumerate() {
        let rcfg = ReplicaConfig {
            replica_id: i,
            mode: run.mode,
            bundled: bundled.clone(),
            psum_grad: psum_grad.clone(),
            apply: apply.clone(),
            seeds: seeds[skip..].iter().map(|row| row[i]).collect(),
            start,
            fault: spec.fault.clone(),
            checkpoint: ck.clone(),
        };
        joins.push(replica::spawn_replica(rcfg, st, bus.clone(), stats.clone()));
    }

    // Join *every* replica, aggregating failures into one error chain —
    // a failing replica has already shut the bus down from its own thread
    // (see `spawn_replica`'s guard), so in-order joins cannot deadlock on a
    // sibling parked in a collective; the first joined error may be a
    // secondary "bus shut down" from that unblocking, not the root cause.
    let mut outs: Vec<Option<replica::ReplicaOut>> = Vec::with_capacity(n_cores);
    let mut err: Option<anyhow::Error> = None;
    for (i, j) in joins.into_iter().enumerate() {
        match j.join() {
            Ok(Ok(out)) => outs.push(Some(out)),
            Ok(Err(e)) => {
                bus.shutdown();
                err = Some(match err.take() {
                    None => e.context(format!("anakin replica {i} failed")),
                    Some(prev) => prev.context(format!("anakin replica {i} also failed: {e:#}")),
                });
                outs.push(None);
            }
            Err(_) => {
                bus.shutdown();
                err = Some(match err.take() {
                    None => anyhow::anyhow!("anakin replica {i} panicked"),
                    Some(prev) => prev.context(format!("anakin replica {i} also panicked")),
                });
                outs.push(None);
            }
        }
    }
    if let Some(e) = err {
        return Err(e);
    }
    let elapsed = t0.elapsed().as_secs_f64();

    // Combine per-replica metric partials in fixed replica order — the
    // cross-core mean, deterministic run-to-run (grouping differs from the
    // serial driver's, so metrics agree up to f64 rounding; parameters are
    // bit-exact — DESIGN.md §10).
    let replicas: Vec<replica::ReplicaOut> =
        outs.into_iter().map(|o| o.expect("no error => every replica returned")).collect();
    let outer = seeds.len() - skip;
    let mut metrics_hist = vec![[0.0f64; 5]; outer];
    for rep in &replicas {
        for (o, row) in rep.metrics_partial.iter().enumerate() {
            for j in 0..5 {
                metrics_hist[o][j] += row[j] / n_cores as f64;
            }
        }
    }
    let updates = match run.mode {
        Mode::Bundled => iters as u64 * outer as u64,
        Mode::Psum => outer as u64,
    };
    let final_params = replicas.into_iter().next().expect("at least one replica").final_params;
    Ok(finish_report(
        run,
        n_cores,
        (batch, unroll, iters),
        outer as u64,
        &cores,
        &busy0,
        &stats,
        elapsed,
        updates,
        metrics_hist,
        final_params,
    ))
}
