//! One Anakin replica thread: a simulated core's host-side twin.
//!
//! Owns its core's execute→convert→post loop (DESIGN.md §10). Per outer
//! iteration the replica fires the device call, accumulates the *previous*
//! call's metrics while the device runs (the overlapped host work the
//! accounting surfaces), harvests, converts the outputs to f32 and joins
//! the driver-level pmean on the [`TensorBus`]:
//!
//! * Bundled — all-reduce parameters, then optimiser state (two reduce
//!   rounds; fixed participant order makes the tree mean bit-exact vs the
//!   serial driver).
//! * Psum — all-reduce gradients; replica 0 runs the apply program on its
//!   core and broadcasts the new parameters + optimiser state back (the
//!   re-broadcast the serial driver did by cloning into every core's slot).
//!
//! A replica that fails shuts the bus down from its own thread (drop
//! guard, covering the panic path), so the driver's in-order joins never
//! deadlock on a sibling parked in a collective — mirroring Sebulba's
//! guarded learner spawn.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::collective::TensorBus;
use crate::coordinator::stats::RunStats;
use crate::runtime::tensor::HostTensor;
use crate::testkit::FaultPlan;

use super::driver::{bundled_partial_row, psum_partial_row, AnakinCheckpoint, CoreInit};
use super::{MetricRow, Mode};

pub(super) struct ReplicaConfig {
    pub replica_id: usize,
    pub mode: Mode,
    pub bundled: String,
    pub psum_grad: String,
    pub apply: String,
    /// This replica's column of the driver's seed table, one per outer
    /// iteration. On a restored run this is the table's *tail*: rows the
    /// checkpointed run already consumed are skipped by the driver.
    pub seeds: Vec<i32>,
    /// Outer iterations the restored run already completed (0 when fresh);
    /// `start + k` is round k's absolute index.
    pub start: u64,
    /// Scheduled faults (resilience tests only).
    pub fault: Option<FaultPlan>,
    /// Cross-replica checkpoint rendezvous, when the run checkpoints.
    pub checkpoint: Option<Arc<AnakinCheckpoint>>,
}

pub(super) struct ReplicaOut {
    /// Per-outer-iteration metric partials for this core (mean over K
    /// in-graph updates; the driver combines across replicas).
    pub metrics_partial: Vec<MetricRow>,
    pub final_params: Vec<f32>,
}

/// Spawn a replica thread whose exit always leaves the pod joinable: the
/// guard shuts the bus down on an `Err` return *and* on a panic, so the
/// driver's in-order joins can't deadlock on a sibling parked in a round
/// this replica will never post to.
pub(super) fn spawn_replica(
    cfg: ReplicaConfig,
    state: CoreInit,
    bus: Arc<TensorBus>,
    stats: Arc<RunStats>,
) -> std::thread::JoinHandle<Result<ReplicaOut>> {
    struct UnblockOnDrop {
        bus: Arc<TensorBus>,
        armed: bool,
    }
    impl Drop for UnblockOnDrop {
        fn drop(&mut self) {
            if self.armed {
                self.bus.shutdown();
            }
        }
    }
    std::thread::Builder::new()
        .name(format!("anakin-{}", cfg.replica_id))
        .spawn(move || {
            let mut guard = UnblockOnDrop { bus: bus.clone(), armed: true };
            let res = replica_main(&cfg, state, &bus, &stats);
            guard.armed = res.is_err();
            res // guard drops here: shuts the bus down on Err (and on panic)
        })
        .expect("spawn anakin replica")
}

fn replica_main(
    cfg: &ReplicaConfig,
    state: CoreInit,
    bus: &TensorBus,
    stats: &RunStats,
) -> Result<ReplicaOut> {
    let CoreInit { core, mut params, mut opt, mut env_states } = state;
    let id = cfg.replica_id;
    let mut rows: Vec<MetricRow> = Vec::with_capacity(cfg.seeds.len());
    // The previous call's metric tensor, accumulated under the next call.
    let mut pending_metrics: Option<HostTensor> = None;
    let mut device_busy = Duration::ZERO;
    let mut host_busy = Duration::ZERO;
    let mut collective_busy = Duration::ZERO;
    let t_loop = Instant::now();

    for (k, &seed) in cfg.seeds.iter().enumerate() {
        let round = cfg.start + k as u64;
        if let Some(f) = &cfg.fault {
            // Injected fault: die at the start of this round, before any of
            // its effects, exactly as a crashed replica process would.
            if f.should_kill(id, round) {
                anyhow::bail!("injected fault: anakin replica {id} killed at round {round}");
            }
        }
        let program = match cfg.mode {
            Mode::Bundled => &cfg.bundled,
            Mode::Psum => &cfg.psum_grad,
        };
        let issued = Instant::now();
        let rx = core.execute_async(
            program,
            vec![
                params.clone(),
                opt.clone(),
                env_states.clone(),
                HostTensor::scalar_i32(seed),
            ],
        )?;
        // Overlap: fold the previous call's metrics while the device runs.
        if let Some(m) = pending_metrics.take() {
            let t = Instant::now();
            rows.push(match cfg.mode {
                Mode::Bundled => bundled_partial_row(&m)?,
                Mode::Psum => psum_partial_row(&m)?,
            });
            host_busy += t.elapsed();
        }
        let mut outs = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("anakin core {} died executing {program}", core.core_id))?
            .with_context(|| format!("{program} on core {}", core.core_id))?;
        // Issue → harvest: the span covers the metric fold above — exactly
        // the hidden work the overlap metric counts (DESIGN.md §10).
        device_busy += issued.elapsed();

        match cfg.mode {
            Mode::Bundled => {
                let t = Instant::now();
                let metrics_t = outs.swap_remove(3);
                env_states = outs.swap_remove(2);
                let o_buf = outs.swap_remove(1).into_f32()?;
                let p_buf = outs.swap_remove(0).into_f32()?;
                host_busy += t.elapsed();
                // the driver-level pmean: params, then optimiser state
                let t = Instant::now();
                let p_mean = bus.all_reduce(id, p_buf)?;
                let o_mean = bus.all_reduce(id, o_buf)?;
                collective_busy += t.elapsed();
                let t = Instant::now();
                params = HostTensor::f32(vec![p_mean.len()], p_mean)?;
                opt = HostTensor::f32(vec![o_mean.len()], o_mean)?;
                pending_metrics = Some(metrics_t);
                host_busy += t.elapsed();
            }
            Mode::Psum => {
                let t = Instant::now();
                let metrics_t = outs.swap_remove(2);
                env_states = outs.swap_remove(1);
                let g_buf = outs.swap_remove(0).into_f32()?;
                host_busy += t.elapsed();
                // the psum: average gradients, apply once on replica 0's
                // core, broadcast the new params + opt state back
                let t = Instant::now();
                let g_mean = bus.all_reduce(id, g_buf)?;
                collective_busy += t.elapsed();
                let (p_new, o_new) = if id == 0 {
                    let t = Instant::now();
                    let mut apply_outs = core
                        .execute(
                            &cfg.apply,
                            vec![
                                params.clone(),
                                opt.clone(),
                                HostTensor::f32(vec![g_mean.len()], g_mean)?,
                            ],
                        )
                        .with_context(|| format!("apply program on core {}", core.core_id))?;
                    device_busy += t.elapsed();
                    let t = Instant::now();
                    let o_vec = apply_outs.swap_remove(1).into_f32()?;
                    let p_vec = apply_outs.swap_remove(0).into_f32()?;
                    host_busy += t.elapsed();
                    let t = Instant::now();
                    let p = bus.broadcast(0, Some(p_vec))?;
                    let o = bus.broadcast(0, Some(o_vec))?;
                    collective_busy += t.elapsed();
                    (p, o)
                } else {
                    let t = Instant::now();
                    let p = bus.broadcast(id, None)?;
                    let o = bus.broadcast(id, None)?;
                    collective_busy += t.elapsed();
                    (p, o)
                };
                let t = Instant::now();
                params = HostTensor::f32(vec![p_new.len()], p_new)?;
                opt = HostTensor::f32(vec![o_new.len()], o_new)?;
                pending_metrics = Some(metrics_t);
                host_busy += t.elapsed();
            }
        }
        // Deposit after the round's collective: every replica now holds
        // identical params/opt, so whichever completes the set saves. The
        // next round's collective is a barrier, so this save finishes
        // before any later round's can begin.
        if let Some(ck) = &cfg.checkpoint {
            let done = round + 1;
            if ck.spec.due(done) {
                ck.deposit(id, done, &params, &opt, &env_states)
                    .with_context(|| format!("checkpoint after round {done}"))?;
            }
        }
    }
    // flush the last call's metrics
    if let Some(m) = pending_metrics.take() {
        let t = Instant::now();
        rows.push(match cfg.mode {
            Mode::Bundled => bundled_partial_row(&m)?,
            Mode::Psum => psum_partial_row(&m)?,
        });
        host_busy += t.elapsed();
    }

    let active = t_loop.elapsed().saturating_sub(collective_busy);
    stats.record_anakin_overlap(device_busy, collective_busy, host_busy, active);
    Ok(ReplicaOut { metrics_partial: rows, final_params: params.into_f32()? })
}
