//! Anakin: the fully on-device online-learning architecture.
//!
//! Everything — environment stepping, action selection, the update — lives
//! in one XLA program (`<agent>_bundled`, built by `python/compile/anakin.py`
//! exactly as in the paper's Figure 2: vmap over a batch of envs, scan over
//! T steps, grad+update, fori_loop over K updates). The Rust driver's job is
//! replication: run the program on every simulated core and average across
//! cores, which on a real pod the in-graph `pmean` would do.
//!
//! Two collective modes (see DESIGN.md §1 for the substitution argument):
//!
//! * [`Mode::Bundled`] — K updates in-graph per outer call; the driver
//!   averages *parameters + optimiser state* across cores after each call
//!   (synchronous data-parallelism with period K).
//! * [`Mode::Psum`] — one update per call returning raw gradients; the
//!   driver all-reduces gradients and applies once — *bit-exact* synchronous
//!   data-parallelism, i.e. exactly where the paper's `psum` sits. Slower
//!   (more host round-trips) but the fidelity reference: tests assert both
//!   modes agree at K=1, and that all cores hold identical parameters.
//!
//! And two drivers (DESIGN.md §10):
//!
//! * [`Driver::Threaded`] (default) — a true pod of host threads, one
//!   replica thread per simulated core (`replica.rs`), each owning its
//!   core's execute→convert→post loop; the driver-level `pmean` runs on the
//!   [`crate::coordinator::collective::TensorBus`] in a deterministic
//!   reduction order, so final parameters are bit-exact vs the serial
//!   schedule while host conversion/metric work parallelises across
//!   replicas and overlaps the next device call.
//! * [`Driver::Serial`] — the single-thread reference schedule: drain every
//!   core, convert, reduce and re-distribute on the driver thread. Kept as
//!   the bit-exactness oracle and the baseline the `fig4a_anakin_scaling`
//!   bench compares against.

mod driver;
mod replica;

use std::path::Path;

use anyhow::Result;

use crate::runtime::Pod;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Bundled,
    Psum,
}

/// Which host-side schedule drives the replicated program (DESIGN.md §10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Driver {
    /// Single driver thread drains/reduces/redistributes every core.
    Serial,
    /// One replica thread per core; the pmean runs on the `TensorBus`.
    Threaded,
}

#[derive(Clone, Debug)]
pub struct AnakinConfig {
    /// Agent tag in the manifest ("anakin_catch", "anakin_grid").
    pub agent: String,
    /// Simulated cores (replicas of the on-device program).
    pub cores: usize,
    /// Outer driver iterations (each = K in-graph updates in Bundled mode,
    /// 1 update in Psum mode).
    pub outer_iters: u64,
    pub mode: Mode,
    pub driver: Driver,
    pub seed: u64,
}

impl Default for AnakinConfig {
    fn default() -> Self {
        Self {
            agent: "anakin_catch".into(),
            cores: 2,
            outer_iters: 10,
            mode: Mode::Bundled,
            driver: Driver::Threaded,
            seed: 7,
        }
    }
}

/// Per-outer-iteration metrics, averaged over cores and in-graph updates:
/// `[loss, pg_loss, baseline_loss, entropy, episode_reward]`.
pub type MetricRow = [f64; 5];

#[derive(Debug)]
pub struct AnakinReport {
    /// Total environment steps across all cores.
    pub steps: u64,
    pub updates: u64,
    pub elapsed: f64,
    /// Wall-clock environment steps/sec.
    pub sps: f64,
    /// Steps/sec if cores ran truly in parallel: steps / critical path,
    /// where the critical path is the max per-core busy time *of this run*
    /// lengthened by the max per-replica post-overlap busy time
    /// (DESIGN.md §10 — an exposed driver schedule bounds the run even on
    /// truly parallel cores).
    pub projected_sps: f64,
    pub metrics: Vec<MetricRow>,
    pub final_params: Vec<f32>,
    /// Device time the replica schedule was exposed to, summed over
    /// replicas: recv-blocked harvest spans (at overlap a span covers host
    /// work issued under it) plus replica 0's Psum apply.
    pub replica_device_seconds: f64,
    /// Host conversion + metric accumulation time, summed over replicas.
    pub replica_host_seconds: f64,
    /// Collective time (bus wait + reduction), summed over replicas.
    pub replica_collective_seconds: f64,
    /// Active wall per replica (loop wall minus collective wait), summed.
    pub replica_active_seconds: f64,
    /// Work the threaded schedule hid: per replica,
    /// `max(0, device + host − active)`. ~0 under the serial driver.
    pub replica_overlap_seconds: f64,
    /// Max per-replica busy time `min(device + host, active)` — the
    /// critical-path contribution `projected_sps` divides by.
    pub replica_busy_max_seconds: f64,
}

pub struct Anakin;

impl Anakin {
    pub fn run(artifacts: &Path, cfg: &AnakinConfig) -> Result<AnakinReport> {
        let mut pod = Pod::new(artifacts, cfg.cores)?;
        Self::run_on(&mut pod, cfg)
    }

    pub fn run_on(pod: &mut Pod, cfg: &AnakinConfig) -> Result<AnakinReport> {
        match cfg.driver {
            Driver::Serial => driver::run_serial(pod, cfg),
            Driver::Threaded => driver::run_threaded(pod, cfg),
        }
    }
}

/// All cores must hold identical parameters after a run — the invariant the
/// collective preserves. (Helper for tests.)
pub fn params_in_sync(report_params: &[f32], other: &[f32]) -> bool {
    report_params.len() == other.len()
        && report_params
            .iter()
            .zip(other)
            .all(|(a, b)| (a - b).abs() <= 1e-6 * a.abs().max(1.0))
}
