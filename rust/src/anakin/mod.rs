//! Anakin: the fully on-device online-learning architecture.
//!
//! Everything — environment stepping, action selection, the update — lives
//! in one XLA program (`<agent>_bundled`, built by `python/compile/anakin.py`
//! exactly as in the paper's Figure 2: vmap over a batch of envs, scan over
//! T steps, grad+update, fori_loop over K updates). The Rust driver's job is
//! replication: run the program on every simulated core and average across
//! cores, which on a real pod the in-graph `pmean` would do.
//!
//! Two collective modes (see DESIGN.md §1 for the substitution argument):
//!
//! * [`Mode::Bundled`] — K updates in-graph per outer call; the driver
//!   averages *parameters + optimiser state* across cores after each call
//!   (synchronous data-parallelism with period K).
//! * [`Mode::Psum`] — one update per call returning raw gradients; the
//!   driver all-reduces gradients and applies once — *bit-exact* synchronous
//!   data-parallelism, i.e. exactly where the paper's `psum` sits. Slower
//!   (more host round-trips) but the fidelity reference: tests assert both
//!   modes agree at K=1, and that all cores hold identical parameters.
//!
//! And two drivers (DESIGN.md §10):
//!
//! * [`Driver::Threaded`] (default) — a true pod of host threads, one
//!   replica thread per simulated core (`replica.rs`), each owning its
//!   core's execute→convert→post loop; the driver-level `pmean` runs on the
//!   [`crate::coordinator::collective::TensorBus`] in a deterministic
//!   reduction order, so final parameters are bit-exact vs the serial
//!   schedule while host conversion/metric work parallelises across
//!   replicas and overlaps the next device call.
//! * [`Driver::Serial`] — the single-thread reference schedule: drain every
//!   core, convert, reduce and re-distribute on the driver thread. Kept as
//!   the bit-exactness oracle and the baseline the `fig4a_anakin_scaling`
//!   bench compares against.

mod driver;
mod replica;

use std::str::FromStr;

use anyhow::Result;

use crate::experiment::{Arch, Report, RunSpec, Runner, Topology};
use crate::runtime::Pod;

pub use crate::experiment::MetricRow;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Bundled,
    Psum,
}

impl FromStr for Mode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "bundled" => Ok(Mode::Bundled),
            "psum" => Ok(Mode::Psum),
            other => anyhow::bail!("unknown mode {other:?} (valid: bundled, psum)"),
        }
    }
}

/// Which host-side schedule drives the replicated program (DESIGN.md §10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Driver {
    /// Single driver thread drains/reduces/redistributes every core.
    Serial,
    /// One replica thread per core; the pmean runs on the `TensorBus`.
    Threaded,
}

impl FromStr for Driver {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "serial" => Ok(Driver::Serial),
            "threaded" => Ok(Driver::Threaded),
            other => anyhow::bail!("unknown driver {other:?} (valid: threaded, serial)"),
        }
    }
}

/// The Anakin *workload*: everything about a run except how many cores
/// replicate it — that arrives as a [`Topology`] through the [`Runner`]
/// trait (Anakin has no actor/learner split, so only
/// `Topology::total_cores()` matters: every core runs the fused
/// act+learn program). Reached through
/// `experiment::Experiment::new(Arch::Anakin)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Anakin {
    /// Agent tag in the manifest ("anakin_catch", "anakin_grid").
    pub agent: String,
    pub mode: Mode,
    pub driver: Driver,
    /// Outer driver iterations (each = K in-graph updates in Bundled mode,
    /// 1 update in Psum mode).
    pub outer_iters: u64,
    pub seed: u64,
}

impl Default for Anakin {
    fn default() -> Self {
        Self {
            agent: "anakin_catch".into(),
            mode: Mode::Bundled,
            driver: Driver::Threaded,
            outer_iters: 10,
            seed: 7,
        }
    }
}

impl Runner for Anakin {
    fn arch(&self) -> Arch {
        Arch::Anakin
    }

    fn run_checkpointed(&self, pod: &mut Pod, topo: &Topology, spec: &RunSpec) -> Result<Report> {
        Anakin::check_topology(topo)?;
        topo.validate_for_pod(pod.n_cores())?;
        // Honour-or-reject: Anakin has no trajectory queue, so a poison
        // fault cannot fire — error out rather than silently drop the knob.
        if spec.fault.as_ref().is_some_and(|f| f.poison_queue_after.is_some()) {
            anyhow::bail!("anakin has no trajectory queue: poison-queue fault cannot apply");
        }
        match self.driver {
            Driver::Serial => driver::run_serial(pod, self, topo, spec),
            Driver::Threaded => driver::run_threaded(pod, self, topo, spec),
        }
    }
}

impl Anakin {
    /// Anakin consumes only `Topology::total_cores()` — every other knob
    /// describes a host-side acting path it does not have, so a
    /// non-trivial value is a hard error, never a silently dropped knob
    /// (the coercion class the experiment API retires). Shared by the
    /// builder and direct `Runner` users.
    pub fn check_topology(topo: &Topology) -> Result<()> {
        let trivial = Topology { learner_cores: topo.learner_cores, ..Topology::anakin(0) };
        if *topo != trivial {
            anyhow::bail!(
                "anakin has no actor/learner split or host pipelines: build its topology \
                 with Topology::anakin(cores) (got {topo:?})"
            );
        }
        Ok(())
    }
}

/// All cores must hold identical parameters after a run — the invariant the
/// collective preserves. (Helper for tests.)
pub fn params_in_sync(report_params: &[f32], other: &[f32]) -> bool {
    report_params.len() == other.len()
        && report_params
            .iter()
            .zip(other)
            .all(|(a, b)| (a - b).abs() <= 1e-6 * a.abs().max(1.0))
}
