//! Anakin: the fully on-device online-learning architecture.
//!
//! Everything — environment stepping, action selection, the update — lives
//! in one XLA program (`<agent>_bundled`, built by `python/compile/anakin.py`
//! exactly as in the paper's Figure 2: vmap over a batch of envs, scan over
//! T steps, grad+update, fori_loop over K updates). The Rust driver's job is
//! replication: run the program on every simulated core and average across
//! cores, which on a real pod the in-graph `pmean` would do.
//!
//! Two modes (see DESIGN.md §1 for the substitution argument):
//!
//! * [`Mode::Bundled`] — K updates in-graph per outer call; the driver
//!   averages *parameters + optimiser state* across cores after each call
//!   (synchronous data-parallelism with period K).
//! * [`Mode::Psum`] — one update per call returning raw gradients; the
//!   driver all-reduces gradients and applies once — *bit-exact* synchronous
//!   data-parallelism, i.e. exactly where the paper's `psum` sits. Slower
//!   (more host round-trips) but the fidelity reference: tests assert both
//!   modes agree at K=1, and that all cores hold identical parameters.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::collective::all_reduce_mean;
use crate::runtime::tensor::HostTensor;
use crate::runtime::{DeviceHandle, Pod};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Bundled,
    Psum,
}

#[derive(Clone, Debug)]
pub struct AnakinConfig {
    /// Agent tag in the manifest ("anakin_catch", "anakin_grid").
    pub agent: String,
    /// Simulated cores (replicas of the on-device program).
    pub cores: usize,
    /// Outer driver iterations (each = K in-graph updates in Bundled mode,
    /// 1 update in Psum mode).
    pub outer_iters: u64,
    pub mode: Mode,
    pub seed: u64,
}

impl Default for AnakinConfig {
    fn default() -> Self {
        Self { agent: "anakin_catch".into(), cores: 2, outer_iters: 10, mode: Mode::Bundled, seed: 7 }
    }
}

/// Per-outer-iteration metrics, averaged over cores and in-graph updates:
/// `[loss, pg_loss, baseline_loss, entropy, episode_reward]`.
pub type MetricRow = [f64; 5];

#[derive(Debug)]
pub struct AnakinReport {
    /// Total environment steps across all cores.
    pub steps: u64,
    pub updates: u64,
    pub elapsed: f64,
    /// Wall-clock environment steps/sec.
    pub sps: f64,
    /// Steps/sec if cores ran truly in parallel (steps / max core busy).
    pub projected_sps: f64,
    pub metrics: Vec<MetricRow>,
    pub final_params: Vec<f32>,
}

struct CoreState {
    core: DeviceHandle,
    params: HostTensor,
    opt: HostTensor,
    env_states: HostTensor,
}

pub struct Anakin;

impl Anakin {
    pub fn run(artifacts: &Path, cfg: &AnakinConfig) -> Result<AnakinReport> {
        let mut pod = Pod::new(artifacts, cfg.cores)?;
        Self::run_on(&mut pod, cfg)
    }

    pub fn run_on(pod: &mut Pod, cfg: &AnakinConfig) -> Result<AnakinReport> {
        anyhow::ensure!(cfg.cores >= 1, "need at least one core");
        anyhow::ensure!(pod.n_cores() >= cfg.cores, "pod too small");
        let agent = pod.manifest.agent(&cfg.agent)?.clone();
        let batch = agent.extra_usize("batch")?;
        let unroll = agent.extra_usize("unroll")?;
        let iters = agent.extra_usize("iters")?;

        let init = format!("{}_init", cfg.agent);
        let bundled = format!("{}_bundled", cfg.agent);
        let psum_grad = format!("{}_psum_grad", cfg.agent);
        let apply = format!("{}_apply", cfg.agent);
        let core_ids: Vec<usize> = (0..cfg.cores).collect();
        match cfg.mode {
            Mode::Bundled => pod.load_programs(&[init.as_str(), bundled.as_str()], &core_ids)?,
            Mode::Psum => {
                pod.load_programs(&[init.as_str(), psum_grad.as_str()], &core_ids)?;
                pod.load_program(&apply, &[0])?;
            }
        }

        // Per-core init: same parameters everywhere (core 0's), but each core
        // gets its own env-state batch from its own seed — the vmap'd env
        // batch is what differs across cores on a real pod too.
        let mut states = Vec::with_capacity(cfg.cores);
        let mut shared_params: Option<HostTensor> = None;
        let mut shared_opt: Option<HostTensor> = None;
        for (i, &cid) in core_ids.iter().enumerate() {
            let core = pod.core(cid)?;
            let outs = core
                .execute(&init, vec![HostTensor::scalar_i32((cfg.seed + i as u64) as i32)])
                .with_context(|| format!("init on core {cid}"))?;
            if shared_params.is_none() {
                shared_params = Some(outs[0].clone());
                shared_opt = Some(outs[1].clone());
            }
            states.push(CoreState {
                core,
                params: shared_params.clone().unwrap(),
                opt: shared_opt.clone().unwrap(),
                env_states: outs[2].clone(),
            });
        }

        let mut rng = crate::util::rng::Xoshiro256::from_stream(cfg.seed, 0xA11A);
        let mut metrics_hist: Vec<MetricRow> = Vec::new();
        let mut updates = 0u64;
        let t0 = Instant::now();

        for _outer in 0..cfg.outer_iters {
            // One deterministic program seed per core per outer iteration.
            let seeds: Vec<i32> = (0..cfg.cores).map(|_| rng.next_program_seed()).collect();
            match cfg.mode {
                Mode::Bundled => {
                    let mut waits = Vec::with_capacity(cfg.cores);
                    for (s, &seed) in states.iter().zip(&seeds) {
                        waits.push(s.core.execute_async(
                            &bundled,
                            vec![
                                s.params.clone(),
                                s.opt.clone(),
                                s.env_states.clone(),
                                HostTensor::scalar_i32(seed),
                            ],
                        )?);
                    }
                    let mut row = [0.0f64; 5];
                    let mut param_bufs = Vec::with_capacity(cfg.cores);
                    let mut opt_bufs = Vec::with_capacity(cfg.cores);
                    for (s, rx) in states.iter_mut().zip(waits) {
                        let outs = rx
                            .recv()
                            .map_err(|_| anyhow::anyhow!("anakin core died"))??;
                        param_bufs.push(outs[0].clone().into_f32()?);
                        opt_bufs.push(outs[1].clone().into_f32()?);
                        s.env_states = outs[2].clone();
                        // metrics [K, 5]
                        let m = outs[3].as_f32()?;
                        let k = m.len() / 5;
                        for ki in 0..k {
                            for j in 0..5 {
                                row[j] += m[ki * 5 + j] as f64 / (k * cfg.cores) as f64;
                            }
                        }
                    }
                    // cross-core average (the driver-level pmean)
                    all_reduce_mean(&mut param_bufs)?;
                    all_reduce_mean(&mut opt_bufs)?;
                    let p = HostTensor::f32(vec![param_bufs[0].len()], param_bufs[0].clone())?;
                    let o = HostTensor::f32(vec![opt_bufs[0].len()], opt_bufs[0].clone())?;
                    for s in &mut states {
                        s.params = p.clone();
                        s.opt = o.clone();
                    }
                    metrics_hist.push(row);
                    updates += iters as u64;
                }
                Mode::Psum => {
                    let mut waits = Vec::with_capacity(cfg.cores);
                    for (s, &seed) in states.iter().zip(&seeds) {
                        waits.push(s.core.execute_async(
                            &psum_grad,
                            vec![
                                s.params.clone(),
                                s.opt.clone(),
                                s.env_states.clone(),
                                HostTensor::scalar_i32(seed),
                            ],
                        )?);
                    }
                    let mut grad_bufs = Vec::with_capacity(cfg.cores);
                    let mut row = [0.0f64; 5];
                    for (s, rx) in states.iter_mut().zip(waits) {
                        let outs = rx
                            .recv()
                            .map_err(|_| anyhow::anyhow!("anakin core died"))??;
                        grad_bufs.push(outs[0].clone().into_f32()?);
                        s.env_states = outs[1].clone();
                        let m = outs[2].as_f32()?;
                        for j in 0..5 {
                            row[j] += m[j] as f64 / cfg.cores as f64;
                        }
                    }
                    // the psum: average gradients, apply once, broadcast
                    all_reduce_mean(&mut grad_bufs)?;
                    let grads =
                        HostTensor::f32(vec![grad_bufs[0].len()], grad_bufs[0].clone())?;
                    let outs = states[0].core.execute(
                        &apply,
                        vec![states[0].params.clone(), states[0].opt.clone(), grads],
                    )?;
                    let p = outs[0].clone();
                    let o = outs[1].clone();
                    for s in &mut states {
                        s.params = p.clone();
                        s.opt = o.clone();
                    }
                    metrics_hist.push(row);
                    updates += 1;
                }
            }
        }

        let elapsed = t0.elapsed().as_secs_f64();
        let per_call = match cfg.mode {
            Mode::Bundled => batch * unroll * iters,
            Mode::Psum => batch * unroll,
        };
        let steps = (per_call as u64) * cfg.outer_iters * cfg.cores as u64;
        let mut critical: f64 = 1e-12;
        for s in &states {
            critical = critical.max(s.core.busy_seconds());
        }
        Ok(AnakinReport {
            steps,
            updates,
            elapsed,
            sps: steps as f64 / elapsed.max(1e-12),
            projected_sps: steps as f64 / critical,
            metrics: metrics_hist,
            final_params: states[0].params.clone().into_f32()?,
        })
    }
}

/// All cores must hold identical parameters after a run — the invariant the
/// collective preserves. (Helper for tests.)
pub fn params_in_sync(report_params: &[f32], other: &[f32]) -> bool {
    report_params.len() == other.len()
        && report_params
            .iter()
            .zip(other)
            .all(|(a, b)| (a - b).abs() <= 1e-6 * a.abs().max(1.0))
}
