//! Ablation: the actor/learner core split.
//!
//! Paper: "For simple model-free agents we often find it convenient to have
//! 3x as many learner cores as actor cores (since the backward pass is
//! slower than the forward pass)." This sweep varies A:L over an 8-core
//! host on the atari_like conv agent and reports throughput plus the
//! actor/learner busy-time balance that explains the optimum.

use podracer::benchkit::Bench;
use podracer::experiment::{Arch, EnvKind, Experiment, Topology};
use podracer::runtime::Pod;

fn main() -> anyhow::Result<()> {
    podracer::util::logging::init();
    let artifacts = podracer::artifacts_dir();
    let fast = std::env::var("PODRACER_BENCH_FAST").is_ok();
    let updates = if fast { 3 } else { 8 };

    // (actor cores, learner cores) with actor_batch=32 => shard 32/L
    // (grad programs lowered for b in {8, 16, 32})
    let splits = [(1usize, 4usize), (2, 4), (4, 4), (4, 2), (6, 2), (4, 1)];

    let mut bench = Bench::new("ablation: actor:learner core split (paper: 1:3 for model-free)");
    let max_cores = splits.iter().map(|&(a, l)| a + l).max().unwrap();
    let mut pod = Pod::new(&artifacts, max_cores)?;
    let mut rows = Vec::new();

    for &(a, l) in &splits {
        let exp = Experiment::new(Arch::Sebulba)
            .artifacts(&artifacts)
            .agent("seb_atari")
            .env(EnvKind::AtariLike)
            .topology(Topology {
                actor_cores: a,
                learner_cores: l,
                threads_per_actor_core: 1,
                pipeline_stages: 1, // keep the seed geometry: this sweep is about the core split
                learner_pipeline: 2, // default learner schedule; this sweep holds it fixed
                queue_capacity: 2,
                ..Topology::default()
            })
            .actor_batch(32)
            .unroll(20)
            .updates(updates)
            .seed(5)
            .build()?;
        let mut out = (0.0, 0.0, 0.0);
        bench.case(&format!("{a}A:{l}L"), "frames/s", || {
            let r = exp.run_on(&mut pod).unwrap();
            let d = r.as_actor_learner().unwrap();
            out = (r.throughput, d.actor_busy_seconds, d.learner_busy_seconds);
            r.throughput
        });
        rows.push((a, l, out.0, out.1, out.2));
    }

    println!("\n| split (A:L) | frames/s | actor busy (s) | learner busy (s) | learner/actor compute |");
    println!("|---|---|---|---|---|");
    for &(a, l, fps, ab, lb) in &rows {
        println!("| {a}:{l} | {fps:.0} | {ab:.2} | {lb:.2} | {:.2}x |", lb / ab.max(1e-9));
    }
    println!(
        "\nshape check (paper: backward pass slower than forward => learner-heavy split wins):\n\
         the learner/actor compute ratio above shows how much device time the update needs\n\
         relative to inference for the same frames — >1 supports the paper's 1:3 guidance."
    );

    bench.finish();
    Ok(())
}
