//! Ablation: double-buffered learner rounds (`learner_pipeline`).
//!
//! The paper's Sebulba learner keeps its cores saturated by *streaming*
//! sharded batches through the update function; a strictly serial
//! pop→grad→reduce→apply loop instead parks the learner cores during the
//! host-side collective and the apply round-trip. This sweep measures what
//! depth-2 pipelining hides (DESIGN.md §9): at `learner_pipeline = 2`,
//! round k+1's grad programs run on the learner cores while round k's
//! collective + apply retire on the host, so the exposed learner schedule
//! (`learner_active_seconds`, a critical-path candidate for
//! `projected_fps`) collapses toward pure device time.
//!
//! Config notes: catch keeps the actors cheap so the learner path is the
//! bottleneck, `micro_batches = 2` gives every bundle two grad rounds so
//! the pipeline fills deterministically, and two actor threads keep the
//! trajectory queue from starving the learner.

use podracer::benchkit::Bench;
use podracer::experiment::{Arch, EnvKind, Experiment, Topology};
use podracer::runtime::Pod;

fn main() -> anyhow::Result<()> {
    podracer::util::logging::init();
    let artifacts = podracer::artifacts_dir();
    let fast = std::env::var("PODRACER_BENCH_FAST").is_ok();
    let updates = if fast { 6 } else { 40 };
    let depths = [1usize, 2];

    let mut bench =
        Bench::new("ablation: learner pipeline (double-buffered grad/apply rounds)");
    let mut rows = Vec::new();

    for &depth in &depths {
        let exp = Experiment::new(Arch::Sebulba)
            .artifacts(&artifacts)
            .agent("seb_catch")
            .env(EnvKind::Catch)
            .topology(Topology {
                actor_cores: 1,
                learner_cores: 2,
                threads_per_actor_core: 2, // keep the learner fed: it must be the bottleneck
                pipeline_stages: 2,
                learner_pipeline: depth,
                ..Topology::default()
            })
            .actor_batch(32)
            .unroll(20)
            .micro_batches(2) // two rounds per bundle: the pipeline fills every window
            .updates(updates)
            .seed(7)
            .build()?;
        let mut out = (0.0, 0.0, 0.0, 0.0);
        bench.case(&format!("learner_pipeline={depth}"), "projected frames/s", || {
            // Fresh pod per repeat: core busy-time accumulates for the life
            // of a pod and projected fps divides by the max core busy — a
            // shared pod would charge each run with every previous run's
            // device time and sink the depth-1 vs depth-2 comparison.
            let mut pod = Pod::new(&artifacts, 3).unwrap();
            let r = exp.run_on(&mut pod).unwrap();
            let d = r.as_actor_learner().unwrap();
            out = (
                r.projected_throughput,
                r.throughput,
                d.learner_active_seconds,
                d.learner_overlap_seconds,
            );
            r.projected_throughput
        });
        rows.push((depth, out.0, out.1, out.2, out.3));
    }

    println!("\n| learner pipeline | projected fps | wall fps | learner active (s) | hidden by overlap (s) |");
    println!("|---|---|---|---|---|");
    for &(d, pfps, fps, active, overlap) in &rows {
        println!("| {d} | {pfps:.0} | {fps:.0} | {active:.2} | {overlap:.2} |");
    }
    println!(
        "\nshape check (streaming-learner claim): at learner_pipeline=2 the gradient\n\
         harvest, host collective and bus wait retire under the next round's grads\n\
         (the apply stays serial on core 0 — DESIGN.md §9), so hidden-overlap seconds\n\
         must be ~0 at depth 1 and positive at depth 2, learner-active seconds must\n\
         shrink by the exposed host time, and projected fps must come out higher on\n\
         the same config. wall fps moves the same way on a fixed topology."
    );

    bench.finish();
    Ok(())
}
