//! Ablation: trajectory length (paper: 60 in Sebulba, up from 20 in IMPALA;
//! longer trajectories increase the effective learner batch and amortise
//! per-update overheads at the price of staler behaviour policies).

use podracer::benchkit::Bench;
use podracer::experiment::{Arch, EnvKind, Experiment, Topology};
use podracer::runtime::Pod;

fn main() -> anyhow::Result<()> {
    podracer::util::logging::init();
    let artifacts = podracer::artifacts_dir();
    let fast = std::env::var("PODRACER_BENCH_FAST").is_ok();
    let updates = if fast { 4 } else { 12 };
    let lens = [20usize, 60, 120];

    let mut bench = Bench::new("ablation: trajectory length T (IMPALA 20 vs Sebulba 60)");
    let mut pod = Pod::new(&artifacts, 6)?;
    let mut rows = Vec::new();

    for &t in &lens {
        let exp = Experiment::new(Arch::Sebulba)
            .artifacts(&artifacts)
            .agent("seb_catch")
            .env(EnvKind::Catch)
            .topology(Topology {
                actor_cores: 2,
                learner_cores: 4, // shard 8: grads lowered for t in {20, 60, 120}
                threads_per_actor_core: 2,
                pipeline_stages: 1, // keep the seed geometry: this sweep is about T
                learner_pipeline: 2, // default learner schedule; this sweep holds it fixed
                queue_capacity: 2,
                ..Topology::default()
            })
            .actor_batch(32)
            .unroll(t)
            .updates(updates)
            .seed(6)
            .build()?;
        let mut out = (0.0, 0.0, 0.0);
        bench.case(&format!("T={t}"), "frames/s", || {
            let r = exp.run_on(&mut pod).unwrap();
            let d = r.as_actor_learner().unwrap();
            out = (r.throughput, d.mean_staleness, r.steps as f64 / r.updates as f64);
            r.throughput
        });
        rows.push((t, out.0, out.1, out.2));
    }

    println!("\n| T | frames/s | frames per update | staleness (updates) |");
    println!("|---|---|---|---|");
    for &(t, fps, stale, fpu) in &rows {
        println!("| {t} | {fps:.0} | {fpu:.0} | {stale:.2} |");
    }
    println!(
        "\nshape check (paper: longer T => bigger effective batch per update, better\n\
         amortisation): frames-per-update grows linearly with T while throughput holds or\n\
         improves; staleness (off-policy lag) grows with T — the tradeoff the paper manages\n\
         with V-trace."
    );

    bench.finish();
    Ok(())
}
