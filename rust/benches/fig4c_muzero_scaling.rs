//! Figure 4c: MuZero-on-Sebulba FPS as a function of the number of cores.
//!
//! Paper: replicating the basic slice 16 -> 128 cores scales MuZero's
//! throughput linearly (search-bound acting; each replica brings its own
//! host + actor cores). Testbed: 1 -> 2 replicas of a 4-core slice (2 actor
//! + 2 learner), MCTS in Rust, model programs on the actor cores.

use podracer::benchkit::Bench;
use podracer::experiment::{Arch, EnvKind, Experiment, Topology};
use podracer::runtime::Pod;
use podracer::util::json::Json;

fn main() -> anyhow::Result<()> {
    podracer::util::logging::init();
    let artifacts = podracer::artifacts_dir();
    let fast = std::env::var("PODRACER_BENCH_FAST").is_ok();
    let updates = if fast { 2 } else { 5 };
    let replica_counts = [1usize, 2];

    let mut bench = Bench::new("fig4c: muzero FPS vs cores (paper: 16-128 cores, linear)");
    let mut series = Vec::new();
    let max_cores = replica_counts.iter().max().unwrap() * 4;
    let mut pod = Pod::new(&artifacts, max_cores)?;

    for &replicas in &replica_counts {
        let exp = Experiment::new(Arch::MuZero)
            .artifacts(&artifacts)
            .agent("mz_catch")
            .env(EnvKind::Catch)
            .topology(Topology {
                actor_cores: 2,
                learner_cores: 2,
                replicas,
                threads_per_actor_core: 1,
                pipeline_stages: 1,
                learner_pipeline: 1,
                queue_capacity: 2,
                ..Topology::default()
            })
            .num_simulations(if fast { 4 } else { 8 })
            .updates(updates)
            .seed(4)
            .build()?;
        let cores = exp.topology().total_cores();
        let mut out = 0.0;
        bench.case(&format!("cores={cores} (replicas={replicas})"), "frames/s", || {
            let report = exp.run_on(&mut pod).unwrap();
            out = report.throughput;
            report.throughput
        });
        series.push((cores, out));
    }

    println!("\n| cores | measured aggregate frames/s | efficiency vs 1 replica | projected parallel frames/s |");
    println!("|---|---|---|---|");
    let base = series[0].1;
    let base_cores = series[0].0 as f64;
    let mut proj = Vec::new();
    for &(cores, fps) in &series {
        // frames generated per unit wall time is flat on 1 CPU; efficiency
        // captures coordination overhead growth; projected assumes the
        // measured per-slice rate parallelises (paper's linear claim).
        let eff = fps / base;
        let projected = base * (cores as f64 / base_cores) * eff;
        proj.push(projected);
        println!("| {cores} | {fps:.0} | {eff:.3} | {projected:.0} |");
    }
    println!(
        "\nshape check (paper Fig 4c: linear in cores): projected speedup at {}x cores = {:.2}x",
        series.last().unwrap().0 / series[0].0,
        proj.last().unwrap() / proj[0]
    );

    bench.finish();
    let j = Json::obj(vec![
        ("figure", Json::str("4c")),
        ("cores", Json::arr_f64(&series.iter().map(|s| s.0 as f64).collect::<Vec<_>>())),
        ("measured_fps", Json::arr_f64(&series.iter().map(|s| s.1).collect::<Vec<_>>())),
        ("projected_fps", Json::arr_f64(&proj)),
    ]);
    std::fs::create_dir_all("bench_results")?;
    std::fs::write("bench_results/fig4c_series.json", j.to_string())?;
    Ok(())
}
