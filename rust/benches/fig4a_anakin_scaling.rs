//! Figure 4a: Anakin frames/sec as a function of the number of cores.
//!
//! Paper: 16 -> 128 TPU cores, near-linear scaling, "the collective
//! operations used to average gradients across replicas appear to cause
//! only minimal overhead". Testbed: 1 -> 8 *simulated* cores on one CPU.
//!
//! On a single CPU, cores time-share, so wall-clock FPS cannot scale; the
//! figure's *shape* is reproduced through two measured quantities:
//!   * per-core step rate (aggregate steps / total core-busy time) — if the
//!     collective added overhead, this would fall with core count;
//!   * scaling efficiency = projected FPS at N cores (N x per-core rate,
//!     discounted by measured coordination wall-time) / (N x 1-core rate).
//!
//! PR 3 adds the driver comparison: the sweep runs the threaded driver
//! (per-core replica threads, `TensorBus` pmean — DESIGN.md §10) and a
//! serial-driver case at 4 cores, so the table quantifies what threading
//! the host schedule buys in wall-clock sps on the same config. The
//! emitted JSON feeds the CI bench-regression gate (`scripts/bench_gate.py`).
//! See DESIGN.md §1 (hardware substitution) and EXPERIMENTS.md §Fig4a.

use podracer::anakin::Driver;
use podracer::benchkit::Bench;
use podracer::experiment::{Arch, Experiment, Topology};
use podracer::runtime::Pod;
use podracer::util::json::Json;

fn main() -> anyhow::Result<()> {
    podracer::util::logging::init();
    let artifacts = podracer::artifacts_dir();
    let fast = std::env::var("PODRACER_BENCH_FAST").is_ok();
    let outer = if fast { 2 } else { 6 };
    let core_counts = [1usize, 2, 4, 8];
    const COMPARE_CORES: usize = 4;

    let mut bench = Bench::new("fig4a: anakin FPS vs cores (paper: 16-128 cores, linear)");
    let mut rows = Vec::new();
    let mut pod = Pod::new(&artifacts, *core_counts.iter().max().unwrap())?;

    for &cores in &core_counts {
        let exp = Experiment::new(Arch::Anakin)
            .artifacts(&artifacts)
            .agent("anakin_catch")
            .topology(Topology::anakin(cores))
            .updates(outer)
            .driver(Driver::Threaded)
            .seed(1)
            .build()?;
        let mut last: Option<(f64, f64)> = None;
        bench.case(&format!("cores={cores}"), "steps/s (aggregate wall)", || {
            let report = exp.run_on(&mut pod).unwrap();
            last = Some((report.throughput, report.steps as f64));
            report.throughput
        });
        let (sps, steps) = last.unwrap();
        rows.push((cores, sps, steps));
    }

    // Driver ablation at the comparison core count (programs are already
    // loaded on cores 0..COMPARE_CORES from the sweep, so both cases pay
    // zero compile time and the gap is purely the host schedule).
    let mut driver_sps = [0.0f64; 2]; // [serial, threaded]
    for (slot, driver, name) in
        [(0usize, Driver::Serial, "serial"), (1, Driver::Threaded, "threaded")]
    {
        let exp = Experiment::new(Arch::Anakin)
            .artifacts(&artifacts)
            .agent("anakin_catch")
            .topology(Topology::anakin(COMPARE_CORES))
            .updates(outer)
            .driver(driver)
            .seed(1)
            .build()?;
        bench.case(
            &format!("driver={name} cores={COMPARE_CORES}"),
            "steps/s (aggregate wall)",
            || {
                let report = exp.run_on(&mut pod).unwrap();
                driver_sps[slot] = report.throughput;
                report.throughput
            },
        );
    }
    let speedup = driver_sps[1] / driver_sps[0].max(1e-12);

    // scaling table: projected N-core FPS = N x (1-core aggregate rate),
    // discounted by the measured throughput ratio (which embeds collective
    // + driver overhead growth).
    let base = rows[0].1;
    println!("\n| cores | measured aggregate steps/s | efficiency vs 1-core | projected parallel steps/s |");
    println!("|---|---|---|---|");
    let mut proj = Vec::new();
    for &(cores, sps, _) in &rows {
        // on 1 CPU, N cores' compute serializes: measured aggregate ~= flat.
        // efficiency = measured_N / measured_1 (1.0 = zero coordination cost)
        let eff = sps / base;
        let projected = base * cores as f64 * eff;
        proj.push(projected);
        println!("| {cores} | {sps:.0} | {eff:.3} | {projected:.0} |");
    }
    println!(
        "\nshape check (paper Fig 4a: near-linear): projected speedup at {}x cores = {:.2}x",
        core_counts[core_counts.len() - 1],
        proj[proj.len() - 1] / proj[0]
    );
    println!(
        "driver check (DESIGN.md §10): threaded vs serial wall-clock sps at {COMPARE_CORES} cores = {:.2}x \
         ({:.0} vs {:.0}; target >= 1.5x in the smoke run)",
        speedup, driver_sps[1], driver_sps[0]
    );

    bench.finish();
    // extra JSON with the derived series (consumed by scripts/bench_gate.py)
    let j = Json::obj(vec![
        ("figure", Json::str("4a")),
        ("cores", Json::arr_f64(&rows.iter().map(|r| r.0 as f64).collect::<Vec<_>>())),
        ("measured_sps", Json::arr_f64(&rows.iter().map(|r| r.1).collect::<Vec<_>>())),
        ("projected_sps", Json::arr_f64(&proj)),
        ("serial_sps_4c", Json::num(driver_sps[0])),
        ("threaded_sps_4c", Json::num(driver_sps[1])),
        ("threaded_speedup_4c", Json::num(speedup)),
    ]);
    std::fs::create_dir_all("bench_results")?;
    std::fs::write("bench_results/fig4a_series.json", j.to_string())?;
    Ok(())
}
